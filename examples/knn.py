"""Paper Sec. VI: kNN classification via order statistics (no sort).

  PYTHONPATH=src python examples/knn.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import robust


def main():
    rng = np.random.default_rng(0)
    centers = np.array([[0, 0], [6, 0], [3, 5]], np.float32)
    n_per = 500
    tx = np.concatenate([
        rng.standard_normal((n_per, 2)).astype(np.float32) + c
        for c in centers])
    ty = np.repeat(np.arange(3), n_per).astype(np.int32)

    qx = np.concatenate([
        rng.standard_normal((100, 2)).astype(np.float32) + c
        for c in centers])
    qy = np.repeat(np.arange(3), 100)

    pred = robust.knn_predict(jnp.asarray(tx), jnp.asarray(ty),
                              jnp.asarray(qx), k=15, classify=True,
                              n_classes=3)
    acc = (np.asarray(pred) == qy).mean()
    print(f"kNN (selection-based cutoff, k=15): accuracy={acc:.1%} "
          f"on {len(qy)} queries / {len(ty)} refs")

    # regression flavour
    f = lambda pts: np.sin(pts[:, 0]) + 0.5 * pts[:, 1]
    ty_r = f(tx).astype(np.float32)
    pred_r = robust.knn_predict(jnp.asarray(tx), jnp.asarray(ty_r),
                                jnp.asarray(qx), k=15)
    mae = np.abs(np.asarray(pred_r) - f(qx)).mean()
    print(f"kNN regression: MAE={mae:.3f}")


if __name__ == "__main__":
    main()
