"""Serving example: batched prefill + greedy decode with KV caches, with
latency percentiles computed by the paper's selection primitive (no sort).

  PYTHONPATH=src python examples/serve_lm.py --batch 4 --prompt-len 32 --gen 24
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, local_plan
from repro.core import selection
from repro.models import model
from repro.train import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    plan = local_plan()
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0), cfg)
    B, P, G = args.batch, args.prompt_len, args.gen
    max_seq = P + G

    serve = jax.jit(make_serve_step(cfg, plan))
    cache = model.init_cache(cfg, B, max_seq=max_seq, plan=plan,
                             dtype=jnp.float32)

    # prefill: feed the prompt token by token (prefill-by-decode keeps the
    # example simple; launch/serve.py shows the batched-prefill path)
    prompt = rng.integers(0, cfg.vocab, (B, P)).astype(np.int32)
    tok_times = []
    tok = None
    for t in range(P):
        t0 = time.perf_counter()
        tok, _, cache = serve(params, cache, jnp.asarray(prompt[:, t:t+1]),
                              jnp.asarray(t, jnp.int32))
        jax.block_until_ready(tok)
        tok_times.append(time.perf_counter() - t0)

    generated = []
    for t in range(P, max_seq):
        t0 = time.perf_counter()
        tok, _, cache = serve(params, cache, tok, jnp.asarray(t, jnp.int32))
        jax.block_until_ready(tok)
        tok_times.append(time.perf_counter() - t0)
        generated.append(np.asarray(tok)[:, 0])

    gen = np.stack(generated, 1)
    ts = jnp.asarray(tok_times[2:], jnp.float32)  # drop compile steps
    p50 = float(selection.median(ts).value) * 1e3
    p99 = float(selection.quantile(ts, 0.99).value) * 1e3
    print(f"arch={cfg.name} (reduced): generated {gen.shape} tokens")
    print(f"first sequence: {gen[0][:12]} ...")
    print(f"per-token latency: p50={p50:.2f}ms p99={p99:.2f}ms "
          f"(percentiles via cutting-plane selection)")


if __name__ == "__main__":
    main()
