"""End-to-end training driver: trains a small LM for a few hundred steps on
CPU with the full production stack — data pipeline, AdamW, quantile
gradient clipping (the paper's primitive), checkpointing, restart, and
step-time percentile telemetry.

  PYTHONPATH=src python examples/train_lm.py --steps 200          # ~10M params
  PYTHONPATH=src python examples/train_lm.py --steps 300 --large  # ~100M params

Resume after interruption:
  PYTHONPATH=src python examples/train_lm.py --steps 400 --ckpt-dir /tmp/lm_ckpt
"""
import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, local_plan
from repro.configs.base import ShapeConfig
from repro.data import SyntheticPipeline
from repro.models import model
from repro.optim import AdamW
from repro.train import TrainState, fit, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--large", action="store_true",
                    help="~100M-param config (slow on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--clip", default="quantile",
                    choices=("quantile", "global_norm", "none"))
    args = ap.parse_args()

    base = get_config(args.arch)
    if args.large:
        cfg = base.reduced(d_model=512, n_heads=8, head_dim=64,
                           n_kv_heads=min(base.n_kv_heads, 4), d_ff=2048,
                           vocab=32768,
                           n_layers=len(base.layer_pattern) * 4)
    else:
        cfg = base.reduced(d_model=256, n_heads=4, head_dim=64, d_ff=1024,
                           vocab=8192,
                           n_layers=len(base.layer_pattern) * 2)
    plan = local_plan()
    shape = ShapeConfig("example", seq_len=args.seq,
                        global_batch=args.batch, kind="train")

    params = model.init(jax.random.PRNGKey(0), cfg)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} (reduced) params={n_params/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq} clip={args.clip}")

    opt = AdamW(lr=3e-4)
    state = TrainState(params=params, opt=opt.init(params),
                       step=jnp.zeros((), jnp.int32))
    step_fn = make_train_step(cfg, plan, opt, clip=args.clip)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    pipe = SyntheticPipeline(cfg, shape, seed=0,
                             start_step=ckpt.latest_step() or 0)
    out = fit(train_step=step_fn, state=state, pipeline=pipe,
              steps=args.steps, ckpt=ckpt, ckpt_every=50, log_every=10)
    pipe.close()
    print(f"final loss: {out['losses'][-1]:.4f} "
          f"(first: {out['losses'][0]:.4f}); retries={out['retries']}")


if __name__ == "__main__":
    main()
