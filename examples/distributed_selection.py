"""Distributed selection demo on an 8-device host mesh (the paper's
multi-GPU scenario, Sec. V-D): the array never leaves its shards; each CP
iteration communicates four scalars; the finalize gathers only the tiny
pivot-interval buffers.  Also demos Byzantine-robust gradient aggregation.

  PYTHONPATH=src python examples/distributed_selection.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import _compat, distributed, robust  # noqa: E402


def main():
    mesh = _compat.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    n = 1 << 22
    x = rng.standard_normal(n).astype(np.float32)
    x[0] = 1e9  # outlier: CP does not care

    res = distributed.sharded_median(jnp.asarray(x), mesh, P("data"),
                                     cap_local=4096)
    truth = np.partition(x, (n + 1) // 2 - 1)[(n + 1) // 2 - 1]
    print(f"sharded median over 8 devices: {float(res.value):+.6f} "
          f"exact={np.float32(res.value) == truth} "
          f"iters={int(res.iters)} |z|={int(res.n_in)}")

    # Byzantine-robust aggregation: device 3 sends garbage gradients
    g = np.tile(np.linspace(-1, 1, 128, dtype=np.float32), (8, 1))
    g += 0.01 * rng.standard_normal(g.shape).astype(np.float32)
    g[3] = 1e6  # corrupted replica

    def agg(gl, method):
        return robust.robust_aggregate({"g": gl}, "data", method=method)

    for method in ["mean", "median", "trimmed"]:
        out = _compat.shard_map(
            lambda gl: agg(gl, method), mesh=mesh,
            in_specs=P("data"), out_specs=P("data"), check=False,
        )(jnp.asarray(g))
        err = float(jnp.max(jnp.abs(np.asarray(out["g"])[0]
                                    - np.linspace(-1, 1, 128))))
        print(f"aggregate[{method:7s}]: max deviation from truth = {err:.4f}"
              f"  {'(poisoned!)' if err > 1 else '(robust)'}")


if __name__ == "__main__":
    main()
