"""Paper Sec. VI: high-breakdown regression demo (LS vs LMS vs LTS).

30% of responses are contaminated; ordinary least squares collapses while
the selection-based LMS/LTS estimators recover the true coefficients.

  PYTHONPATH=src python examples/robust_regression.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import robust


def main():
    rng = np.random.default_rng(0)
    n, p = 2000, 5
    X = rng.standard_normal((n, p)).astype(np.float32)
    X[:, -1] = 1.0
    theta_true = np.array([2.0, -1.0, 0.5, 3.0, -0.7], np.float32)
    y = X @ theta_true + 0.05 * rng.standard_normal(n).astype(np.float32)
    out_idx = rng.choice(n, int(0.3 * n), replace=False)
    y[out_idx] += 300 + 100 * rng.random(len(out_idx)).astype(np.float32)

    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    theta_ls = np.linalg.lstsq(X, y, rcond=None)[0]
    lts = robust.lts_fit(jax.random.PRNGKey(0), Xj, yj, n_starts=128)
    lms = robust.lms_fit(jax.random.PRNGKey(1), Xj, yj, n_starts=512)

    print(f"{'':12s} {'true':>8s} {'LS':>9s} {'LMS':>9s} {'LTS':>9s}")
    for i in range(p):
        print(f"theta[{i}]     {theta_true[i]:8.3f} {theta_ls[i]:9.3f} "
              f"{float(lms.theta[i]):9.3f} {float(lts.theta[i]):9.3f}")
    for name, th in [("LS", theta_ls), ("LMS", np.asarray(lms.theta)),
                     ("LTS", np.asarray(lts.theta))]:
        print(f"||err|| {name}: {np.linalg.norm(th - theta_true):.4f}")

    w = np.asarray(lts.inlier_weights)
    flagged = np.where(w == 0)[0]
    hit = len(set(flagged) & set(out_idx)) / len(out_idx)
    print(f"LTS flagged {len(flagged)} outliers; "
          f"recall of true outliers: {hit:.1%}")


if __name__ == "__main__":
    main()
