"""Quickstart: the paper in one script.

Computes the median of a large array with every method, shows the CP
iteration count, the hybrid pivot-interval size and exactness, the outlier
robustness, and the monotone-transform guard.

  PYTHONPATH=src python examples/quickstart.py [--n 2097152]
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import selection


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 21)
    args = ap.parse_args()
    n = args.n
    rng = np.random.default_rng(0)
    x = rng.standard_normal(n).astype(np.float32)
    xj = jnp.asarray(x)
    k = (n + 1) // 2
    truth = np.partition(x, k - 1)[k - 1]
    print(f"n={n}, true median={truth}")

    for method in ["sort", "cp", "binned", "bisection", "golden", "brent"]:
        fn = jax.jit(lambda v: selection.order_statistic(
            v, k, method=method, maxit=256).value)
        fn(xj).block_until_ready()  # compile
        t0 = time.perf_counter()
        val = fn(xj).block_until_ready()
        dt = time.perf_counter() - t0
        res = selection.order_statistic(xj, k, method=method, maxit=256)
        print(f"  {method:10s}: {float(val):+.6f} exact={float(val)==truth} "
              f"iters={int(res.iters):3d} |z|={int(res.n_in):7d} "
              f"time={dt*1e3:.2f}ms")

    print("\nWith one 1e9 outlier (paper Fig. 5):")
    x2 = x.copy(); x2[0] = 1e9
    for method in ["cp", "binned", "bisection"]:
        res = selection.order_statistic(jnp.asarray(x2), k, method=method,
                                        maxit=256)
        print(f"  {method:10s}: iters={int(res.iters):3d} "
              f"exact={np.float32(res.value)==np.partition(x2,k-1)[k-1]}")

    print("\nWith 1e20 entries (f32 summation breakdown -> log1p guard):")
    x3 = x.copy(); x3[:16] = 1e20
    want = np.partition(x3, k - 1)[k - 1]
    r_plain = selection.order_statistic(jnp.asarray(x3), k)
    r_guard = selection.order_statistic(jnp.asarray(x3), k,
                                        transform="log1p")
    print(f"  plain:  exact={np.float32(r_plain.value)==want}")
    print(f"  log1p:  exact={np.float32(r_guard.value)==want}")


if __name__ == "__main__":
    main()
