"""Subprocess worker: mini dry-run on an 8-device host mesh.

Exercises the full launch path (plans, specs, lowering, compiling, roofline
analysis) at reduced scale — the same code the 512-device dry-run uses.
"""
import os
import sys

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402

from repro.analysis import analyze_compiled, roofline_terms  # noqa: E402
from repro.core import _compat  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.configs.base import ShapeConfig  # noqa: E402
from repro.launch import inputs as I  # noqa: E402
from repro.launch.mesh import make_plan  # noqa: E402
from repro.train.step import make_serve_step, make_train_step  # noqa: E402



def check(cond, msg):
    if not cond:
        print("FAIL:", msg)
        sys.exit(1)


def main():
    mesh = _compat.make_mesh((2, 4), ("data", "model"))

    for arch, strategy in [("gemma2-2b", "tp"), ("mixtral-8x7b", "tp"),
                           ("rwkv6-1.6b", "tp"), ("gemma2-2b", "fsdp"),
                           ("whisper-medium", "tp")]:
        cfg = get_config(arch).reduced(
            d_model=64, n_heads=8, n_kv_heads=4, head_dim=16, d_ff=128,
            vocab=512 if arch != "whisper-medium" else 509,  # indivisible!
        )
        shape = ShapeConfig("t", seq_len=64, global_batch=8, kind="train")
        plan = make_plan(cfg, shape, mesh, strategy=strategy)
        opt, (state, bspecs), in_sh, out_sh = I.train_cell(cfg, shape, plan)
        step = make_train_step(cfg, plan, opt, clip="quantile",
                               accum_steps=2)
        with mesh:
            compiled = jax.jit(step, in_shardings=in_sh,
                               out_shardings=out_sh).lower(
                state, bspecs).compile()
        a = analyze_compiled(compiled, n_devices=8)
        t = roofline_terms(a)
        check(a["flops_per_device"] > 0, f"{arch}: no flops found")
        check(t["dominant"] in ("compute", "memory", "collective"), arch)
        print(f"OK train {arch}/{strategy}: {t['dominant']}-bound, "
              f"flops={a['flops_per_device']:.2e}")

    # decode path with caches on the mesh
    cfg = get_config("gemma3-27b").reduced(
        d_model=64, n_heads=8, n_kv_heads=4, head_dim=16, d_ff=128,
        vocab=512, window=16)
    shape = ShapeConfig("d", seq_len=256, global_batch=16, kind="decode")
    plan = make_plan(cfg, shape, mesh)
    args, in_sh, out_sh = I.decode_cell(cfg, shape, plan)
    serve = make_serve_step(cfg, plan)
    with mesh:
        compiled = jax.jit(serve, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=(1,)).lower(*args).compile()
    print("OK decode gemma3 (ring + global caches)")

    # long-context decode: batch < dp -> KV-sequence sharding plan
    shape = ShapeConfig("l", seq_len=1024, global_batch=1, kind="decode")
    plan = make_plan(cfg, shape, mesh)
    check(plan.seq_axes == ("data", "model"), plan)
    args, in_sh, out_sh = I.decode_cell(cfg, shape, plan)
    serve = make_serve_step(cfg, plan)
    with mesh:
        compiled = jax.jit(serve, in_shardings=in_sh,
                           out_shardings=out_sh).lower(*args).compile()
    compiled.as_text()  # smoke: lowering must stay printable
    print("OK long-context decode (seq-sharded flash combine)")
    print("ALL OK")


if __name__ == "__main__":
    main()
