"""Property-based differential suite: hypothesis strategies drive every
public selection API against ``np.partition`` / a numpy weighted oracle and
assert BIT-EXACTNESS, not closeness.

Strategy notes (shared with tests/test_property.py): float values are
derived from integer strategies (scaled by powers of two) because XLA:CPU
runs with FTZ/fast-math processor flags that trip hypothesis's strict
float-bound validation — and because integer-derived dyadic floats maximize
tie coverage (the hardest case for selection) while keeping every weight
mass EXACTLY summable, which is what makes bit-exact weighted comparisons
well-defined.  ``scale_exp`` stretches magnitudes from denormal-adjacent
(2^-30) to ±inf-adjacent (2^97 * 2^20 ~ 1.6e35, within a few octaves of
f32 max), covering the overflow-safe bin-edge and log1p regimes.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import selection  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


def to_f32(ints, scale_exp=0):
    x = np.asarray(ints, np.float64) * (2.0 ** (scale_exp - 10))
    return x.astype(np.float32)


def weighted_oracle(x, w, wk):
    """Smallest element v with sum(w[x <= v]) >= wk (f64 sorted cumsum —
    order-independent for the exactly-summable weights generated here)."""
    o = np.argsort(x, kind="stable")
    xs, ws = np.asarray(x)[o], np.asarray(w)[o]
    c = np.cumsum(ws.astype(np.float64))
    i = np.searchsorted(c, wk, side="left")
    return xs[min(i, len(xs) - 1)]


ints_small = st.lists(st.integers(-(2**20), 2**20), min_size=1, max_size=300)
# duplicate-heavy: values drawn from a handful of levels
ints_dupes = st.lists(st.integers(-4, 4), min_size=1, max_size=300)
scale_exps = st.integers(min_value=-20, max_value=97)  # denormal..inf-adjacent
methods = st.sampled_from(["cp", "binned", "bisection"])


# ---------------------------------------------------------------------------
# unweighted: order_statistic / select_rows / multi_order_statistic
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(ints=ints_small, scale_exp=scale_exps,
       kf=st.integers(min_value=0, max_value=1000), method=methods)
def test_order_statistic_bit_exact(ints, scale_exp, kf, method):
    x = to_f32(ints, scale_exp)
    n = x.size
    k = max(1, min(n, 1 + (kf * n) // 1001))
    expected = np.partition(x, k - 1)[k - 1]
    res = selection.order_statistic(jnp.asarray(x), k, method=method,
                                    maxit=256, cap=8)
    np.testing.assert_equal(np.float32(res.value), expected)


@settings(max_examples=40, deadline=None)
@given(ints=ints_dupes, scale_exp=scale_exps,
       kf=st.integers(min_value=0, max_value=1000))
def test_order_statistic_duplicate_storms(ints, scale_exp, kf):
    """Handfuls of levels (ties dominate) across the magnitude range."""
    x = to_f32(ints, scale_exp)
    n = x.size
    k = max(1, min(n, 1 + (kf * n) // 1001))
    expected = np.partition(x, k - 1)[k - 1]
    for method in ["cp", "binned"]:
        res = selection.order_statistic(jnp.asarray(x), k, method=method,
                                        maxit=256, cap=4)
        np.testing.assert_equal(np.float32(res.value), expected)


@settings(max_examples=30, deadline=None)
@given(
    ints=st.lists(st.integers(-(2**16), 2**16), min_size=4, max_size=120),
    b=st.integers(min_value=1, max_value=6),
    scale_exp=scale_exps,
    method=st.sampled_from(["cp", "binned"]),
    data=st.data(),
)
def test_select_rows_bit_exact(ints, b, scale_exp, method, data):
    base = to_f32(ints, scale_exp)
    n = base.size
    rng = np.random.default_rng(abs(hash((tuple(ints), b))) % (2**31))
    x = np.stack([rng.permutation(base) for _ in range(b)])
    ks = np.asarray(
        data.draw(st.lists(st.integers(1, n), min_size=b, max_size=b)),
        np.int32)
    res = selection.select_rows(jnp.asarray(x), jnp.asarray(ks),
                                method=method, cap=8, maxit=256)
    want = np.array([np.partition(x[i], ks[i] - 1)[ks[i] - 1]
                     for i in range(b)], np.float32)
    np.testing.assert_array_equal(np.asarray(res.value), want)


@settings(max_examples=30, deadline=None)
@given(
    ints=st.lists(st.integers(-(2**18), 2**18), min_size=2, max_size=200),
    scale_exp=scale_exps,
    data=st.data(),
)
def test_multi_order_statistic_bit_exact(ints, scale_exp, data):
    x = to_f32(ints, scale_exp)
    n = x.size
    ks = np.asarray(
        data.draw(st.lists(st.integers(1, n), min_size=1, max_size=6)),
        np.int32)
    for method in ["cp", "binned"]:
        res = selection.multi_order_statistic(
            jnp.asarray(x), jnp.asarray(ks), method=method, cap=8,
            maxit=256)
        want = np.partition(x, ks - 1)[ks - 1]
        np.testing.assert_array_equal(np.asarray(res.value), want)


@settings(max_examples=25, deadline=None)
@given(
    ints=st.lists(st.integers(0, 2**30), min_size=4, max_size=200),
    scale_exp=st.integers(min_value=0, max_value=60),
    kf=st.integers(min_value=0, max_value=1000),
)
def test_log1p_transform_bit_exact(ints, scale_exp, kf):
    """The monotone guard stays exact on huge-range data, both methods."""
    x = to_f32(ints, scale_exp)
    n = x.size
    k = max(1, min(n, 1 + (kf * n) // 1001))
    expected = np.partition(x, k - 1)[k - 1]
    for method in ["cp", "binned"]:
        res = selection.order_statistic(jnp.asarray(x), k, method=method,
                                        transform="log1p", maxit=256, cap=8)
        np.testing.assert_equal(np.float32(res.value), expected)


# ---------------------------------------------------------------------------
# weighted APIs vs the numpy weighted oracle
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(
    ints=ints_small,
    scale_exp=scale_exps,
    wf=st.integers(min_value=0, max_value=1000),
    method=st.sampled_from(["cp", "binned", "sort"]),
    data=st.data(),
)
def test_weighted_order_statistic_bit_exact(ints, scale_exp, wf, method,
                                            data):
    x = to_f32(ints, scale_exp)
    n = x.size
    w = np.asarray(
        data.draw(st.lists(st.integers(0, 7), min_size=n, max_size=n)),
        np.float32)
    w[0] = max(w[0], 1.0)  # some mass
    W = float(w.sum())
    # the target mass must be the SAME f32 value on both sides of the
    # differential (the engine compares masses in f32; a python-float wk
    # could round across an integer mass boundary)
    wk = float(np.float32(max(W * wf / 1000.0, 0.5)))
    res = selection.weighted_order_statistic(
        jnp.asarray(x), jnp.asarray(w), wk, method=method, maxit=256,
        cap=8)
    np.testing.assert_equal(np.float32(res.value),
                            weighted_oracle(x, w, wk))


@settings(max_examples=40, deadline=None)
@given(ints=ints_small, scale_exp=scale_exps,
       kf=st.integers(min_value=1, max_value=1000))
def test_weighted_uniform_equals_unweighted(ints, scale_exp, kf):
    """The property the whole weighted stack hangs on: w == 1, wk == k
    reproduces the unweighted engine bit for bit."""
    x = to_f32(ints, scale_exp)
    n = x.size
    k = max(1, min(n, 1 + (kf * n) // 1001))
    ones = jnp.ones((n,), jnp.float32)
    for method in ["cp", "binned"]:
        a = selection.weighted_order_statistic(
            jnp.asarray(x), ones, float(k), method=method, maxit=256,
            cap=8)
        b = selection.order_statistic(jnp.asarray(x), k, method=method,
                                      maxit=256, cap=8)
        np.testing.assert_equal(np.float32(a.value), np.float32(b.value))
        np.testing.assert_equal(np.float32(a.value),
                                np.partition(x, k - 1)[k - 1])


@settings(max_examples=30, deadline=None)
@given(
    ints=ints_dupes,
    scale_exp=scale_exps,
    wf=st.integers(min_value=0, max_value=1000),
    data=st.data(),
)
def test_weighted_duplicate_storm_with_zero_mass(ints, scale_exp, wf, data):
    """Tie blocks where some members carry zero weight: the answer must
    skip massless elements exactly like the oracle."""
    x = to_f32(ints, scale_exp)
    n = x.size
    w = np.asarray(
        data.draw(st.lists(st.integers(0, 2), min_size=n, max_size=n)),
        np.float32)
    w[0] = max(w[0], 1.0)
    wk = float(np.float32(max(float(w.sum()) * wf / 1000.0, 0.5)))
    for method in ["cp", "binned"]:
        res = selection.weighted_order_statistic(
            jnp.asarray(x), jnp.asarray(w), wk, method=method, maxit=256,
            cap=4)
        np.testing.assert_equal(np.float32(res.value),
                                weighted_oracle(x, w, wk))


@settings(max_examples=25, deadline=None)
@given(
    ints=st.lists(st.integers(-(2**16), 2**16), min_size=4, max_size=120),
    b=st.integers(min_value=1, max_value=5),
    scale_exp=scale_exps,
    data=st.data(),
)
def test_weighted_select_rows_bit_exact(ints, b, scale_exp, data):
    base = to_f32(ints, scale_exp)
    n = base.size
    rng = np.random.default_rng(abs(hash((tuple(ints), b, 7))) % (2**31))
    x = np.stack([rng.permutation(base) for _ in range(b)])
    w = rng.integers(0, 5, (b, n)).astype(np.float32)
    w[:, 0] = np.maximum(w[:, 0], 1.0)
    fracs = np.asarray(
        data.draw(st.lists(st.integers(1, 1000), min_size=b, max_size=b)),
        np.float64)
    wks = np.maximum(w.sum(1) * fracs / 1000.0, 0.5).astype(np.float32)
    res = selection.weighted_select_rows(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(wks), method="binned",
        maxit=256, cap=8)
    want = np.array([weighted_oracle(x[i], w[i], wks[i]) for i in range(b)],
                    np.float32)
    np.testing.assert_array_equal(np.asarray(res.value), want)


@settings(max_examples=25, deadline=None)
@given(
    ints=st.lists(st.integers(-(2**18), 2**18), min_size=2, max_size=150),
    scale_exp=scale_exps,
    data=st.data(),
)
def test_weighted_multi_order_statistic_bit_exact(ints, scale_exp, data):
    x = to_f32(ints, scale_exp)
    n = x.size
    rng = np.random.default_rng(abs(hash(tuple(ints))) % (2**31))
    w = rng.integers(0, 4, n).astype(np.float32)
    w[0] = max(w[0], 1.0)
    fracs = data.draw(st.lists(st.integers(0, 1000), min_size=1,
                               max_size=5))
    wks = np.maximum(np.asarray(fracs, np.float64) / 1000.0 * w.sum(),
                     0.5).astype(np.float32)
    for method in ["cp", "binned"]:
        res = selection.weighted_multi_order_statistic(
            jnp.asarray(x), jnp.asarray(w), jnp.asarray(wks),
            method=method, maxit=256, cap=8)
        want = np.array([weighted_oracle(x, w, t) for t in wks], np.float32)
        np.testing.assert_array_equal(np.asarray(res.value), want)


# ---------------------------------------------------------------------------
# polish_edges: direct property coverage (previously only end-to-end)
# ---------------------------------------------------------------------------


@settings(max_examples=80, deadline=None)
@given(
    a=st.integers(-(2**20), 2**20),
    b=st.integers(-(2**20), 2**20),
    scale_exp=scale_exps,
    degen=st.sampled_from(["none", "collapsed", "ulp", "inf_adjacent"]),
    tkind=st.sampled_from(["inside", "below", "above", "nan", "inf"]),
    tq=st.integers(min_value=0, max_value=1000),
    nbins=st.sampled_from([2, 3, 4, 8, 128]),
)
def test_polish_edges_contract(a, b, scale_exp, degen, tkind, tq, nbins):
    """The realized-edge contract of ``polish_edges``, pinned directly:
    monotone-sorted output of ``nbins + 1`` values, ``e_0 == lo`` and
    ``e_nbins == hi`` EXACTLY, every value a realized fp number inside
    ``[lo, hi]`` — under degenerate brackets (lo == hi, ulp-wide,
    ±inf-adjacent) and degenerate cuts (outside the bracket, NaN, inf),
    which the engine feeds it whenever a bin's centroid is garbage."""
    lo, hi = np.sort(to_f32([min(a, b), max(a, b)], scale_exp))
    if degen == "collapsed":
        hi = lo
    elif degen == "ulp":
        hi = np.nextafter(lo, np.float32(np.inf))
    elif degen == "inf_adjacent":
        lo = np.float32(-3.4e38)
        hi = np.float32(3.4e38)
    if tkind == "inside":
        t = np.float32(lo + (np.float64(hi) - np.float64(lo)) * tq / 1000.0)
    elif tkind == "below":
        # f64 intermediate: the f32 cast may overflow to -inf, which is a
        # legitimate garbage-cut input the clamp must absorb
        with np.errstate(over="ignore"):
            t = np.float32(np.float64(lo) - abs(np.float64(lo)) - 1.0)
    elif tkind == "above":
        with np.errstate(over="ignore"):
            t = np.float32(np.float64(hi) + abs(np.float64(hi)) + 1.0)
    elif tkind == "nan":
        t = np.float32(np.nan)
    else:
        t = np.float32(np.inf)
    ej = selection.polish_edges(
        jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(t), nbins)
    e = np.asarray(ej)
    assert e.shape == (nbins + 1,)
    assert np.all(np.isfinite(e)), e
    # monotone under the PLATFORM's comparison semantics (the ones the
    # histogram pass and descent step actually use): on FTZ hardware
    # denormal-scale edges compare DAZ-equal, which numpy would misread
    assert bool(jnp.all(ej[1:] >= ej[:-1])), "edges must be monotone-sorted"
    # exact endpoint anchoring: the descent step and the finalize compare
    # against e_0/e_nbins as the bracket itself
    assert e[0] == lo and e[-1] == hi, (e[0], e[-1], lo, hi)
    assert bool(jnp.all(ej >= lo)) and bool(jnp.all(ej <= hi))
    # realized values: the array IS the fp truth (f32 round-trip identity)
    np.testing.assert_array_equal(e, e.astype(np.float32))


@settings(max_examples=40, deadline=None)
@given(
    ints=ints_small,
    scale_exp=scale_exps,
    kf=st.integers(min_value=0, max_value=1000),
    impl=st.sampled_from(["searchsorted", "arithmetic"]),
)
def test_binned_polish_bit_exact_both_impls(ints, scale_exp, kf, impl):
    """binned_polish rides hypothesis data through both slotting impls —
    the polish must stay np.partition-exact whatever edges it places."""
    x = to_f32(ints, scale_exp)
    n = x.size
    k = max(1, min(n, 1 + (kf * n) // 1001))
    expected = np.partition(x, k - 1)[k - 1]
    res = selection.order_statistic(jnp.asarray(x), k,
                                    method="binned_polish",
                                    binned_impl=impl, maxit=256, cap=8)
    np.testing.assert_equal(np.float32(res.value), expected)


@settings(max_examples=20, deadline=None)
@given(
    ints=st.lists(st.integers(-(2**20), 2**20), min_size=1, max_size=200),
    use_f64=st.booleans(),
    wf=st.integers(min_value=0, max_value=1000),
)
def test_weighted_dtype_sweep(ints, use_f64, wf):
    """dtype leg: f32 vs (rerouted, dtype-preserving) f64 both bit-exact."""
    import jax.experimental

    x32 = to_f32(ints)
    n = x32.size
    rng = np.random.default_rng(abs(hash(tuple(ints))) % (2**31))
    w32 = rng.integers(1, 5, n).astype(np.float32)
    wk = float(np.float32(max(float(w32.sum()) * wf / 1000.0, 0.5)))
    if use_f64:
        with jax.experimental.enable_x64():
            x = x32.astype(np.float64)
            w = w32.astype(np.float64)
            res = selection.weighted_order_statistic(
                jnp.asarray(x), jnp.asarray(w), wk, method="binned",
                maxit=256, cap=8)
            np.testing.assert_equal(float(res.value),
                                    float(weighted_oracle(x, w, wk)))
    else:
        res = selection.weighted_order_statistic(
            jnp.asarray(x32), jnp.asarray(w32), wk, method="binned",
            maxit=256, cap=8)
        np.testing.assert_equal(np.float32(res.value),
                                weighted_oracle(x32, w32, wk))


# ---------------------------------------------------------------------------
# warm-start prior leg (PR 10): arbitrary-prior invariance + sweep economy
# ---------------------------------------------------------------------------

# priors drawn INDEPENDENTLY of the data: special values + dyadic floats
weird_floats = st.one_of(
    st.sampled_from([float("nan"), float("inf"), float("-inf"), 0.0, -0.0]),
    st.integers(-(2**20), 2**20).map(lambda i: i * 2.0 ** -10),
    st.integers(-(2**20), 2**20).map(lambda i: i * 2.0 ** 20),
)


def _mk_prior(pv, plo, phi, pcut):
    return selection.Prior(
        value=jnp.asarray(np.float32(pv)), y_lo=jnp.asarray(np.float32(plo)),
        y_hi=jnp.asarray(np.float32(phi)), cut=jnp.asarray(np.float32(pcut)))


@settings(max_examples=60, deadline=None)
@given(ints=ints_small, scale_exp=scale_exps,
       kf=st.integers(min_value=0, max_value=1000), method=methods,
       pv=weird_floats, plo=weird_floats, phi=weird_floats,
       pcut=weird_floats)
def test_arbitrary_prior_invariance(ints, scale_exp, kf, method,
                                    pv, plo, phi, pcut):
    """The result is pinned to ``np.partition`` for EVERY prior — the
    prior only steers edge placement, never the answer."""
    x = to_f32(ints, scale_exp)
    n = x.size
    k = max(1, min(n, 1 + (kf * n) // 1001))
    expected = np.partition(x, k - 1)[k - 1]
    res = selection.order_statistic(
        jnp.asarray(x), k, method=method, maxit=256, cap=8,
        prior=_mk_prior(pv, plo, phi, pcut))
    np.testing.assert_equal(np.float32(res.value), expected)
    assert int(res.status) != selection.NOT_CONVERGED


@settings(max_examples=40, deadline=None)
@given(ints=ints_dupes, scale_exp=scale_exps,
       wf=st.integers(min_value=0, max_value=1000),
       pv=weird_floats, pcut=weird_floats, data=st.data())
def test_arbitrary_prior_invariance_weighted(ints, scale_exp, wf, pv, pcut,
                                             data):
    """Weighted leg pinned to the f64 sorted-cumsum oracle under arbitrary
    priors, on duplicate-storm data (the hardest tie case)."""
    x = to_f32(ints, scale_exp)
    n = x.size
    w = np.asarray(
        data.draw(st.lists(st.integers(0, 3), min_size=n, max_size=n)),
        np.float32)
    w[0] = max(w[0], 1.0)
    wk = float(np.float32(max(float(w.sum()) * wf / 1000.0, 0.5)))
    prior = _mk_prior(pv, pv, pv, pcut)
    for method in ["cp", "binned"]:
        res = selection.weighted_order_statistic(
            jnp.asarray(x), jnp.asarray(w), wk, method=method, maxit=256,
            cap=4, prior=prior)
        np.testing.assert_equal(np.float32(res.value),
                                weighted_oracle(x, w, wk))


@settings(max_examples=50, deadline=None)
@given(ints=ints_small, scale_exp=scale_exps,
       kf=st.integers(min_value=0, max_value=1000))
def test_exact_prior_sweep_economy(ints, scale_exp, kf):
    """An exact prior (the previous run's own result) resolves in <= 1
    binned sweep: the ``prev_float(v)``/``v`` collapse pair certifies an
    unchanged answer immediately."""
    x = to_f32(ints, scale_exp)
    n = x.size
    k = max(1, min(n, 1 + (kf * n) // 1001))
    expected = np.partition(x, k - 1)[k - 1]
    # answers at exactly 0.0 cannot form a collapse pair under FTZ
    # (prev_float(0) is a denormal the CPU flushes) — exactness holds but
    # the 1-sweep economy legitimately does not
    hypothesis.assume(expected != 0.0)
    cold = selection.order_statistic(jnp.asarray(x), k, method="binned",
                                     maxit=256, cap=8)
    warm = selection.order_statistic(jnp.asarray(x), k, method="binned",
                                     maxit=256, cap=8, prior=cold)
    np.testing.assert_equal(np.float32(warm.value), expected)
    assert int(warm.iters) <= 1


@settings(max_examples=80, deadline=None)
@given(
    a=st.integers(-(2**20), 2**20),
    b=st.integers(-(2**20), 2**20),
    scale_exp=scale_exps,
    pv=weird_floats, plo=weird_floats, phi=weird_floats,
    pcut=weird_floats,
    nbins=st.sampled_from([2, 3, 4, 8, 128]),
)
def test_prior_edges_contract(a, b, scale_exp, pv, plo, phi, pcut, nbins):
    """``prior_edges`` honors the realized-edge contract for ANY prior:
    sorted ``nbins + 1`` output, endpoints pinned to lo/hi EXACTLY, every
    edge a finite realized fp value inside ``[lo, hi]``."""
    lo, hi = np.sort(to_f32([min(a, b), max(a, b)], scale_exp))
    e = np.asarray(selection.prior_edges(
        jnp.asarray(np.float32(lo)), jnp.asarray(np.float32(hi)),
        _mk_prior(pv, plo, phi, pcut), nbins))
    assert e.shape == (nbins + 1,)
    assert e[0] == lo and e[-1] == hi
    assert np.all(np.diff(e) >= 0)
    assert np.all((e >= lo) & (e <= hi))
    assert np.all(np.isfinite(e)) or (not np.isfinite(lo)
                                      or not np.isfinite(hi))
