"""Weighted order statistics: differential tests against a numpy
sorted-cumsum oracle, plus the weighted-regression consumers.

Exactness contract under test: with exactly-summable weights (integers /
dyadic rationals with bounded total — including the uniform case) every
mass comparison is exact, so all engine methods must be BIT-IDENTICAL to
the oracle, and uniform weights must reproduce today's unweighted answers
exactly.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import robust, selection

jax.config.update("jax_platform_name", "cpu")


def weighted_oracle(x, w, wk):
    """Smallest element v with sum(w[x <= v]) >= wk, by sorted cumsum
    (f64 accumulation: the reference is order-independent for the
    exactly-summable weights the tests generate)."""
    o = np.argsort(x, kind="stable")
    xs, ws = np.asarray(x)[o], np.asarray(w)[o]
    c = np.cumsum(ws.astype(np.float64))
    i = np.searchsorted(c, wk, side="left")
    return xs[min(i, len(xs) - 1)]


def weighted_oracle_rows(x, w, wks):
    return np.array([weighted_oracle(x[i], w[i], wks[i])
                     for i in range(x.shape[0])], x.dtype)


ENGINE_METHODS = ["cp", "binned", "bisection", "sort"]


# ---------------------------------------------------------------------------
# scalar (B=1): uniform parity + integer-weight differential sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ENGINE_METHODS)
def test_uniform_weights_reproduce_unweighted(method):
    """w == 1, wk == k must give exactly order_statistic / np.partition."""
    rng = np.random.default_rng(0)
    n = 4097
    x = rng.standard_normal(n).astype(np.float32)
    w = jnp.ones((n,), jnp.float32)
    for k in [1, 2, n // 3, (n + 1) // 2, n - 1, n]:
        res = selection.weighted_order_statistic(
            jnp.asarray(x), w, float(k), method=method)
        want = np.partition(x, k - 1)[k - 1]
        assert np.float32(res.value) == want, (method, k)
        unw = selection.order_statistic(jnp.asarray(x), k).value
        assert np.float32(res.value) == np.float32(unw), (method, k)


@pytest.mark.parametrize("method", ENGINE_METHODS)
@pytest.mark.parametrize("n", [1, 2, 50, 1000, 20_000])
def test_integer_weights_match_oracle(method, n):
    rng = np.random.default_rng(n)
    x = (rng.integers(-(2**20), 2**20, n).astype(np.float32)) * 2.0**-10
    w = rng.integers(0, 8, n).astype(np.float32)
    w[0] = 1.0  # at least some mass
    W = w.sum()
    for frac in [0.0005, 0.25, 0.5, 0.9, 1.0]:
        wk = max(frac * W, 0.5)
        res = selection.weighted_order_statistic(
            jnp.asarray(x), jnp.asarray(w), wk, method=method, cap=16)
        assert np.float32(res.value) == weighted_oracle(x, w, wk), \
            (method, n, frac)
        assert int(res.status) != selection.NOT_CONVERGED


def test_duplicate_storm_and_zero_weights():
    """Tie blocks with zero-weight members: the answer skips massless
    elements exactly like the cumsum oracle."""
    rng = np.random.default_rng(1)
    x = np.repeat(np.array([1.0, 2.0, 3.0, 4.0], np.float32), 1000)
    w = np.tile(np.array([0.0, 1.0, 2.0, 1.0], np.float32), 1000)
    p = rng.permutation(4000)
    x, w = x[p], w[p]
    for frac in [0.01, 0.3, 0.5, 0.75, 0.99]:
        wk = frac * w.sum()
        for method in ["cp", "binned"]:
            res = selection.weighted_order_statistic(
                jnp.asarray(x), jnp.asarray(w), wk, method=method, cap=4)
            assert np.float32(res.value) == weighted_oracle(x, w, wk), \
                (frac, method)


def test_wk_edges_and_clipping():
    """wk <= 0 pins the minimum; wk > total mass clips to the maximum."""
    rng = np.random.default_rng(2)
    x = rng.standard_normal(1000).astype(np.float32)
    w = rng.integers(1, 4, 1000).astype(np.float32)
    lo = selection.weighted_order_statistic(
        jnp.asarray(x), jnp.asarray(w), 0.0)
    assert np.float32(lo.value) == x.min()
    hi = selection.weighted_order_statistic(
        jnp.asarray(x), jnp.asarray(w), 10.0 * w.sum())
    assert np.float32(hi.value) == x.max()


def test_weighted_extreme_magnitudes():
    """1e9-scale outliers: the binned sweeps localize mass without a
    transform, bit-exact vs the oracle."""
    rng = np.random.default_rng(3)
    n = 100_000
    x = rng.standard_normal(n).astype(np.float32)
    x[:4] = [1e9, -1e9, 3e8, -7e8]
    w = rng.integers(1, 3, n).astype(np.float32)
    wk = 0.5 * w.sum()
    for method in ["cp", "binned"]:
        res = selection.weighted_order_statistic(
            jnp.asarray(x), jnp.asarray(w), wk, method=method)
        assert np.float32(res.value) == weighted_oracle(x, w, wk), method


# ---------------------------------------------------------------------------
# rows mode + shared-x mode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["cp", "binned", "sort"])
@pytest.mark.parametrize("b,n", [(1, 1000), (8, 4096), (33, 257)])
def test_weighted_rows_match_oracle(method, b, n):
    rng = np.random.default_rng(b * n)
    x = (rng.integers(-1000, 1000, (b, n))).astype(np.float32)
    w = rng.integers(0, 5, (b, n)).astype(np.float32)
    w[:, 0] = 1.0
    W = w.sum(axis=1)
    wks = (rng.uniform(0.05, 1.0, b) * W).astype(np.float32)
    res = selection.weighted_select_rows(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(wks), method=method,
        cap=8)
    np.testing.assert_array_equal(np.asarray(res.value),
                                  weighted_oracle_rows(x, w, wks))
    assert np.all(np.asarray(res.status) != selection.NOT_CONVERGED)


@pytest.mark.parametrize("method", ["cp", "binned", "sort"])
def test_weighted_shared_match_oracle(method):
    rng = np.random.default_rng(10)
    n = 30_000
    x = (rng.integers(-500, 500, n)).astype(np.float32)
    w = rng.integers(0, 4, n).astype(np.float32)
    w[0] = 1.0
    W = w.sum()
    wks = np.array([1e-3, 0.1, 0.25, 0.5, 0.75, 0.999, 1.0],
                   np.float32) * W
    res = selection.weighted_multi_order_statistic(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(wks), method=method,
        cap=8)
    want = np.array([weighted_oracle(x, w, t) for t in wks], np.float32)
    np.testing.assert_array_equal(np.asarray(res.value), want)


def test_weighted_quantiles_and_median_wrappers():
    rng = np.random.default_rng(11)
    n = 5000
    x = rng.standard_normal(n).astype(np.float32)
    w = rng.integers(1, 6, n).astype(np.float32)
    med = selection.weighted_median(jnp.asarray(x), jnp.asarray(w))
    assert np.float32(med.value) == weighted_oracle(x, w, 0.5 * w.sum())
    qs = [0.1, 0.5, 0.9]
    res = selection.weighted_quantiles(jnp.asarray(x), jnp.asarray(w), qs)
    want = np.array([weighted_oracle(x, w, q * w.sum()) for q in qs],
                    np.float32)
    np.testing.assert_array_equal(np.asarray(res.value), want)
    # uniform weights: weighted median == unweighted median exactly
    ones = jnp.ones((n,), jnp.float32)
    assert np.float32(selection.weighted_median(jnp.asarray(x), ones).value) \
        == np.float32(selection.median(jnp.asarray(x)).value)


def test_weighted_kernel_backend_parity():
    """Weighted solves driven by the Pallas (interpret) kernels match the
    jnp-oracle-driven solves bit for bit."""
    rng = np.random.default_rng(12)
    n = 4096
    x = (rng.integers(-1000, 1000, n)).astype(np.float32)
    w = rng.integers(0, 4, n).astype(np.float32)
    w[0] = 1.0
    wk = 0.4 * w.sum()
    for method in ["cp", "binned"]:
        a = selection.weighted_order_statistic(
            jnp.asarray(x), jnp.asarray(w), wk, method=method,
            backend="jnp")
        b = selection.weighted_order_statistic(
            jnp.asarray(x), jnp.asarray(w), wk, method=method,
            backend="pallas_interpret")
        assert np.float32(a.value) == np.float32(b.value), method
        assert np.float32(a.value) == weighted_oracle(x, w, wk), method


def test_weighted_x64_sub_f32_resolution():
    """f64 data/weights distinguishable only below f32 resolution: the
    dispatch reroutes to the dtype-preserving oracles and stays exact."""
    import jax.experimental

    with jax.experimental.enable_x64():
        base, eps = 1.0, 1e-12
        vals = np.array([base + i * eps for i in range(-30, 31)], np.float64)
        rng = np.random.default_rng(13)
        rng.shuffle(vals)
        w = rng.integers(1, 4, vals.size).astype(np.float64)
        for frac in [0.1, 0.5, 0.9]:
            wk = frac * w.sum()
            for method in ["cp", "binned"]:
                res = selection.weighted_order_statistic(
                    jnp.asarray(vals), jnp.asarray(w), wk, method=method,
                    cap=4)
                assert float(res.value) == weighted_oracle(vals, w, wk), \
                    (frac, method)


def test_weighted_binned_sweep_count():
    """The weighted binned descent keeps the ~3-sweep schedule at 1M."""
    rng = np.random.default_rng(14)
    n = 1 << 20
    x = rng.standard_normal(n).astype(np.float32)
    w = rng.integers(1, 3, n).astype(np.float32)
    res = selection.weighted_order_statistic(
        jnp.asarray(x), jnp.asarray(w), 0.5 * float(w.sum()),
        method="binned")
    assert np.float32(res.value) == weighted_oracle(x, w, 0.5 * w.sum())
    assert int(res.iters) <= 3, int(res.iters)


# ---------------------------------------------------------------------------
# distributed weighted selection (single-device mesh; multi-device in
# tests/_dist_worker.py)
# ---------------------------------------------------------------------------


def test_sharded_weighted_single_device():
    from jax.sharding import PartitionSpec as P

    from repro.core import _compat, distributed

    mesh = _compat.make_mesh((1,), ("data",))
    rng = np.random.default_rng(15)
    x = rng.standard_normal(10_000).astype(np.float32)
    w = rng.integers(0, 5, 10_000).astype(np.float32)
    w[0] = 1.0
    for frac in [0.01, 0.5, 0.99]:
        wk = frac * w.sum()
        res = distributed.sharded_weighted_order_statistic(
            jnp.asarray(x), jnp.asarray(w), wk, mesh, P("data"))
        assert np.float32(res.value) == weighted_oracle(x, w, wk), frac
    res = distributed.sharded_weighted_median(
        jnp.asarray(x), jnp.asarray(w), mesh, P("data"))
    assert np.float32(res.value) == weighted_oracle(x, w, 0.5 * w.sum())


# ---------------------------------------------------------------------------
# regression consumers: Theil-Sen + IRLS
# ---------------------------------------------------------------------------


def _contaminated_line(rng, n=200, frac=0.3, slope=2.5, intercept=-1.0):
    """30% of points moved onto an adversarial WRONG line (slope -10):
    slope-destroying contamination, not just an intercept shift."""
    x = rng.uniform(-5, 5, n).astype(np.float32)
    y = (slope * x + intercept
         + 0.01 * rng.standard_normal(n)).astype(np.float32)
    bad = rng.choice(n, size=int(frac * n), replace=False)
    y[bad] = (60.0 - 10.0 * x[bad]
              + rng.standard_normal(bad.size)).astype(np.float32)
    return x, y


def test_theil_sen_recovers_contaminated_line():
    """Acceptance bar: 30% gross contamination — Theil-Sen recovers the
    true slope, OLS does not."""
    rng = np.random.default_rng(16)
    x, y = _contaminated_line(rng)
    fit = robust.theil_sen_fit(jnp.asarray(x), jnp.asarray(y))
    assert abs(float(fit.slope) - 2.5) < 0.05
    assert abs(float(fit.intercept) + 1.0) < 0.2
    X = np.stack([np.ones_like(x), x], 1)
    ols = np.linalg.lstsq(X, y, rcond=None)[0]
    assert abs(ols[1] - 2.5) > 0.5  # OLS destroyed by the outliers
    np.testing.assert_array_equal(
        np.asarray(fit.theta),
        np.array([float(fit.intercept), float(fit.slope)], np.float32))


def test_theil_sen_uniform_weighting_and_clean_data():
    rng = np.random.default_rng(17)
    x = rng.uniform(0, 10, 100).astype(np.float32)
    y = (0.5 * x + 3.0).astype(np.float32)
    for weighting in ["sen", "uniform"]:
        fit = robust.theil_sen_fit(jnp.asarray(x), jnp.asarray(y),
                                   weighting=weighting)
        assert abs(float(fit.slope) - 0.5) < 1e-4, weighting
        assert abs(float(fit.intercept) - 3.0) < 1e-3, weighting


@pytest.mark.parametrize("loss", ["huber", "tukey"])
def test_irls_recovers_contaminated_line(loss):
    rng = np.random.default_rng(18)
    x, y = _contaminated_line(rng)
    X = jnp.asarray(np.stack([np.ones_like(x), x], 1))
    fit = robust.irls_fit(X, jnp.asarray(y), loss=loss)
    assert abs(float(fit.theta[1]) - 2.5) < 0.05, (loss, fit.theta)
    assert abs(float(fit.theta[0]) + 1.0) < 0.2, (loss, fit.theta)
    # outliers end up down-weighted, inliers keep weight ~1
    wts = np.asarray(fit.weights)
    r = np.abs(np.asarray(X) @ np.asarray(fit.theta) - y)
    assert wts[np.argsort(r)[: 100]].min() > 0.5
    assert wts[np.argmax(r)] < 0.1
    assert float(fit.scale) > 0


def test_irls_clean_data_matches_ls():
    rng = np.random.default_rng(19)
    n = 150
    x = rng.uniform(-2, 2, n).astype(np.float32)
    y = (1.5 * x + 0.25).astype(np.float32)
    X = jnp.asarray(np.stack([np.ones_like(x), x], 1))
    for loss in ["huber", "tukey"]:
        fit = robust.irls_fit(X, jnp.asarray(y), loss=loss)
        assert abs(float(fit.theta[1]) - 1.5) < 1e-3
        assert abs(float(fit.theta[0]) - 0.25) < 1e-3
