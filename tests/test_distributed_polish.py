"""Polish-driven distributed rounds: differential + round-count tests.

The multi-device leg runs in a subprocess (``--xla_force_host_platform_
device_count``, patterned on ``_dist_worker.py``) so this pytest process
keeps its single CPU device; the worker asserts, at n = 1M and for BOTH
measures, that ``method='binned_polish'`` matches np.partition / the
weighted sorted-cumsum oracle AND the local engine, that it needs exactly
1 psum round where plain binned needs >= 2, and that an injected garbage
centroid cut costs rounds but never exactness.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import _compat, distributed

jax.config.update("jax_platform_name", "cpu")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _subprocess_env():
    """Worker env: PYTHONPATH to src, XLA_FLAGS preserved except any stale
    device-count flag (the worker prepends its own)."""
    from _dist_env import subprocess_env

    return subprocess_env(ROOT)


def test_single_device_polish_path():
    """1-device mesh sanity for both measures (API + exactness; the round
    economics need real sharding and live in the subprocess worker)."""
    mesh = _compat.make_mesh((1,), ("data",))
    rng = np.random.default_rng(3)
    n = 1 << 17
    x = rng.standard_normal(n).astype(np.float32)
    k = n // 3
    res = distributed.sharded_order_statistic(
        jnp.asarray(x), k, mesh, P("data"), method="binned_polish")
    assert np.float32(res.value) == np.partition(x, k - 1)[k - 1]
    # auto resolves statically by the global element count (binned here)
    res_a = distributed.sharded_order_statistic(
        jnp.asarray(x), k, mesh, P("data"), method="auto")
    assert np.float32(res_a.value) == np.partition(x, k - 1)[k - 1]
    # and to the cp rounds below BINNED_MIN_N
    small = rng.standard_normal(1 << 10).astype(np.float32)
    res_s = distributed.sharded_order_statistic(
        jnp.asarray(small), 1 << 9, mesh, P("data"), method="auto")
    assert np.float32(res_s.value) == \
        np.partition(small, (1 << 9) - 1)[(1 << 9) - 1]
    w = rng.integers(1, 4, n).astype(np.float32)
    o = np.argsort(x, kind="stable")
    cumw = np.cumsum(w[o].astype(np.float64))
    wk = float(np.float32(0.5 * w.sum()))
    wres = distributed.sharded_weighted_order_statistic(
        jnp.asarray(x), jnp.asarray(w), wk, mesh, P("data"),
        method="binned_polish")
    assert np.float32(wres.value) == \
        x[o][min(np.searchsorted(cumw, wk, "left"), n - 1)]


def test_local_weighted_wrapper_validates_method():
    mesh = _compat.make_mesh((1,), ("data",))
    with pytest.raises(ValueError):
        distributed.sharded_weighted_order_statistic(
            jnp.zeros((16,), jnp.float32), jnp.ones((16,), jnp.float32),
            4.0, mesh, P("data"), method="florble")


def test_weighted_cp_rounds_and_auto_small_n():
    """The weighted leg supports the cp rounds too (six-partial psums) —
    'auto' resolves there below BINNED_MIN_N, so pin its exactness."""
    mesh = _compat.make_mesh((1,), ("data",))
    rng = np.random.default_rng(9)
    n = 1 << 12
    x = rng.standard_normal(n).astype(np.float32)
    w = rng.integers(1, 4, n).astype(np.float32)
    o = np.argsort(x, kind="stable")
    cumw = np.cumsum(w[o].astype(np.float64))
    wk = float(np.float32(0.5 * w.sum()))
    want = x[o][min(np.searchsorted(cumw, wk, "left"), n - 1)]
    for method in ["cp", "auto"]:
        res = distributed.sharded_weighted_order_statistic(
            jnp.asarray(x), jnp.asarray(w), wk, mesh, P("data"),
            method=method, cap_local=256)
        assert np.float32(res.value) == want, method


@pytest.mark.parametrize("n_dev", [4])
def test_multi_device_polish_subprocess(n_dev):
    env = _subprocess_env()
    out = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "_dist_polish_worker.py"), str(n_dev)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "OK" in out.stdout


def test_single_device_multi_k_deciles():
    """1-device mesh sanity for the one-sweep multi-k front doors (round
    economics live in the subprocess worker)."""
    mesh = _compat.make_mesh((1,), ("data",))
    rng = np.random.default_rng(11)
    n = 1 << 17
    x = rng.standard_normal(n).astype(np.float32)
    qs = [i / 10.0 for i in range(1, 9)]
    ks = np.asarray([int(np.ceil(q * n)) for q in qs], np.int32)
    want = np.partition(x, ks - 1)[ks - 1]
    res = distributed.sharded_multi_order_statistic(
        jnp.asarray(x), jnp.asarray(ks), mesh, P("data"), method="binned")
    np.testing.assert_array_equal(np.asarray(res.value), want)
    res_q = distributed.sharded_quantiles(
        jnp.asarray(x), qs, mesh, P("data"), method="binned_polish")
    np.testing.assert_array_equal(np.asarray(res_q.value), want)


def test_single_device_warm_prior():
    """1-device mesh sanity for the warm-start prior leg (the 1-psum-round
    economics need real sharding and live in the subprocess worker)."""
    mesh = _compat.make_mesh((1,), ("data",))
    rng = np.random.default_rng(13)
    n = 1 << 17
    x = rng.standard_normal(n).astype(np.float32)
    k = n // 2
    want = np.partition(x, k - 1)[k - 1]
    cold = distributed.sharded_order_statistic(
        jnp.asarray(x), k, mesh, P("data"), method="binned")
    warm = distributed.sharded_order_statistic(
        jnp.asarray(x), k, mesh, P("data"), method="binned", prior=cold)
    assert np.float32(cold.value) == want
    assert np.float32(warm.value) == want
    assert int(warm.iters) <= int(cold.iters)
    # cp rounds accept the prior too
    small = rng.standard_normal(1 << 12).astype(np.float32)
    ksm = 1 << 11
    csm = distributed.sharded_order_statistic(
        jnp.asarray(small), ksm, mesh, P("data"), method="cp")
    wsm = distributed.sharded_order_statistic(
        jnp.asarray(small), ksm, mesh, P("data"), method="cp", prior=csm)
    assert np.float32(wsm.value) == np.float32(csm.value) == \
        np.partition(small, ksm - 1)[ksm - 1]
    assert int(wsm.iters) <= int(csm.iters)


@pytest.mark.parametrize("n_dev", [4])
def test_multi_device_warm_one_round_subprocess(n_dev):
    """Warm distributed re-selection at n = 1M: the carried bracket shrinks
    round 1's psum'd slot vector so ONE round resolves it, both measures;
    stale/adversarial priors never affect the value (_dist_warm_worker)."""
    env = _subprocess_env()
    out = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "_dist_warm_worker.py"), str(n_dev)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "OK" in out.stdout


@pytest.mark.parametrize("n_dev", [4])
def test_multi_device_multi_k_one_round_subprocess(n_dev):
    """K = 8 deciles at n = 1M: ONE psum of the (K, nbins+2) slot matrix
    resolves the whole vector, both measures (see _dist_multi_k_worker)."""
    env = _subprocess_env()
    out = subprocess.run(
        [sys.executable,
         os.path.join(ROOT, "tests", "_dist_multi_k_worker.py"), str(n_dev)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "OK" in out.stdout
