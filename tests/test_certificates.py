"""Certificate-invariant regression tests (the PR-2 fail-safe contract).

The binned engine may mint EXACT_HIT only off a MEASURED invariant:

    count(x <= value) >= k   with   count(x < value) < k        (counts)
    mass(x <= value) >= wk   with   mass(x < value) < wk        (masses)

These tests lock in the contract on its adversarial inputs — seeded
tie-storms (certificates race the cap rule) and ulp-collapsed brackets
(the collapse certificate is the only exit) — by recounting the invariant
at every EXACT_HIT the engine reports, and by driving the shared
narrowing-decision core (``binned_descent_step``) and the weighted loop
directly with inconsistent count/mass vectors, which must STALL, never
certify.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import selection
from repro.core.objective import FnEvaluator

jax.config.update("jax_platform_name", "cpu")


def assert_exact_hits_verified(x, k, res):
    """Any EXACT_HIT must satisfy the recounted count invariant."""
    v = np.float32(res.value)
    if int(res.status) == selection.EXACT_HIT:
        n_lt = int((x < v).sum())
        n_le = int((x <= v).sum())
        assert n_lt < k <= n_le, (k, v, n_lt, n_le)


def assert_weighted_exact_hits_verified(x, w, wk, res):
    """Any weighted EXACT_HIT must satisfy the recounted mass invariant."""
    v = np.float32(res.value)
    if int(res.status) == selection.EXACT_HIT:
        w_lt = float(w[x < v].sum())
        w_le = float(w[x <= v].sum())
        assert w_lt < wk <= w_le, (wk, v, w_lt, w_le)


# ---------------------------------------------------------------------------
# seeded tie-storms: certificates must survive duplicate avalanches
# ---------------------------------------------------------------------------


def _tie_storms(rng, n=6000):
    """Adversarial duplicate patterns: storm at the answer, around it,
    constant arrays, two-level splits, storm at the extremes."""
    half = n // 2
    return [
        rng.integers(0, 3, n).astype(np.float32),            # 3 levels
        np.full(n, 2.5, np.float32),                         # constant
        np.concatenate([np.full(half, 1.0), np.full(n - half, 2.0)]
                       ).astype(np.float32),                 # two levels
        np.concatenate([rng.standard_normal(n - half),
                        np.full(half, 0.125)]).astype(np.float32),
        np.concatenate([np.full(n - 2, -1e9), [0.0], [1e9]]
                       ).astype(np.float32),                 # extreme storm
    ]


@pytest.mark.parametrize("nbins", [4, 128])
def test_tie_storm_exact_hits_verified(nbins):
    rng = np.random.default_rng(100)
    for x in _tie_storms(rng):
        rng.shuffle(x)
        n = x.size
        for k in [1, 2, n // 3, (n + 1) // 2, n - 1, n]:
            res = selection.order_statistic(
                jnp.asarray(x), k, method="binned", cap=4, nbins=nbins)
            np.testing.assert_equal(np.float32(res.value),
                                    np.partition(x, k - 1)[k - 1])
            assert_exact_hits_verified(x, k, res)


def test_weighted_tie_storm_exact_hits_verified():
    rng = np.random.default_rng(101)
    for x in _tie_storms(rng):
        n = x.size
        w = rng.integers(0, 3, n).astype(np.float32)
        w[0] = 1.0
        W = float(w.sum())
        for frac in [0.001, 0.33, 0.5, 0.999]:
            wk = float(np.float32(max(frac * W, 0.5)))
            res = selection.weighted_order_statistic(
                jnp.asarray(x), jnp.asarray(w), wk, method="binned",
                cap=4)
            assert int(res.status) != selection.NOT_CONVERGED
            assert_weighted_exact_hits_verified(x, w, wk, res)


# ---------------------------------------------------------------------------
# ulp-collapsed brackets: the collapse certificate under a magnifier
# ---------------------------------------------------------------------------


def _ulp_cluster(rng, base, n_levels, n):
    """Values spanning only a few ulps around ``base`` (with duplicates):
    forces the bracket to collapse to single representable values."""
    levels = [base]
    for _ in range(n_levels - 1):
        levels.append(np.nextafter(levels[-1], np.float32(np.inf),
                                   dtype=np.float32))
    return np.asarray(levels, np.float32)[rng.integers(0, n_levels, n)]


@pytest.mark.parametrize("base", [1.0, -255.1234, 3e38])
def test_ulp_collapsed_bracket_exact_hits_verified(base):
    rng = np.random.default_rng(102)
    x = _ulp_cluster(rng, np.float32(base), 4, 5000)
    n = x.size
    for k in [1, n // 4, (n + 1) // 2, n]:
        res = selection.order_statistic(jnp.asarray(x), k, method="binned",
                                        cap=2)
        np.testing.assert_equal(np.float32(res.value),
                                np.partition(x, k - 1)[k - 1])
        assert_exact_hits_verified(x, k, res)


def test_ulp_cluster_at_ftz_floor_fails_safe():
    """At denormal-adjacent magnitudes (1.2e-38) the bin width flushes to
    zero (FTZ), so the bracket CANNOT narrow below a few ulps: with an
    undersized cap the engine must stall into an honest non-exact status —
    never a lying EXACT_HIT — and with the default cap the survivor
    compaction must still resolve the answer exactly."""
    rng = np.random.default_rng(102)
    x = _ulp_cluster(rng, np.float32(1.2e-38), 4, 5000)
    n = x.size
    for k in [1, n // 4, (n + 1) // 2, n]:
        want = np.partition(x, k - 1)[k - 1]
        # default cap: the stalled bracket's <= 4-ulp survivor set fits the
        # compaction buffer, so the answer is exact
        res = selection.order_statistic(jnp.asarray(x), k, method="binned")
        np.testing.assert_equal(np.float32(res.value), want)
        assert_exact_hits_verified(x, k, res)
        # cap=2: thousands of duplicate survivors cannot compact and the
        # tie fallback only reaches one distinct value up — the fail-safe
        # contract is an honest status, not a minted certificate
        res = selection.order_statistic(jnp.asarray(x), k, method="binned",
                                        cap=2)
        assert_exact_hits_verified(x, k, res)
        if int(res.status) != selection.NOT_CONVERGED:
            np.testing.assert_equal(np.float32(res.value), want)


def test_weighted_ulp_collapsed_bracket():
    rng = np.random.default_rng(103)
    x = _ulp_cluster(rng, np.float32(7.25), 3, 4000)
    w = rng.integers(0, 4, x.size).astype(np.float32)
    w[0] = 1.0
    W = float(w.sum())
    for frac in [0.1, 0.5, 0.9]:
        wk = float(np.float32(frac * W))
        res = selection.weighted_order_statistic(
            jnp.asarray(x), jnp.asarray(w), wk, method="binned", cap=2)
        assert int(res.status) != selection.NOT_CONVERGED
        assert_weighted_exact_hits_verified(x, w, wk, res)
        # differential against the sorted-cumsum oracle (integer weights:
        # masses exactly summable)
        o = np.argsort(x, kind="stable")
        c = np.cumsum(w[o].astype(np.float64))
        want = x[o][min(np.searchsorted(c, wk, "left"), x.size - 1)]
        np.testing.assert_equal(np.float32(res.value), want)


# ---------------------------------------------------------------------------
# violated invariants must stall, never certify
# ---------------------------------------------------------------------------


def test_descent_step_fails_safe_on_short_counts():
    """cum[-1] < k (counts inconsistent with the bracket invariant):
    argmax-of-all-False must not masquerade as hit_lo / exact."""
    from repro.kernels.ref import bin_edges

    cum = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    yL = jnp.asarray([0.0], jnp.float32)
    yR = jnp.asarray([1.0], jnp.float32)
    kk = jnp.asarray([10], jnp.int32)
    *_, hit_lo, exact, stall = selection.binned_descent_step(
        cum, bin_edges(yL, yR, 3), yL, yR, kk)
    assert not bool(exact[0]) and not bool(hit_lo[0]) and bool(stall[0])


def test_descent_step_fails_safe_on_short_mass():
    """The weighted regime drives the SAME core with float masses: a mass
    vector that never reaches wk must stall identically — even when the
    bracket is ulp-collapsed (the collapse certificate must stay gated on
    the mass invariant)."""
    from repro.kernels.ref import bin_edges

    yL = jnp.asarray([1.0], jnp.float32)
    yR = jnp.asarray([float(np.nextafter(np.float32(1.0),
                                         np.float32(np.inf)))], jnp.float32)
    cumw = jnp.asarray([[0.25, 0.5, 0.5, 0.75]], jnp.float32)
    wk = jnp.asarray([2.0], jnp.float32)
    *_, hit_lo, exact, stall = selection.binned_descent_step(
        cumw, bin_edges(yL, yR, 3), yL, yR, wk)
    assert not bool(exact[0]) and not bool(hit_lo[0]) and bool(stall[0])
    # sanity: with a CONSISTENT mass vector the collapse certifies
    cumw_ok = jnp.asarray([[0.25, 0.5, 0.5, 2.5]], jnp.float32)
    *_, hit_lo, exact, stall = selection.binned_descent_step(
        cumw_ok, bin_edges(yL, yR, 3), yL, yR, wk)
    assert bool(exact[0]) and not bool(stall[0])


def test_weighted_late_hit_lo_demoted_to_stall():
    """A mass vector claiming mass(x <= yL) >= wk AFTER the first sweep can
    only be an inexact-mass ulp-flip (the invariant forbids it in exact
    arithmetic): the weighted binned loop must freeze the row (fail safe),
    never mint the non-element edge value as EXACT_HIT."""
    n = 64
    x = jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)
    w = jnp.ones((n,), jnp.float32)
    W = jnp.sum(w)
    wk = jnp.asarray([0.5 * n], jnp.float32)
    xmin, xmax = x[0], x[-1]

    def init_stats():
        one = lambda v: jnp.reshape(v, (1,))
        return one(xmin), one(xmax), one(jnp.mean(x))

    def lying_histogram(edges):
        from repro.kernels import ref

        cnt, wcnt, wsum = ref.wcp_histogram_ref(x, w, edges[0])
        honest = (cnt[None, :], wcnt[None, :], wsum[None, :])
        # sweep 1 sees the full bracket (edges[0] == xmin); afterwards lie:
        # all mass at or below the bracket's left end
        first_sweep = edges[0, 0] == xmin
        lie_wcnt = jnp.zeros_like(honest[1]).at[:, 0].set(W)
        return (honest[0],
                jnp.where(first_sweep, honest[1], lie_wcnt),
                honest[2])

    ev = FnEvaluator(
        partials=None, n=jnp.asarray(n, jnp.int32), k=wk,
        init_stats=init_stats, histogram=lying_histogram,
        weights_total=jnp.reshape(W, ()))
    # the weighted leg of the ONE binned loop (ev.weighted selects it)
    s, _, _ = selection.binned_loop_batched(ev, nbins=8, maxit=8, cap=1)
    # the lie arrives on sweep 2: the loop must stall the row unfinished
    # rather than certify yL (a non-element bin edge) as the answer
    assert not bool(s.found_exact[0])
    assert int(s.iters[0]) == 2  # sweep 1 honest narrowing + sweep 2 stall
    assert bool(s.yL[0] > xmin) and bool(s.yR[0] < xmax)  # sweep-1 bracket


def test_weighted_extreme_shortcuts_gated_on_seed_bracket():
    """The weighted at_min/at_max finalize shortcuts re-measure masses with
    a different summation order than the loop: a rounding flip near wk
    (cLw >= wk with the bracket far from the minimum) must NOT override the
    answer with xmin as EXACT_HIT — it falls through to the sorted-prefix
    chain.  Only a bracket still AT the extreme may certify through them."""
    from repro.core.selection import BatchState, _assemble_answers

    def state(yL, yR):
        one = lambda v: jnp.asarray([v], jnp.float32)
        return BatchState(
            yL=one(yL), fL=one(0), gL=one(0), yR=one(yR), fR=one(0),
            gR=one(0), cleL=jnp.asarray([1], jnp.int32),
            cleR=jnp.asarray([4], jnp.int32), t_exact=one(jnp.nan),
            found_exact=jnp.asarray([False]),
            iters=jnp.asarray([1], jnp.int32),
            it=jnp.asarray(1, jnp.int32), tp=one(0), fp=one(0))

    wkk = jnp.asarray([5.0], jnp.float32)
    zs = jnp.asarray([[2.0, 3.0]], jnp.float32)
    zws = jnp.asarray([[1.0, 1.0]], jnp.float32)
    common = dict(cap=2, zs=zs, zws=zws, n_in=jnp.asarray([2], jnp.int32),
                  vnext=jnp.asarray([2.0], jnp.float32),
                  m_le_v=jnp.asarray([6.0], jnp.float32),
                  xmin=jnp.asarray([0.0], jnp.float32),
                  xmax=jnp.asarray([9.0], jnp.float32))
    # cLm >= wk (flip) but yL moved off xmin: sorted-prefix answer, not xmin
    res = _assemble_answers(
        wkk, state(1.5, 3.0), cLm=jnp.asarray([5.0], jnp.float32),
        m_lt_max=jnp.asarray([10.0], jnp.float32), **common)
    assert float(res.value[0]) == 2.0
    assert int(res.status[0]) == selection.HYBRID_SORT
    # m_lt_max < wk (flip) but yR moved off xmax: same fail-safe
    res = _assemble_answers(
        wkk, state(1.5, 3.0), cLm=jnp.asarray([4.0], jnp.float32),
        m_lt_max=jnp.asarray([4.5], jnp.float32), **common)
    assert float(res.value[0]) == 2.0
    assert int(res.status[0]) == selection.HYBRID_SORT
    # bracket still AT the extreme: the shortcut may certify
    res = _assemble_answers(
        wkk, state(0.0, 9.0), cLm=jnp.asarray([5.0], jnp.float32),
        m_lt_max=jnp.asarray([10.0], jnp.float32), **common)
    assert float(res.value[0]) == 0.0
    assert int(res.status[0]) == selection.EXACT_HIT


def test_binned_never_mints_unverified_exact_hit_random_sweep():
    """Randomized spot-sweep across sizes/caps/nbins: every EXACT_HIT the
    binned engine reports (weighted or not) survives the recount."""
    rng = np.random.default_rng(104)
    for trial in range(20):
        n = int(rng.integers(10, 3000))
        x = (rng.integers(-50, 50, n)).astype(np.float32) * 0.25
        k = int(rng.integers(1, n + 1))
        cap = int(rng.integers(1, 32))
        nbins = int(rng.choice([2, 8, 128]))
        res = selection.order_statistic(jnp.asarray(x), k, method="binned",
                                        cap=cap, nbins=nbins)
        np.testing.assert_equal(np.float32(res.value),
                                np.partition(x, k - 1)[k - 1])
        assert_exact_hits_verified(x, k, res)

        w = rng.integers(0, 3, n).astype(np.float32)
        w[0] = 1.0
        wk = float(np.float32(max(float(w.sum()) * rng.uniform(), 0.5)))
        wres = selection.weighted_order_statistic(
            jnp.asarray(x), jnp.asarray(w), wk, method="binned", cap=cap,
            nbins=nbins)
        assert_weighted_exact_hits_verified(x, w, wk, wres)
        assert int(wres.status) != selection.NOT_CONVERGED
