"""Subprocess worker for multi-device distributed-selection tests.

Run as:  python tests/_dist_worker.py <n_devices>
Sets XLA_FLAGS *before* importing jax, builds a host-device mesh and checks
the distributed primitives against numpy oracles.  Exits nonzero on failure.
"""
import os
import sys

n_dev = int(sys.argv[1]) if len(sys.argv) > 1 else 8
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={n_dev} "
    + os.environ.get("XLA_FLAGS", "")
)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import _compat, distributed  # noqa: E402

assert jax.device_count() == n_dev, jax.devices()


def check(cond, msg):
    if not cond:
        print("FAIL:", msg)
        sys.exit(1)


def main():
    mesh = _compat.make_mesh((n_dev,), ("data",))
    rng = np.random.default_rng(0)

    # --- sharded_order_statistic vs np.partition, incl. outliers/ties ---
    for trial, make in enumerate([
        lambda: rng.standard_normal(1 << 16),
        lambda: np.concatenate([rng.standard_normal(1 << 15),
                                np.full(1 << 15, 0.25)]),
        lambda: np.concatenate([rng.standard_normal((1 << 16) - 1), [1e9]]),
        lambda: rng.integers(0, 5, 1 << 16).astype(np.float64),
    ]):
        x = make().astype(np.float32)
        rng.shuffle(x)
        n = x.size
        # default method is the binned descent; trial 0 also cross-checks
        # the cutting-plane loop explicitly
        methods = ["binned", "cp"] if trial == 0 else ["binned"]
        for k in [1, n // 4, (n + 1) // 2, n - 3, n]:
            for method in methods:
                res = distributed.sharded_order_statistic(
                    jnp.asarray(x), k, mesh, P("data"), cap_local=1024,
                    method=method)
                want = np.partition(x, k - 1)[k - 1]
                check(np.float32(res.value) == want,
                      f"trial {trial} k={k} {method}: {res.value} != {want}")

    # result must be identical on every shard (replicated out_spec) — and
    # the round count small (binned descent: ~2-3 histogram psums where the
    # paper's CP loop takes < 30)
    res = distributed.sharded_median(
        jnp.asarray(rng.standard_normal(1 << 20).astype(np.float32)),
        mesh, P("data"))
    check(int(res.iters) <= 5, f"too many rounds: {res.iters}")

    # --- weighted sharded selection: psum'd mass vectors + pair gather ---
    x = rng.standard_normal(1 << 16).astype(np.float32)
    w = rng.integers(0, 5, 1 << 16).astype(np.float32)
    w[0] = 1.0
    o = np.argsort(x, kind="stable")
    cumw = np.cumsum(w[o].astype(np.float64))
    for frac in [0.001, 0.25, 0.5, 0.999]:
        wk = float(np.float32(max(frac * w.sum(), 0.5)))
        res = distributed.sharded_weighted_order_statistic(
            jnp.asarray(x), jnp.asarray(w), wk, mesh, P("data"),
            cap_local=1024)
        want = x[o][min(np.searchsorted(cumw, wk, "left"), x.size - 1)]
        check(np.float32(res.value) == want,
              f"weighted frac={frac}: {res.value} != {want}")
        check(int(res.iters) <= 5,
              f"weighted frac={frac}: too many rounds {res.iters}")
    # uniform weights reproduce the unweighted answer exactly
    n = x.size
    k = (n + 1) // 2
    res_w = distributed.sharded_weighted_order_statistic(
        jnp.asarray(x), jnp.ones_like(jnp.asarray(x)), float(k), mesh,
        P("data"), cap_local=1024)
    check(np.float32(res_w.value) == np.partition(x, k - 1)[k - 1],
          "weighted uniform != unweighted median")

    # --- median/order-stat across a mesh axis (coordinate-wise) ---
    vals = rng.standard_normal((n_dev, 4, 33)).astype(np.float32)
    # inject ties across replicas
    vals[:, 1, :] = 0.5
    vals[: n_dev // 2, 2, :] = vals[n_dev // 2:, 2, :]
    arr = jnp.asarray(vals)

    for method in ["gather", "cp", "binned"]:
        for k in [1, (n_dev + 1) // 2, n_dev]:
            def run(v):
                return distributed.order_statistic_across_axis(
                    v, k, "data", method=method)
            got = _compat.shard_map(
                run, mesh=mesh,
                in_specs=P("data"), out_specs=P("data"), check=False,
            )(arr)
            got0 = np.asarray(got)[0]  # replicated along data
            want = np.sort(vals, axis=0)[k - 1]
            check(np.allclose(got0, want),
                  f"across-axis method={method} k={k} mismatch: "
                  f"{got0.ravel()[:4]} vs {want.ravel()[:4]}")

    # auto resolves statically by replica count: force the binned branch by
    # dropping the gather threshold below n_dev
    def run_auto(v):
        return distributed.order_statistic_across_axis(
            v, (n_dev + 1) // 2, "data", method="auto",
            gather_threshold=n_dev - 1)
    got = _compat.shard_map(run_auto, mesh=mesh, in_specs=P("data"),
                            out_specs=P("data"), check=False)(arr)
    want = np.sort(vals, axis=0)[(n_dev + 1) // 2 - 1]
    check(np.allclose(np.asarray(got)[0], want), "across-axis auto mismatch")

    print("OK")


if __name__ == "__main__":
    main()
