"""Optimizer / pipeline / checkpoint / train-step / loop integration tests."""
import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, local_plan
from repro.configs.base import ShapeConfig
from repro.data import SyntheticPipeline, batch_for_shape
from repro.models import model
from repro.optim import AdamW, Adafactor, int8_compress, int8_decompress
from repro.train import TrainState, fit, make_serve_step, make_train_step

jax.config.update("jax_platform_name", "cpu")

SMALL = ShapeConfig("small", seq_len=32, global_batch=2, kind="train")


def small_setup(arch="gemma2-2b", optimizer=None):
    cfg = get_config(arch).reduced()
    plan = local_plan()
    params = model.init(jax.random.PRNGKey(0), cfg)
    opt = optimizer or AdamW(lr=1e-2)
    state = TrainState(params=params, opt=opt.init(params),
                       step=jnp.zeros((), jnp.int32))
    return cfg, plan, opt, state


def test_adamw_reduces_loss():
    cfg, plan, opt, state = small_setup()
    step = make_train_step(cfg, plan, opt, clip="quantile")
    batch = batch_for_shape(cfg, SMALL, seed=0, step=0)  # fixed batch
    losses = []
    jstep = jax.jit(step)
    for _ in range(8):
        state, m = jstep(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_adafactor_runs():
    cfg, plan, opt, state = small_setup(optimizer=Adafactor(lr=1e-2))
    step = make_train_step(cfg, plan, opt, clip="none")
    batch = batch_for_shape(cfg, SMALL, seed=0, step=0)  # fixed batch
    jstep = jax.jit(step)
    l0 = None
    for _ in range(6):
        state, m = jstep(state, batch)
        l0 = l0 or float(m["loss"])
    assert float(m["loss"]) < l0


def test_quantile_clip_bounds_gradients():
    cfg, plan, opt, state = small_setup()
    step_q = make_train_step(cfg, plan, opt, clip="quantile", clip_q=0.9)
    pipe = SyntheticPipeline(cfg, SMALL, seed=0)
    _, m = jax.jit(step_q)(state, next(pipe))
    pipe.close()
    assert float(m["clip_thr"]) > 0


def test_pipeline_deterministic_resume():
    cfg = get_config("phi3-mini-3.8b").reduced()
    p1 = SyntheticPipeline(cfg, SMALL, seed=7)
    b0, b1, b2 = next(p1), next(p1), next(p1)
    p1.close()
    p2 = SyntheticPipeline(cfg, SMALL, seed=7, start_step=2)
    b2r = next(p2)
    p2.close()
    np.testing.assert_array_equal(b2["tokens"], b2r["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    cfg, plan, opt, state = small_setup()
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    mgr.save(3, state, extra={"pipeline": {"step": 3}})
    mgr.save(6, state)
    mgr.save(9, state)
    assert mgr.steps() == [6, 9]  # keep=2
    restored, manifest = mgr.restore(9, state)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        state.params, restored.params)
    # no .tmp directories left behind
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_loop_checkpoint_restart(tmp_path):
    cfg, plan, opt, state = small_setup()
    step = make_train_step(cfg, plan, opt, clip="none")
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    pipe = SyntheticPipeline(cfg, SMALL, seed=0)
    out = fit(train_step=step, state=state, pipeline=pipe, steps=6,
              ckpt=mgr, ckpt_every=3, log_every=100, log_fn=lambda s: None)
    pipe.close()
    assert mgr.latest_step() == 6
    # resume adds more steps from the checkpoint
    state2 = TrainState(params=out["state"].params, opt=out["state"].opt,
                        step=jnp.zeros((), jnp.int32))
    pipe2 = SyntheticPipeline(cfg, SMALL, seed=0, start_step=6)
    fit(train_step=step, state=state2, pipeline=pipe2, steps=8,
        ckpt=mgr, ckpt_every=4, log_every=100, log_fn=lambda s: None)
    pipe2.close()
    assert mgr.latest_step() == 8


def test_serve_step_greedy():
    cfg, plan, opt, state = small_setup("phi3-mini-3.8b")
    serve = jax.jit(make_serve_step(cfg, plan))
    cache = model.init_cache(cfg, 2, max_seq=16, plan=plan,
                             dtype=jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    for i in range(4):
        tok, logits, cache = serve(state.params, cache, tok,
                                   jnp.asarray(i, jnp.int32))
    assert tok.shape == (2, 1)
    assert int(tok.max()) < cfg.vocab


def test_int8_compression_roundtrip():
    rng = np.random.default_rng(0)
    tree = {"a": jnp.asarray(rng.standard_normal((64, 8)).astype(np.float32))}
    c, _ = int8_compress(jax.random.PRNGKey(0), tree)
    d = int8_decompress(c)
    err = np.abs(np.asarray(d["a"]) - np.asarray(tree["a"])).max()
    scale = float(c["a"]["scale"])
    assert err <= scale  # quantization error bounded by one step


def test_fused_loss_matches_plain():
    """lm_loss_fused == unembed + lm_loss (same CE, no logits buffer)."""
    cfg = get_config("gemma2-2b").reduced()  # exercises final_softcap too
    plan = local_plan()
    params = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 33)).astype(np.int32))
    batch = {"tokens": toks}
    hidden, _ = model.forward(params, batch, cfg, plan, mode="train",
                              return_hidden=True)
    logits, _ = model.forward(params, batch, cfg, plan, mode="prefill")
    l1, m1 = model.lm_loss_fused(hidden[:, :-1], params["embed"],
                                 toks[:, 1:], jnp.ones_like(toks[:, 1:]),
                                 cfg, plan)
    l2, m2 = model.lm_loss(logits[:, :-1], toks[:, 1:],
                           jnp.ones_like(toks[:, 1:]))
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-4)


def test_grad_accumulation_matches_full_batch():
    cfg, plan, opt, state = small_setup("phi3-mini-3.8b")
    batch = batch_for_shape(cfg, SMALL, seed=0, step=0)
    s1 = make_train_step(cfg, plan, opt, clip="none", accum_steps=1)
    s2 = make_train_step(cfg, plan, opt, clip="none", accum_steps=2)
    st1, m1 = jax.jit(s1)(state, batch)
    state2 = TrainState(params=state.params, opt=opt.init(state.params),
                        step=jnp.zeros((), jnp.int32))
    st2, m2 = jax.jit(s2)(state2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    a = jax.tree.leaves(st1.params)[0]
    b = jax.tree.leaves(st2.params)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-5)
