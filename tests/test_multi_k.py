"""One-sweep multi-k binned selection: differential + structural tests.

The tentpole claim: K order statistics of one array share every histogram
data pass — per-k bracket state, ONE ``(K, nbins+2)`` slot-matrix sweep per
round, no ``(K, n)`` intermediate.  These tests pin

* bit-exactness of ``multi_order_statistic`` / ``quantiles`` under
  'binned' / 'binned_polish' against per-k ``np.partition`` across the
  adversarial fp regimes (dup-heavy, denormal-scale, ulp-wide spans,
  tie-storms), on both measure legs;
* the structural no-(K, n) guarantee via a jaxpr shape walk;
* the sweep-sharing economy: K=16 deciles take no more histogram sweeps
  than ~2x a single binned median;
* the ``ranks_from_quantiles`` f64 rank derivation (regression: the traced
  f32 product mis-lands q = 0.999999 at n = 2^25);
* the segmented (per-leaf) engine and the per-leaf clip rewiring.

Deterministic on purpose — the hypothesis-driven generalization lives in
``test_property_multi_k.py`` (skipped where hypothesis is absent).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import robust, selection
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# adversarial regimes (all f32, all finite)
# ---------------------------------------------------------------------------


def _regimes():
    rng = np.random.default_rng(42)
    n = 6000
    out = {}
    out["normal"] = rng.normal(size=n).astype(np.float32)
    # dup-heavy: 8 distinct values
    out["dup_heavy"] = rng.choice(
        np.asarray([-3.0, -1.5, 0.0, 1e-3, 0.25, 1.0, 7.5, 100.0],
                   np.float32), size=n)
    # denormal-scale: values straddling the f32 subnormal range
    out["denormal"] = (rng.normal(size=n).astype(np.float32)
                       * np.float32(1e-41))
    # ulp-wide: exponents spanning the whole f32 range
    out["ulp_wide"] = (rng.normal(size=n).astype(np.float32)
                       * np.exp2(rng.integers(-120, 120, size=n))
                       .astype(np.float32))
    # tie-storm: half the mass exactly AT the median-ish value
    ts = rng.normal(size=n).astype(np.float32)
    ts[: n // 2] = np.float32(0.5)
    out["tie_storm"] = rng.permutation(ts)
    return out


KS_FRACS = (0.001, 0.1, 0.25, 0.5, 0.5, 0.9, 0.999)  # dup k exercises ties


def _ks_for(n):
    return np.clip(np.ceil(np.asarray(KS_FRACS) * n), 1, n).astype(np.int32)


def _flush(a):
    """DAZ-equivalence: XLA:CPU runs with FTZ/DAZ, so every subnormal sits
    in the zero tie-class under the platform's comparison semantics (the
    engine's documented contract — see order_statistic_across_axis).  Both
    sides of a differential flush before comparing; normal-range values
    pass through bit-identically."""
    a = np.asarray(a)
    return np.where(np.abs(a) < np.finfo(np.float32).tiny,
                    np.float32(0.0), a)


@pytest.mark.parametrize("regime", sorted(_regimes()))
@pytest.mark.parametrize("method", ["binned", "binned_polish"])
def test_multi_k_counting_matches_partition(regime, method):
    x = _regimes()[regime]
    n = x.size
    ks = _ks_for(n)
    xs = np.sort(x)
    expected = xs[ks - 1]
    res = selection.multi_order_statistic(
        jnp.asarray(x), jnp.asarray(ks), method=method, backend="jnp")
    np.testing.assert_array_equal(_flush(res.value), _flush(expected))


@pytest.mark.parametrize("regime", sorted(_regimes()))
@pytest.mark.parametrize("method", ["binned", "binned_polish"])
def test_multi_k_weighted_matches_sorted_cumsum(regime, method):
    x = _regimes()[regime]
    rng = np.random.default_rng(7)
    w = rng.integers(1, 6, size=x.size).astype(np.float32)
    order = np.argsort(x, kind="stable")
    cw = np.cumsum(w[order].astype(np.float64))
    W = np.float32(cw[-1])
    wks = (np.asarray(KS_FRACS, np.float64) * float(W)).astype(np.float32)
    expected = np.asarray(
        [x[order][int(np.argmax(cw >= wk))] for wk in wks], x.dtype)
    res = selection.weighted_multi_order_statistic(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(wks), method=method,
        backend="jnp")
    np.testing.assert_array_equal(_flush(res.value), _flush(expected))


@pytest.mark.parametrize("method", ["binned", "binned_polish"])
def test_multi_k_impls_bit_identical(method):
    """searchsorted vs verified-arithmetic slotting: same bits, multi-k."""
    x = _regimes()["ulp_wide"]
    ks = _ks_for(x.size)
    r1 = selection.multi_order_statistic(
        jnp.asarray(x), jnp.asarray(ks), method=method, backend="jnp",
        binned_impl="arithmetic")
    r2 = selection.multi_order_statistic(
        jnp.asarray(x), jnp.asarray(ks), method=method, backend="jnp",
        binned_impl="searchsorted")
    np.testing.assert_array_equal(np.asarray(r1.value),
                                  np.asarray(r2.value))


# ---------------------------------------------------------------------------
# structural guarantees
# ---------------------------------------------------------------------------


def _jaxpr_shapes(jaxpr, acc):
    """All intermediate shapes, recursing into pjit/scan/cond sub-jaxprs."""
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            if hasattr(v.aval, "shape"):
                acc.add(tuple(v.aval.shape))
        for val in eqn.params.values():
            sub = getattr(val, "jaxpr", None)
            if sub is not None:
                _jaxpr_shapes(sub, acc)
    return acc


def test_multi_k_binned_never_materializes_k_by_n():
    """The one-sweep histogram core reads x chunk-wise for all K ladders;
    the largest traced intermediate must stay well under (K, n)."""
    n, k = 1 << 17, 8
    ks = jnp.asarray(np.linspace(1, n, k).astype(np.int32))
    x = jnp.zeros((n,), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda a: selection.multi_order_statistic(
            a, ks, method="binned", backend="jnp")
    )(x)
    shapes = _jaxpr_shapes(jaxpr.jaxpr, set())
    assert (k, n) not in shapes, "the (K, n) broadcast is back"
    biggest = max((int(np.prod(s)) for s in shapes), default=0)
    assert 0 < biggest < k * n, (biggest, sorted(shapes)[-5:])


def test_multi_k_sweep_sharing_economy():
    """K=16 quantiles narrow from the SAME sweeps: the shared-x histogram
    loop takes at most 2x the sweeps of a single binned median (vs ~Kx for
    independent solves)."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=1 << 17).astype(np.float32))
    qs = (np.arange(1, 17) / 17.0).tolist()
    res_k1 = selection.median(x, method="binned", backend="jnp")
    res_k16 = selection.quantiles(x, qs, method="binned", backend="jnp")
    s1 = int(np.asarray(res_k1.iters))
    s16 = int(np.asarray(res_k16.iters).max())
    assert s16 <= max(2 * s1, s1 + 1), (s1, s16)


def test_fused_histogram_multi_want_sums_gating():
    """want_sums=False must drop the per-slot sums on the multi paths."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=4096).astype(np.float32))
    w = jnp.asarray(rng.uniform(1, 2, size=4096).astype(np.float32))
    edges = jnp.stack([jnp.linspace(-3, 3, 9), jnp.linspace(-1, 1, 9)])
    edges = edges.astype(jnp.float32)
    for backend in ("jnp", "pallas_interpret"):
        cnt, bsum = kops.fused_histogram_multi(x, edges, backend=backend,
                                               want_sums=False)
        assert bsum is None
        cnt2, bsum2 = kops.fused_histogram_multi(x, edges, backend=backend,
                                                 want_sums=True)
        np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt2))
        assert bsum2 is not None
        c, m, s = kops.fused_weighted_histogram_multi(
            x, w, edges, backend=backend, want_sums=False)
        assert s is None


# ---------------------------------------------------------------------------
# rank derivation (regression: f32 ceil at n = 2^25)
# ---------------------------------------------------------------------------


def test_ranks_from_quantiles_f64_regression():
    n = 1 << 25
    q = 0.999999
    exact = int(np.ceil(np.float64(q) * np.float64(n)))
    wrong = int(np.ceil(np.float32(q) * np.float32(n)))
    assert wrong != exact  # the bug this guards against is real at 2^25
    assert int(selection.ranks_from_quantiles(q, n)) == exact
    ks = selection.ranks_from_quantiles([0.0, q, 1.0], n)
    np.testing.assert_array_equal(np.asarray(ks),
                                  np.asarray([1, exact, n], np.int32))


def test_quantiles_high_q_end_to_end_2_25():
    """End-to-end: at n = 2^25 the q = 0.999999 quantile must hit the
    exact rank (the traced-f32 derivation lands one element low)."""
    n = 1 << 25
    q = 0.999999
    k = int(np.ceil(np.float64(q) * np.float64(n)))
    # zeros except a distinct ramp at the top ranks: ranks near k map to
    # distinct values, so an off-by-one rank is a visible value error
    m = 64
    x = np.zeros(n, np.float32)
    x[-m:] = np.arange(1, m + 1, dtype=np.float32)
    expected = np.float32(k - (n - m))  # rank k lands inside the ramp
    rng = np.random.default_rng(0)
    x = rng.permutation(x)
    res = selection.quantiles(jnp.asarray(x), [q], method="binned",
                              backend="jnp")
    np.testing.assert_array_equal(np.asarray(res.value),
                                  np.asarray([expected]))


def test_traced_quantile_still_works():
    """Traced qs fall back to the on-device derivation (no host pull)."""
    x = jnp.asarray(np.arange(100, dtype=np.float32))

    @jax.jit
    def f(q):
        return selection.quantile(x, q, method="cp").value

    assert float(f(jnp.float32(0.5))) == 49.0


# ---------------------------------------------------------------------------
# segmented (per-leaf) engine
# ---------------------------------------------------------------------------


def _segment_case():
    rng = np.random.default_rng(1)
    sizes = [1, 37, 4096, 513, 1000]
    parts = [rng.normal(size=s).astype(np.float32)
             * np.float32(10.0 ** float(rng.integers(-3, 3)))
             for s in sizes]
    x = np.concatenate(parts)
    seg = np.concatenate([np.full(s, i, np.int32)
                          for i, s in enumerate(sizes)])
    p = rng.permutation(x.size)
    return x[p], seg[p], sizes


@pytest.mark.parametrize("method", ["binned", "binned_polish", "cp", "sort"])
def test_segmented_quantiles_exact(method):
    x, seg, sizes = _segment_case()
    q = 0.9
    res = selection.segmented_quantiles(
        jnp.asarray(x), jnp.asarray(seg), q, sizes, method=method)
    for i, s in enumerate(sizes):
        xi = np.sort(x[seg == i])
        k = int(np.clip(np.ceil(q * s), 1, s))
        assert np.asarray(res.value)[i] == xi[k - 1], (i, method)


def test_segmented_distinct_ks():
    x, seg, sizes = _segment_case()
    ks = np.asarray([1, 37, 2048, 1, 999], np.int32)
    res = selection.segmented_order_statistic(
        jnp.asarray(x), jnp.asarray(seg), jnp.asarray(ks), nsegs=len(sizes))
    exp = [np.sort(x[seg == i])[k - 1] for i, k in enumerate(ks)]
    np.testing.assert_array_equal(np.asarray(res.value),
                                  np.asarray(exp, np.float32))


def test_segmented_matches_multi_on_one_segment():
    """A single segment must reproduce the shared-x solver bit for bit."""
    rng = np.random.default_rng(9)
    x = rng.normal(size=5000).astype(np.float32)
    ks = np.asarray([1, 2500, 5000], np.int32)
    seg = np.zeros(x.size, np.int32)
    a = selection.multi_order_statistic(jnp.asarray(x), jnp.asarray(ks),
                                        method="binned", backend="jnp")
    for k in ks:
        b = selection.segmented_order_statistic(
            jnp.asarray(x), jnp.asarray(seg), jnp.asarray([k]), nsegs=1)
        i = int(np.where(ks == k)[0][0])
        assert np.asarray(b.value)[0] == np.asarray(a.value)[i]


def test_per_leaf_clip_matches_per_leaf_partition():
    rng = np.random.default_rng(3)
    tree = {
        "embed": jnp.asarray(rng.normal(size=(256, 32)).astype(np.float32)),
        "out": [jnp.asarray(rng.normal(size=(513,)).astype(np.float32)
                            * np.float32(100.0)),
                jnp.asarray(rng.normal(size=(33, 7)).astype(np.float32)
                            * np.float32(0.01))],
    }
    q = 0.99
    clipped, thrs = robust.clip_by_quantile(tree, q, per_leaf=True)
    for g, t, c in zip(jax.tree.leaves(tree), jax.tree.leaves(thrs),
                       jax.tree.leaves(clipped)):
        a = np.abs(np.asarray(g).ravel())
        k = int(np.clip(np.ceil(q * a.size), 1, a.size))
        exp = max(np.sort(a)[k - 1], np.float32(1e-8))
        np.testing.assert_equal(np.float32(t), np.float32(exp))
        assert np.all(np.abs(np.asarray(c)) <= np.float32(t))
