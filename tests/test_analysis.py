"""Tests for the roofline analysis (HLO walker) and param counting."""
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import param_counts, roofline_terms
from repro.analysis.roofline import hlo_cost
from repro.configs import get_config

jax.config.update("jax_platform_name", "cpu")


def test_hlo_cost_counts_scan_trips():
    """The walker must multiply scan-body flops by the trip count."""
    def body(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    n, d, trips = 64, 64, 10
    x = jax.ShapeDtypeStruct((n, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((trips, d, d), jnp.float32)
    comp = jax.jit(f).lower(x, ws).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, list):  # pre-0.5 jax wraps the dict in a list
        ca = ca[0]
    raw = ca["flops"]
    walked = hlo_cost(comp.as_text())
    expect = 2 * n * d * d * trips
    assert walked["flops_dot"] == pytest.approx(expect, rel=0.01)
    # raw counts the body once — the whole point of the walker
    assert raw < walked["flops_dot"]


def test_hlo_cost_nested_scan():
    def inner(x, w):
        return x @ w, None

    def outer(x, ws):
        def step(c, w):
            y, _ = jax.lax.scan(inner, c, ws)
            return y, None
        y, _ = jax.lax.scan(step, x, jnp.arange(3.0))
        return y

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    comp = jax.jit(outer).lower(x, ws).compile()
    walked = hlo_cost(comp.as_text())
    expect = 2 * 32 * 32 * 32 * 5 * 3  # inner trips x outer trips
    assert walked["flops_dot"] == pytest.approx(expect, rel=0.01)


def test_roofline_terms_dominance():
    t = roofline_terms({
        "flops_per_device": 197e12,       # exactly 1s of compute
        "bytes_per_device": 819e9 * 0.1,  # 0.1s memory
        "collective_bytes_per_device": 50e9 * 0.5,
    })
    assert t["dominant"] == "compute"
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(0.1)
    assert t["collective_s"] == pytest.approx(0.5)


def test_param_counts_moe_active():
    cfg = get_config("mixtral-8x7b")
    from repro.launch import inputs as I
    shapes = I.params_shapes(cfg)
    total, active = param_counts(shapes, cfg)
    # mixtral-8x7b: ~47B total, ~13B active (2 of 8 experts)
    assert 4.4e10 < total < 5.2e10, total
    assert 1.1e10 < active < 1.5e10, active


def test_param_counts_kimi_scale():
    cfg = get_config("kimi-k2-1t-a32b")
    from repro.launch import inputs as I
    shapes = I.params_shapes(cfg)
    total, active = param_counts(shapes, cfg)
    assert total > 0.95e12, f"kimi should be ~1T params, got {total:.3e}"
    assert active < 0.05 * total  # top-8 of 384 experts
