"""Distributed-selection tests.

The multi-device checks run in a subprocess so that this pytest process
keeps the default single CPU device (required by the smoke tests / benches).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import _compat, distributed

jax.config.update("jax_platform_name", "cpu")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_single_device_mesh_path():
    """shard_map path with a 1-device mesh (API-level sanity)."""
    mesh = _compat.make_mesh((1,), ("data",))
    rng = np.random.default_rng(1)
    x = rng.standard_normal(10_000).astype(np.float32)
    k = 2500
    res = distributed.sharded_order_statistic(jnp.asarray(x), k, mesh,
                                              P("data"))
    assert np.float32(res.value) == np.partition(x, k - 1)[k - 1]


def test_across_axis_single_device():
    mesh = _compat.make_mesh((1,), ("data",))
    rng = np.random.default_rng(2)
    v = rng.standard_normal((1, 17)).astype(np.float32)

    def run(vl):
        return distributed.median_across_axis(vl, "data", method="cp")

    got = _compat.shard_map(run, mesh=mesh, in_specs=P("data"),
                            out_specs=P("data"), check=False)(jnp.asarray(v))
    np.testing.assert_allclose(np.asarray(got)[0], v[0])


@pytest.mark.parametrize("n_dev", [4, 8])
def test_multi_device_subprocess(n_dev):
    from _dist_env import subprocess_env

    # drops only a stale device-count flag (the worker prepends its own);
    # popping XLA_FLAGS wholesale would clobber unrelated caller flags
    env = subprocess_env(ROOT)
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "_dist_worker.py"),
         str(n_dev)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "OK" in out.stdout
