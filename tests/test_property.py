"""Hypothesis property tests for the system's core invariants.

Note: float values are generated from integer strategies (scaled) because
XLA:CPU enables FTZ/fast-math processor flags, which trips hypothesis's
strict float-bound validation. Integer-derived floats also maximize tie
coverage, the hardest case for selection.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import selection
from repro.core.objective import eval_fg

jax.config.update("jax_platform_name", "cpu")


def to_f32(ints, scale_exp=0):
    x = np.asarray(ints, np.float64) * (2.0 ** (scale_exp - 10))
    return x.astype(np.float32)


ints_small = st.lists(st.integers(-(2**20), 2**20), min_size=1, max_size=300)


@settings(max_examples=60, deadline=None)
@given(
    ints=ints_small,
    scale_exp=st.integers(min_value=-20, max_value=60),
    kf=st.integers(min_value=0, max_value=1000),
    method=st.sampled_from(["cp", "bisection"]),
)
def test_order_statistic_matches_partition(ints, scale_exp, kf, method):
    x = to_f32(ints, scale_exp)
    n = x.size
    k = max(1, min(n, 1 + (kf * n) // 1001))
    expected = np.partition(x, k - 1)[k - 1]
    res = selection.order_statistic(
        jnp.asarray(x), k, method=method, maxit=256, cap=8
    )
    np.testing.assert_equal(np.float32(res.value), expected)


@settings(max_examples=40, deadline=None)
@given(ints=ints_small, scale_exp=st.integers(min_value=-20, max_value=40))
def test_median_permutation_invariance_and_membership(ints, scale_exp):
    x = to_f32(ints, scale_exp)
    v = np.float32(selection.median(jnp.asarray(x)).value)
    rng = np.random.default_rng(0)
    xp = x.copy(); rng.shuffle(xp)
    assert np.float32(selection.median(jnp.asarray(xp)).value) == v
    # the median is an element of the sample
    assert v in x


@settings(max_examples=40, deadline=None)
@given(
    ints=st.lists(st.integers(-(2**14), 2**14), min_size=2, max_size=200),
    kf=st.integers(min_value=0, max_value=1000),
)
def test_subgradient_certificate_iff(ints, kf):
    """0 in [g_lo,g_hi] at y iff y == x_(k) — on arbitrary data."""
    x = to_f32(ints)
    n = x.size
    k = max(1, min(n, 1 + (kf * n) // 1001))
    xk = np.partition(x, k - 1)[k - 1]
    fg = eval_fg(jnp.asarray(x), jnp.float32(xk), k)
    assert float(fg.g_lo) <= 0.0 <= float(fg.g_hi)
    for v in np.unique(x)[:5]:
        if v != xk:
            fg2 = eval_fg(jnp.asarray(x), jnp.float32(v), k)
            assert not (float(fg2.g_lo) <= 0.0 <= float(fg2.g_hi))


@settings(max_examples=30, deadline=None)
@given(
    ints=st.lists(st.integers(0, 2**30), min_size=4, max_size=256),
    scale_exp=st.integers(min_value=0, max_value=40),
)
def test_log_transform_guard(ints, scale_exp):
    """Monotone-transform selection stays exact on huge-range data."""
    x = to_f32(ints, scale_exp)
    n = x.size
    k = (n + 1) // 2
    expected = np.partition(x, k - 1)[k - 1]
    res = selection.order_statistic(jnp.asarray(x), k, transform="log1p",
                                    maxit=128, cap=8)
    np.testing.assert_equal(np.float32(res.value), expected)
