"""Exactness guarantees of the batched-first selection engine.

Rows mode (``select_rows``) and shared-x mode (``multi_order_statistic``)
must match ``np.partition`` row-wise bit-for-bit, report truthful per-row
status codes, and survive the hard cases: duplicate-heavy rows, k at the
extremes, all-equal rows, per-row k vectors, and the log1p monotone guard.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import selection

jax.config.update("jax_platform_name", "cpu")


def kth_rows(x, ks):
    """Row-wise np.partition oracle; ks scalar or per-row."""
    x = np.asarray(x)
    ks = np.broadcast_to(np.asarray(ks), (x.shape[0],))
    return np.array([np.partition(row, k - 1)[k - 1]
                     for row, k in zip(x, ks)], x.dtype)


@pytest.mark.parametrize("b,n", [(1, 1000), (8, 4096), (33, 257)])
@pytest.mark.parametrize("method", ["cp", "bisection", "sort"])
def test_rows_match_partition(b, n, method):
    rng = np.random.default_rng(b * n)
    x = rng.standard_normal((b, n)).astype(np.float32)
    ks = rng.integers(1, n + 1, size=b).astype(np.int32)
    res = selection.select_rows(jnp.asarray(x), jnp.asarray(ks),
                                method=method, maxit=256)
    np.testing.assert_array_equal(np.asarray(res.value), kth_rows(x, ks))
    assert res.value.shape == (b,)
    assert int(jnp.max(res.status)) <= selection.TIE_FALLBACK


def test_rows_scalar_k_broadcasts():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((5, 2048)).astype(np.float32)
    k = 1024
    res = selection.select_rows(jnp.asarray(x), k)
    np.testing.assert_array_equal(np.asarray(res.value), kth_rows(x, k))


def test_rows_status_codes_truthful():
    """EXACT_HIT / HYBRID_SORT / TIE_FALLBACK per row, each certified."""
    rng = np.random.default_rng(1)
    n = 8192
    rows = [
        rng.standard_normal(n),                      # generic: hybrid/exact
        np.full(n, 3.25),                            # all-equal: exact @ min
        np.concatenate([np.full(n - 100, 0.5),       # > cap duplicates of
                        rng.standard_normal(100)]),  # the answer: fallback
    ]
    x = np.stack(rows).astype(np.float32)
    ks = np.array([n // 2, n // 2, n // 2], np.int32)
    res = selection.select_rows(jnp.asarray(x), jnp.asarray(ks),
                                cap=64, maxit=64)
    np.testing.assert_array_equal(np.asarray(res.value), kth_rows(x, ks))
    st = np.asarray(res.status)
    assert st[1] == selection.EXACT_HIT          # all-equal -> min shortcut
    assert st[2] in (selection.EXACT_HIT, selection.TIE_FALLBACK)
    assert np.all(st != selection.NOT_CONVERGED)


def test_rows_duplicate_heavy():
    """Every row mostly ties, answers inside tie blocks, tiny cap."""
    rng = np.random.default_rng(2)
    b, n = 6, 5000
    x = rng.integers(0, 4, size=(b, n)).astype(np.float32)
    ks = rng.integers(1, n + 1, size=b).astype(np.int32)
    res = selection.select_rows(jnp.asarray(x), jnp.asarray(ks), cap=8)
    np.testing.assert_array_equal(np.asarray(res.value), kth_rows(x, ks))


def test_rows_k_at_extremes():
    rng = np.random.default_rng(3)
    n = 3000
    x = rng.standard_normal((4, n)).astype(np.float32)
    for ks in ([1, 1, 1, 1], [n, n, n, n]):
        res = selection.select_rows(jnp.asarray(x),
                                    jnp.asarray(ks, jnp.int32), cap=16)
        np.testing.assert_array_equal(np.asarray(res.value), kth_rows(x, ks))
        # k=1 / k=n always resolve through the extreme-tie shortcut
        assert np.all(np.asarray(res.status) == selection.EXACT_HIT)
    ks = [1, 2, n - 1, n]
    res = selection.select_rows(jnp.asarray(x), jnp.asarray(ks, jnp.int32),
                                cap=16)
    np.testing.assert_array_equal(np.asarray(res.value), kth_rows(x, ks))
    assert np.all(np.asarray(res.status) != selection.NOT_CONVERGED)


def test_rows_per_row_iters():
    """A frozen row's iteration counter stops; live rows keep going."""
    rng = np.random.default_rng(4)
    n = 20_000
    easy = np.full(n, 1.0)                      # exact at min immediately
    hard = rng.standard_normal(n)
    x = np.stack([easy, hard]).astype(np.float32)
    res = selection.select_rows(jnp.asarray(x), (n + 1) // 2, cap=64)
    iters = np.asarray(res.iters)
    assert iters[0] < iters[1]


def test_rows_log1p_transform():
    """Per-row monotone guard: huge-magnitude rows stay exact."""
    rng = np.random.default_rng(5)
    b, n = 4, 16_384
    x = rng.standard_normal((b, n)).astype(np.float32)
    x[:, :16] = 1e20
    x[2] *= 1e10
    ks = np.array([n // 2, 1, n // 3, n], np.int32)
    res = selection.select_rows(jnp.asarray(x), jnp.asarray(ks),
                                transform="log1p")
    np.testing.assert_array_equal(np.asarray(res.value), kth_rows(x, ks))


def test_rows_matches_scalar_view():
    """order_statistic IS select_rows at B=1 — identical results/statuses."""
    rng = np.random.default_rng(6)
    x = rng.standard_normal((3, 9999)).astype(np.float32)
    ks = [17, 5000, 9999]
    batched = selection.select_rows(jnp.asarray(x),
                                    jnp.asarray(ks, jnp.int32), cap=128)
    for i, k in enumerate(ks):
        scalar = selection.order_statistic(jnp.asarray(x[i]), k, cap=128)
        assert float(batched.value[i]) == float(scalar.value)
        assert int(batched.status[i]) == int(scalar.status)


def test_rows_jit_traced_ks():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((4, 1024)).astype(np.float32))

    @jax.jit
    def f(x, ks):
        return selection.select_rows(x, ks).value

    ks = jnp.asarray([1, 10, 512, 1024], jnp.int32)
    np.testing.assert_array_equal(np.asarray(f(x, ks)),
                                  kth_rows(np.asarray(x), np.asarray(ks)))


# ---------------------------------------------------------------------------
# shared-x mode
# ---------------------------------------------------------------------------


def test_shared_multi_order_statistic_exact():
    rng = np.random.default_rng(8)
    n = 50_001
    x = rng.standard_normal(n).astype(np.float32)
    ks = np.array([1, 7, n // 4, n // 2, n - 1, n], np.int32)
    res = selection.multi_order_statistic(jnp.asarray(x), jnp.asarray(ks))
    want = np.partition(x, ks - 1)[ks - 1]
    np.testing.assert_array_equal(np.asarray(res.value), want)
    assert np.all(np.asarray(res.status) != selection.NOT_CONVERGED)


def test_shared_duplicate_heavy_small_cap():
    rng = np.random.default_rng(9)
    x = rng.integers(0, 5, 30_000).astype(np.float32)
    ks = np.array([1, 10_000, 15_000, 29_999], np.int32)
    res = selection.multi_order_statistic(jnp.asarray(x), jnp.asarray(ks),
                                          cap=8)
    want = np.partition(x, ks - 1)[ks - 1]
    np.testing.assert_array_equal(np.asarray(res.value), want)


def test_shared_log1p_transform():
    rng = np.random.default_rng(10)
    n = 32_768
    x = rng.standard_normal(n).astype(np.float32)
    x[:16] = 1e20
    ks = np.array([n // 4, n // 2, n], np.int32)
    res = selection.multi_order_statistic(jnp.asarray(x), jnp.asarray(ks),
                                          transform="log1p")
    want = np.partition(x, ks - 1)[ks - 1]
    np.testing.assert_array_equal(np.asarray(res.value), want)


def test_shared_backend_interpret_parity():
    """Shared-x solve driven by the multi-pivot Pallas kernel (interpret)."""
    rng = np.random.default_rng(11)
    n = 4096
    x = rng.standard_normal(n).astype(np.float32)
    ks = np.array([1, 100, 2048, 4096], np.int32)
    res_jnp = selection.multi_order_statistic(
        jnp.asarray(x), jnp.asarray(ks), backend="jnp")
    res_pal = selection.multi_order_statistic(
        jnp.asarray(x), jnp.asarray(ks), backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(res_jnp.value),
                                  np.asarray(res_pal.value))
    want = np.partition(x, ks - 1)[ks - 1]
    np.testing.assert_array_equal(np.asarray(res_jnp.value), want)


def test_quantiles_use_shared_mode():
    rng = np.random.default_rng(12)
    x = np.abs(rng.standard_normal(10_000)).astype(np.float32)
    qs = [0.01, 0.25, 0.5, 0.75, 0.99, 1.0]
    res = selection.quantiles(jnp.asarray(x), qs)
    for i, q in enumerate(qs):
        k = max(1, int(np.ceil(q * x.size)))
        np.testing.assert_equal(np.float32(res.value[i]),
                                np.partition(x, k - 1)[k - 1])
