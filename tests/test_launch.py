"""Launch-path tests: plans, specs, mini dry-run on an 8-device mesh."""
import os
import subprocess
import sys

import pytest

import jax

from repro.configs import SHAPES, get_config
from repro.launch.mesh import make_plan

jax.config.update("jax_platform_name", "cpu")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_plan_rules_no_mesh():
    cfg = get_config("gemma2-2b")
    plan = make_plan(cfg, SHAPES["train_4k"], None)
    assert plan.mesh is None and plan.tp == 1


@pytest.mark.skipif(
    tuple(int(p) for p in jax.__version__.split(".")[:2]) < (0, 5),
    reason="jax 0.4.x shard_map mistransposes the fused vocab loss in the "
           "dry-run path (observed on the container's jax 0.4.37; passes "
           "on jax >= 0.5) — see ROADMAP open items; re-enable on bump",
)
def test_mini_dryrun_subprocess():
    """Full launch path (lower+compile+analyze) on an 8-device host mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "_launch_worker.py")],
        capture_output=True, text=True, env=env, timeout=1800,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ALL OK" in out.stdout
