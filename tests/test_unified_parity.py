"""Unified-engine parity suite (the PR-4 refactor contract).

One engine now serves both measures: counts are the exact-integer
specialization of weight mass (``objective.py``), and exactly one bracket
loop / binned loop / compaction / finalize chain remains in
``core.selection``.  These tests pin the refactor's behavioral contract:

* the counting path reproduces ``np.partition`` bit-for-bit across methods
  {cp, binned, binned_polish}, backends {jnp, pallas_interpret} and dtypes
  {f32, f64} — including the certificate stress shapes (tie storms, ulp
  clusters) from ``test_certificates.py``;
* uniform weights with ``wk = k`` reproduce the counting path bit-for-bit
  (measure comparisons become exact integer-valued comparisons);
* exactly-summable integer weights reproduce the f64 sorted-cumsum oracle
  bit-for-bit on every method;
* every EXACT_HIT the engine reports survives an independent recount of
  its measure invariant (the fail-safe contract transfers to the unified
  loops and to the polish).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import robust, selection

jax.config.update("jax_platform_name", "cpu")

METHODS = ["cp", "binned", "binned_polish"]


def _cases(rng, n=4000):
    """Adversarial data shapes: smooth, heavy-tailed, dup-storms, extremes,
    near-constant."""
    half = n // 2
    return [
        rng.standard_normal(n).astype(np.float32),
        rng.lognormal(0, 6, n).astype(np.float32),
        rng.integers(0, 4, n).astype(np.float32),
        np.full(n, -3.25, np.float32),
        np.concatenate([np.full(n - 2, -1e38), [0.0], [1e38]]
                       ).astype(np.float32),
        np.concatenate([rng.standard_normal(half),
                        np.full(n - half, 0.5)]).astype(np.float32),
    ]


def _weighted_oracle(x, w, wk):
    o = np.argsort(x, kind="stable")
    c = np.cumsum(w[o].astype(np.float64))
    return x[o][min(np.searchsorted(c, wk, "left"), x.size - 1)]


def _assert_exact_hit_verified(x, w, kk, res):
    """Any EXACT_HIT must satisfy an independently recounted measure
    invariant (w=None: counts; else masses)."""
    v = np.float32(res.value)
    if int(res.status) != selection.EXACT_HIT:
        return
    if w is None:
        m_lt, m_le = int((x < v).sum()), int((x <= v).sum())
    else:
        m_lt = float(w[x < v].sum())
        m_le = float(w[x <= v].sum())
    assert m_lt < kk <= m_le, (kk, v, m_lt, m_le)


# ---------------------------------------------------------------------------
# counting path: np.partition parity across methods x backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS)
def test_counting_parity_vs_partition(method):
    rng = np.random.default_rng(40)
    for x in _cases(rng):
        n = x.size
        for k in [1, 2, n // 3, (n + 1) // 2, n - 1, n]:
            res = selection.order_statistic(jnp.asarray(x), k,
                                            method=method)
            np.testing.assert_equal(np.float32(res.value),
                                    np.partition(x, k - 1)[k - 1])
            _assert_exact_hit_verified(x, None, k, res)


@pytest.mark.parametrize("method", METHODS)
def test_counting_parity_interpret_backend(method):
    """The Pallas-interpret backend (TPU kernel emulation) must agree with
    the jnp oracle backend on the unified loops (smaller n: interpret mode
    is a Python emulator)."""
    rng = np.random.default_rng(41)
    x = np.concatenate([rng.standard_normal(1500),
                        rng.integers(0, 3, 500).astype(np.float64)]
                       ).astype(np.float32)
    n = x.size
    for k in [1, n // 4, (n + 1) // 2, n]:
        want = np.partition(x, k - 1)[k - 1]
        for backend in ["jnp", "pallas_interpret"]:
            res = selection.order_statistic(
                jnp.asarray(x), k, method=method, backend=backend,
                nbins=32)
            np.testing.assert_equal(np.float32(res.value), want, err_msg=f"{method}/{backend}/k={k}")


@pytest.mark.parametrize("method", METHODS)
def test_rows_and_shared_modes_parity(method):
    rng = np.random.default_rng(42)
    xb = rng.standard_normal((6, 3000)).astype(np.float32)
    ks = np.array([1, 5, 700, 1500, 2999, 3000], np.int32)
    res = selection.select_rows(jnp.asarray(xb), jnp.asarray(ks),
                                method=method)
    want = np.take_along_axis(np.sort(xb, axis=1), ks[:, None] - 1,
                              axis=1)[:, 0]
    np.testing.assert_array_equal(np.asarray(res.value), want)

    x = xb[0]
    resm = selection.multi_order_statistic(jnp.asarray(x), jnp.asarray(ks),
                                           method=method)
    wantm = np.sort(x)[ks - 1]
    np.testing.assert_array_equal(np.asarray(resm.value), wantm)


@pytest.mark.parametrize("method", ["binned", "binned_polish"])
def test_x64_sub_f32_resolution(method):
    """f64 data whose gaps vanish at f32 resolution: the ops-layer reroute
    must keep the unified binned loops exact under x64."""
    with jax.experimental.enable_x64():
        base = np.float64(1.0)
        x = base + np.arange(2000, dtype=np.float64) * 1e-12
        rng = np.random.default_rng(43)
        rng.shuffle(x)
        for k in [1, 700, 1999, 2000]:
            res = selection.order_statistic(jnp.asarray(x), k,
                                            method=method)
            np.testing.assert_equal(np.float64(res.value),
                                    np.partition(x, k - 1)[k - 1])


# ---------------------------------------------------------------------------
# uniform weights == counting path, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS)
def test_uniform_weights_reproduce_counting_path(method):
    rng = np.random.default_rng(44)
    for x in _cases(rng, n=2500):
        n = x.size
        ones = jnp.ones((n,), jnp.float32)
        for k in [1, n // 3, (n + 1) // 2, n]:
            a = selection.order_statistic(jnp.asarray(x), k, method=method)
            b = selection.weighted_order_statistic(
                jnp.asarray(x), ones, float(k), method=method)
            np.testing.assert_equal(np.float32(b.value),
                                    np.float32(a.value))
            _assert_exact_hit_verified(x, np.ones(n, np.float32),
                                       float(k), b)


# ---------------------------------------------------------------------------
# exactly-summable weights == f64 sorted-cumsum oracle, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS)
def test_integer_weights_vs_sorted_cumsum_oracle(method):
    rng = np.random.default_rng(45)
    for x in _cases(rng, n=2500):
        n = x.size
        w = rng.integers(0, 5, n).astype(np.float32)
        w[0] = 1.0
        W = float(w.sum())
        for frac in [0.01, 0.33, 0.5, 0.999]:
            wk = float(np.float32(max(frac * W, 0.5)))
            res = selection.weighted_order_statistic(
                jnp.asarray(x), jnp.asarray(w), wk, method=method)
            np.testing.assert_equal(np.float32(res.value),
                                    _weighted_oracle(x, w, wk))
            _assert_exact_hit_verified(x, w, wk, res)
            assert int(res.status) != selection.NOT_CONVERGED


# ---------------------------------------------------------------------------
# certificate stress shapes under the polish (the fail-safe gates carry
# over: tie storms + ulp clusters with adversarially tiny caps)
# ---------------------------------------------------------------------------


def test_polish_tie_storm_exact_hits_verified():
    rng = np.random.default_rng(46)
    n = 5000
    storms = [
        rng.integers(0, 3, n).astype(np.float32),
        np.full(n, 2.5, np.float32),
        np.concatenate([np.full(n - 2, -1e9), [0.0], [1e9]]
                       ).astype(np.float32),
    ]
    for x in storms:
        for k in [1, 2, (n + 1) // 2, n - 1, n]:
            res = selection.order_statistic(
                jnp.asarray(x), k, method="binned_polish", cap=4, nbins=8)
            np.testing.assert_equal(np.float32(res.value),
                                    np.partition(x, k - 1)[k - 1])
            _assert_exact_hit_verified(x, None, k, res)


def test_polish_ulp_cluster_and_ftz_floor():
    """Ulp-collapsed brackets and the FTZ floor: the polish must inherit
    the stall gates — honest statuses, never a minted certificate."""
    rng = np.random.default_rng(47)
    for base in [np.float32(1.0), np.float32(-255.1234),
                 np.float32(1.2e-38)]:
        levels = [base]
        for _ in range(3):
            levels.append(np.nextafter(levels[-1], np.float32(np.inf),
                                       dtype=np.float32))
        x = np.asarray(levels, np.float32)[rng.integers(0, 4, 4000)]
        n = x.size
        for k in [1, n // 4, (n + 1) // 2, n]:
            want = np.partition(x, k - 1)[k - 1]
            res = selection.order_statistic(jnp.asarray(x), k,
                                            method="binned_polish")
            np.testing.assert_equal(np.float32(res.value), want)
            _assert_exact_hit_verified(x, None, k, res)
            # undersized cap: fail-safe statuses only
            res = selection.order_statistic(jnp.asarray(x), k,
                                            method="binned_polish", cap=2)
            _assert_exact_hit_verified(x, None, k, res)
            if int(res.status) != selection.NOT_CONVERGED:
                np.testing.assert_equal(np.float32(res.value), want)


def test_polish_weighted_stress():
    rng = np.random.default_rng(48)
    n = 4000
    x = rng.integers(-20, 20, n).astype(np.float32) * 0.5
    w = rng.integers(0, 3, n).astype(np.float32)
    w[0] = 1.0
    W = float(w.sum())
    for frac in [0.001, 0.5, 0.999]:
        wk = float(np.float32(max(frac * W, 0.5)))
        res = selection.weighted_order_statistic(
            jnp.asarray(x), jnp.asarray(w), wk, method="binned_polish",
            cap=4)
        np.testing.assert_equal(np.float32(res.value),
                                _weighted_oracle(x, w, wk))
        _assert_exact_hit_verified(x, w, wk, res)


def test_polish_log1p_transform_roundtrip():
    """The polish runs in the transformed domain too; the count-preserving
    map-back + original-space finalize must stay exact."""
    rng = np.random.default_rng(49)
    x = np.exp(rng.uniform(-40, 80, 3000)).astype(np.float32)
    n = x.size
    for k in [1, n // 2, n]:
        res = selection.order_statistic(
            jnp.asarray(x), k, method="binned_polish", transform="log1p")
        np.testing.assert_equal(np.float32(res.value),
                                np.partition(x, k - 1)[k - 1])


# ---------------------------------------------------------------------------
# polish telemetry: the CP-centered edges must not COST sweeps
# ---------------------------------------------------------------------------


def test_polish_sweep_count_no_worse_than_binned():
    rng = np.random.default_rng(50)
    for gen in [lambda: rng.standard_normal(1 << 17),
                lambda: rng.lognormal(0, 8, 1 << 17)]:
        x = gen().astype(np.float32)
        k = (x.size + 1) // 2
        plain = selection.select_rows(jnp.asarray(x)[None, :], k,
                                      method="binned")
        pol = selection.select_rows(jnp.asarray(x)[None, :], k,
                                    method="binned_polish")
        want = np.partition(x, k - 1)[k - 1]
        np.testing.assert_equal(np.float32(plain.value[0]), want)
        np.testing.assert_equal(np.float32(pol.value[0]), want)
        assert int(pol.iters[0]) <= int(plain.iters[0])


# ---------------------------------------------------------------------------
# Theil-Sen blocked pair-subsample mode
# ---------------------------------------------------------------------------


def test_theil_sen_blocked_equals_full_on_small_n():
    """max_pairs >= n(n-1) enumerates every ordered pair exactly once; the
    (slope, weight) multiset then matches the full (n, n) matrix (whose
    diagonal carries weight 0), so the two modes agree exactly (integer x
    grid: pair weights |dx| sum exactly in any order)."""
    rng = np.random.default_rng(51)
    n = 48
    x = np.arange(n, dtype=np.float32)
    y = 2.5 * x - 3.0 + 0.25 * rng.integers(-2, 3, n).astype(np.float32)
    full = robust.theil_sen_fit(jnp.asarray(x), jnp.asarray(y))
    blocked = robust.theil_sen_fit(jnp.asarray(x), jnp.asarray(y),
                                   max_pairs=n * (n - 1))
    np.testing.assert_equal(np.float32(blocked.slope),
                            np.float32(full.slope))
    np.testing.assert_equal(np.float32(blocked.intercept),
                            np.float32(full.intercept))


def test_theil_sen_subsampled_recovers_slope_under_contamination():
    """The O(max_pairs)-memory mode keeps the robustness story: exact slope
    recovery at 30% slope-destroying contamination with ~25x fewer pairs
    than the full matrix."""
    rng = np.random.default_rng(52)
    n = 400
    x = rng.standard_normal(n).astype(np.float32)
    y = (4.0 * x + 1.0).astype(np.float32)
    bad = rng.choice(n, int(0.3 * n), replace=False)
    y[bad] = rng.standard_normal(bad.size).astype(np.float32) * 50.0
    fit = robust.theil_sen_fit(jnp.asarray(x), jnp.asarray(y),
                               max_pairs=n * 16)
    assert abs(float(fit.slope) - 4.0) < 0.05
    assert abs(float(fit.intercept) - 1.0) < 0.2


def _jaxpr_shapes(jaxpr, acc):
    """All intermediate shapes, recursing into pjit/scan/cond sub-jaxprs."""
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            if hasattr(v.aval, "shape"):
                acc.add(tuple(v.aval.shape))
        for val in eqn.params.values():
            sub = getattr(val, "jaxpr", None)
            if sub is not None:
                _jaxpr_shapes(sub, acc)
    return acc


def test_theil_sen_blocked_never_materializes_nxn():
    """Shape check on the traced computation (recursing through the jit
    call boundary): with max_pairs << n^2 the largest intermediate is
    (p, n), p = max_pairs // n — the (n, n) slope matrix never exists."""
    n, max_pairs = 256, 1024
    x = jnp.arange(n, dtype=jnp.float32)
    y = 2.0 * x
    jaxpr = jax.make_jaxpr(
        lambda a, b: robust.theil_sen_fit(a, b, max_pairs=max_pairs)
    )(x, y)
    shapes = _jaxpr_shapes(jaxpr.jaxpr, set())
    assert any(s[0] * s[1] >= n for s in shapes if len(s) == 2), shapes
    biggest = max((int(np.prod(s)) for s in shapes), default=0)
    assert 0 < biggest < n * n, (biggest, sorted(shapes)[-5:])


def test_theil_sen_full_coverage_blocked_branch_is_taken():
    """max_pairs == n(n-1) must route through the BLOCKED branch (offsets
    1..n-1, a (n-1, n) block) — the regime where the offset schedule
    enumerates every ordered pair and the equality test above is
    meaningful, not a second run of the full-matrix branch."""
    n = 48
    jaxpr = jax.make_jaxpr(
        lambda a, b: robust.theil_sen_fit(a, b, max_pairs=n * (n - 1))
    )(jnp.arange(n, dtype=jnp.float32), jnp.arange(n, dtype=jnp.float32))
    shapes = _jaxpr_shapes(jaxpr.jaxpr, set())
    assert (n - 1, n) in shapes, sorted(shapes)[-5:]
    assert (n, n) not in shapes
