"""End-to-end behaviour tests for the paper's system.

The full pipeline: synthetic data -> train_step (forward, fused CE,
quantile clip via cutting-plane selection, AdamW) -> checkpoint -> restore
-> serve (greedy generation), on a reduced config.
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, local_plan
from repro.configs.base import ShapeConfig
from repro.core import selection
from repro.data import SyntheticPipeline
from repro.models import model
from repro.optim import AdamW
from repro.train import TrainState, fit, make_serve_step, make_train_step

jax.config.update("jax_platform_name", "cpu")


def test_end_to_end_train_checkpoint_serve(tmp_path):
    cfg = get_config("gemma2-2b").reduced()
    plan = local_plan()
    shape = ShapeConfig("e2e", seq_len=32, global_batch=2, kind="train")
    opt = AdamW(lr=1e-3)
    params = model.init(jax.random.PRNGKey(0), cfg)
    state = TrainState(params=params, opt=opt.init(params),
                       step=jnp.zeros((), jnp.int32))
    step = make_train_step(cfg, plan, opt, clip="quantile")
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    pipe = SyntheticPipeline(cfg, shape, seed=0)
    out = fit(train_step=step, state=state, pipeline=pipe, steps=5,
              ckpt=ckpt, ckpt_every=5, log_every=100, log_fn=lambda s: None)
    pipe.close()
    assert all(np.isfinite(out["losses"]))
    assert ckpt.latest_step() == 5

    # restore into a fresh state and serve greedily (note: the original
    # `params`/`state` buffers were DONATED by the train loop)
    fresh_params = model.init(jax.random.PRNGKey(1), cfg)
    fresh = TrainState(params=fresh_params, opt=opt.init(fresh_params),
                       step=jnp.zeros((), jnp.int32))
    restored, manifest = ckpt.restore(5, fresh)
    serve = jax.jit(make_serve_step(cfg, plan))
    cache = model.init_cache(cfg, 2, max_seq=16, plan=plan,
                             dtype=jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    toks = []
    for i in range(8):
        tok, _, cache = serve(restored.params, cache, tok,
                              jnp.asarray(i, jnp.int32))
        toks.append(np.asarray(tok))
    gen = np.concatenate(toks, axis=1)
    assert gen.shape == (2, 8)
    assert (gen >= 0).all() and (gen < cfg.vocab).all()


def test_selection_is_the_primitive_everywhere():
    """The paper's selection drives clipping thresholds and telemetry."""
    rng = np.random.default_rng(0)
    times = jnp.asarray(np.abs(rng.standard_normal(200)).astype(np.float32))
    p50 = selection.median(times)
    p99 = selection.quantile(times, 0.99)
    t = np.asarray(times)
    assert float(p50.value) == np.partition(t, 99)[99]  # k=100, 0-idx 99
    k99 = int(np.ceil(0.99 * t.size)) - 1
    assert float(p99.value) == np.partition(t, k99)[k99]
