"""Subprocess worker for the polish-driven distributed-round tests.

Run as:  python tests/_dist_polish_worker.py <n_devices>
Sets XLA_FLAGS *before* importing jax (preserving caller flags other than a
stale device-count), then checks on an n = 1M array, both measures:

* exactness of ``method='binned_polish'`` vs np.partition / the weighted
  sorted-cumsum oracle AND vs the local engine;
* the round-count claim: 1 psum round where plain binned takes >= 2;
* garbage-cut injection: a sabotaged centroid cut costs extra rounds but
  NEVER exactness (the fp contract: the cut steers edge placement only,
  narrowing stays on psum'd measured prefixes).

Exits nonzero on failure.
"""
import sys

from _dist_env import force_device_count

n_dev = int(sys.argv[1]) if len(sys.argv) > 1 else 4
force_device_count(n_dev)  # must run BEFORE the jax import below

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import _compat, distributed, selection  # noqa: E402

assert jax.device_count() == n_dev, jax.devices()


def check(cond, msg):
    if not cond:
        print("FAIL:", msg)
        sys.exit(1)


def main():
    mesh = _compat.make_mesh((n_dev,), ("data",))
    rng = np.random.default_rng(0)
    n = 1 << 20
    x = rng.standard_normal(n).astype(np.float32)
    xj = jnp.asarray(x)
    k = (n + 1) // 2
    want = np.partition(x, k - 1)[k - 1]

    # --- counting measure: exactness + the 2 -> 1 psum-round claim -------
    res_b = distributed.sharded_order_statistic(xj, k, mesh, P("data"),
                                                method="binned")
    res_p = distributed.sharded_order_statistic(xj, k, mesh, P("data"),
                                                method="binned_polish")
    loc = selection.order_statistic(xj, k, method="binned")
    check(np.float32(res_b.value) == want, f"binned {res_b.value} != {want}")
    check(np.float32(res_p.value) == want, f"polish {res_p.value} != {want}")
    check(np.float32(loc.value) == want, "local engine disagrees")
    check(int(res_p.iters) == 1,
          f"polish rounds at 1M: {int(res_p.iters)} != 1")
    check(int(res_b.iters) >= 2,
          f"plain binned unexpectedly took {int(res_b.iters)} round(s)")

    # off-median ranks stay exact under the polish
    for kq in [1, n // 10, n - 7]:
        r = distributed.sharded_order_statistic(xj, kq, mesh, P("data"),
                                                method="binned_polish")
        check(np.float32(r.value) == np.partition(x, kq - 1)[kq - 1],
              f"polish k={kq} mismatch")

    # --- weighted measure ------------------------------------------------
    w = rng.integers(0, 5, n).astype(np.float32)
    w[0] = 1.0
    o = np.argsort(x, kind="stable")
    cumw = np.cumsum(w[o].astype(np.float64))
    wk = float(np.float32(0.5 * w.sum()))
    wwant = x[o][min(np.searchsorted(cumw, wk, "left"), n - 1)]
    wres_b = distributed.sharded_weighted_order_statistic(
        xj, jnp.asarray(w), wk, mesh, P("data"), method="binned")
    wres_p = distributed.sharded_weighted_order_statistic(
        xj, jnp.asarray(w), wk, mesh, P("data"), method="binned_polish")
    check(np.float32(wres_b.value) == wwant,
          f"weighted binned {wres_b.value} != {wwant}")
    check(np.float32(wres_p.value) == wwant,
          f"weighted polish {wres_p.value} != {wwant}")
    check(int(wres_p.iters) == 1,
          f"weighted polish rounds at 1M: {int(wres_p.iters)} != 1")
    check(int(wres_b.iters) >= 2,
          f"weighted binned unexpectedly took {int(wres_b.iters)}")

    # --- garbage-cut injection: a bad centroid costs rounds, never
    # exactness (cut steers edge PLACEMENT only) -------------------------
    orig = selection.polish_edges

    def garbage_cut(lo, hi, t, nbins):
        # a finite but maximally-unhelpful cut: pinned at the bracket's
        # right end regardless of the psum'd centroid
        bad = lo + jnp.asarray(0.99, lo.dtype) * (hi - lo)
        return orig(lo, hi, bad, nbins)

    selection.polish_edges = garbage_cut
    try:
        res_g = distributed.sharded_order_statistic(
            xj, k, mesh, P("data"), method="binned_polish")
        wres_g = distributed.sharded_weighted_order_statistic(
            xj, jnp.asarray(w), wk, mesh, P("data"), method="binned_polish")
    finally:
        selection.polish_edges = orig
    check(np.float32(res_g.value) == want,
          f"garbage cut broke exactness: {res_g.value} != {want}")
    check(np.float32(wres_g.value) == wwant,
          f"garbage cut broke weighted exactness: {wres_g.value}")
    check(int(res_g.iters) > int(res_p.iters),
          f"garbage cut should cost rounds: {int(res_g.iters)} vs "
          f"{int(res_p.iters)}")
    check(int(res_g.iters) <= int(res_b.iters) + 2,
          f"garbage cut cost too many rounds: {int(res_g.iters)}")

    # NaN cut: polish_edges degrades it to the bracket midpoint internally
    def nan_cut(lo, hi, t, nbins):
        return orig(lo, hi, jnp.full_like(t, jnp.nan), nbins)

    selection.polish_edges = nan_cut
    try:
        res_n = distributed.sharded_order_statistic(
            xj, k, mesh, P("data"), method="binned_polish")
    finally:
        selection.polish_edges = orig
    check(np.float32(res_n.value) == want,
          f"NaN cut broke exactness: {res_n.value} != {want}")

    # --- method='auto' mirrors the local engine (static by global n) -----
    res_a = distributed.sharded_order_statistic(xj, k, mesh, P("data"),
                                                method="auto")
    check(np.float32(res_a.value) == want, "auto mismatch")
    small = rng.standard_normal(1 << 12).astype(np.float32)
    ks = 1 << 11
    res_s = distributed.sharded_order_statistic(
        jnp.asarray(small), ks, mesh, P("data"), method="auto")
    check(np.float32(res_s.value) == np.partition(small, ks - 1)[ks - 1],
          "small auto (cp leg) mismatch")

    print("OK")


if __name__ == "__main__":
    main()
