"""Per-arch smoke tests: REDUCED same-family configs, one forward + one
decode step on CPU; asserts output shapes and finiteness (no NaNs)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, local_plan
from repro.models import model

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 32


def make_small_batch(cfg, rng):
    if cfg.family == "encdec":
        return {
            "audio": jnp.asarray(
                rng.standard_normal((B, S, cfg.d_model)).astype(np.float32)),
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)),
        }
    if cfg.frontend == "patch_stub":
        n_img = cfg.n_frontend_tokens
        return {
            "patches": jnp.asarray(rng.standard_normal(
                (B, n_img, cfg.d_model)).astype(np.float32)),
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (B, S - n_img)).astype(np.int32)),
        }
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, S)).astype(np.int32))}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    plan = local_plan()
    rng = np.random.default_rng(hash(arch) % 2**31)
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = make_small_batch(cfg, rng)
    logits, aux = jax.jit(
        lambda p, b: model.forward(p, b, cfg, plan))(params, batch)
    assert logits.shape == (B, S, cfg.vocab), logits.shape
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    plan = local_plan()
    rng = np.random.default_rng(1)
    params = model.init(jax.random.PRNGKey(0), cfg)
    cache = model.init_cache(cfg, B, max_seq=S, plan=plan,
                             dtype=jnp.float32, enc_seq=S)
    if cfg.family == "encdec":
        # fill cross KV from a stub encoder pass (layers stacked on axis 0)
        from repro.models import encdec
        audio = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)).astype(np.float32))
        enc_out = encdec.encode(params, audio, cfg, plan)
        ks, vs = [], []
        for i in range(cfg.n_layers):
            p_i = jax.tree.map(lambda x: x[i], params["dec"])
            k, v = encdec._cross_kv(p_i["cross"], enc_out, cfg, plan)
            ks.append(k.astype(cache["xk"].dtype))
            vs.append(v.astype(cache["xv"].dtype))
        cache = dict(cache, xk=jnp.stack(ks), xv=jnp.stack(vs))

    token = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)).astype(np.int32))
    step = jax.jit(lambda p, c, t, i: model.decode_step(p, c, t, i, cfg, plan))
    logits, new_cache = step(params, cache, token, jnp.asarray(3, jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure preserved
    jax.tree.map(lambda a, b: None, cache, new_cache)


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "recurrentgemma-9b",
                                  "gemma2-2b"])
def test_decode_matches_forward(arch):
    """Stepwise decode logits == full-sequence forward logits (tail)."""
    cfg = get_config(arch).reduced()
    plan = local_plan()
    rng = np.random.default_rng(2)
    params = model.init(jax.random.PRNGKey(0), cfg)
    T = 12
    toks = rng.integers(0, cfg.vocab, (B, T)).astype(np.int32)
    full_logits, _ = jax.jit(
        lambda p, b: model.forward(p, b, cfg, plan, mode="prefill"))(
        params, {"tokens": jnp.asarray(toks)})

    cache = model.init_cache(cfg, B, max_seq=T, plan=plan, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t, i: model.decode_step(p, c, t, i, cfg, plan))
    outs = []
    for t in range(T):
        lg, cache = step(params, cache, jnp.asarray(toks[:, t:t + 1]),
                         jnp.asarray(t, jnp.int32))
        outs.append(np.asarray(lg[:, 0]))
    dec_logits = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec_logits, np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_chunked_matches_scan():
    """The chunked GLA form is exact vs the time-scan for moderate decay."""
    cfg = get_config("rwkv6-1.6b").reduced()
    plan = local_plan()
    params = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, 33)).astype(np.int32))
    l1, _ = model.forward(params, {"tokens": toks}, cfg, plan,
                          rwkv_impl="scan")
    l2, _ = model.forward(params, {"tokens": toks}, cfg, plan,
                          rwkv_impl="chunked")
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-3, atol=2e-3)


def test_loss_decreases_one_sgd_step():
    """End-to-end differentiability: one SGD step reduces the loss."""
    cfg = get_config("gemma2-2b").reduced()
    plan = local_plan()
    params = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)).astype(np.int32))

    def loss_fn(p):
        logits, _ = model.forward(p, {"tokens": toks}, cfg, plan)
        loss, _ = model.lm_loss(logits[:, :-1], toks[:, 1:],
                                jnp.ones_like(toks[:, 1:]))
        return loss

    l0, g = jax.value_and_grad(loss_fn)(params)
    params2 = jax.tree.map(lambda p, gg: p - 0.5 * gg, params, g)
    l1 = loss_fn(params2)
    assert np.isfinite(float(l0)) and float(l1) < float(l0)


def test_whisper_decode_matches_forward():
    """Enc-dec stepwise decode == teacher-forced forward (cross-attn path)."""
    cfg = get_config("whisper-medium").reduced()
    plan = local_plan()
    rng = np.random.default_rng(5)
    params = model.init(jax.random.PRNGKey(0), cfg)
    T = 10
    audio = jnp.asarray(rng.standard_normal((B, T, cfg.d_model))
                        .astype(np.float32))
    toks = rng.integers(0, cfg.vocab, (B, T)).astype(np.int32)
    full_logits, _ = model.forward(
        params, {"audio": audio, "tokens": jnp.asarray(toks)}, cfg, plan,
        mode="prefill")

    from repro.models import encdec
    enc_out = encdec.encode(params, audio, cfg, plan)
    cache = model.init_cache(cfg, B, max_seq=T, plan=plan,
                             dtype=jnp.float32, enc_seq=T)
    ks, vs = [], []
    for i in range(cfg.n_layers):
        p_i = jax.tree.map(lambda x: x[i], params["dec"])
        k, v = encdec._cross_kv(p_i["cross"], enc_out, cfg, plan)
        ks.append(k.astype(cache["xk"].dtype))
        vs.append(v.astype(cache["xv"].dtype))
    cache = dict(cache, xk=jnp.stack(ks), xv=jnp.stack(vs))

    step = jax.jit(lambda p, c, t, i: model.decode_step(p, c, t, i, cfg, plan))
    outs = []
    for t in range(T):
        lg, cache = step(params, cache, jnp.asarray(toks[:, t:t + 1]),
                         jnp.asarray(t, jnp.int32))
        outs.append(np.asarray(lg[:, 0]))
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "kimi-k2-1t-a32b"])
def test_moe_capacity_conservation(arch):
    """MoE output only mixes routed tokens; gates bounded; aux finite."""
    from repro.models import moe as moe_mod
    cfg = get_config(arch).reduced()
    plan = local_plan()
    rng = np.random.default_rng(6)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model))
                    .astype(np.float32))
    out, aux, z = moe_mod.moe_apply(params, x, cfg, plan)
    assert out.shape == x.shape
    assert np.isfinite(float(aux)) and np.isfinite(float(z))
    assert bool(jnp.all(jnp.isfinite(out)))
    # aux (load-balance) near 1 for near-uniform routing at init
    assert 0.5 < float(aux) < 3.0
