"""Warm-start test wall: the ``prior=`` carry NEVER affects exactness.

Differential suite for the prior leg (PR 10): every public selection API,
every method family, both measures and both kernel backends must return
BIT-IDENTICAL values warm and cold — including under adversarial priors
(NaN/±inf cut, bracket excluding the true answer, prior from a different
array, stale prior after 100% data replacement).  Only sweep counts may
differ; the economy half of the contract (an exact prior resolves in one
binned sweep; warm LTS/IRLS steady state = 1 sweep per iteration) is
pinned by instrumented-counter assertions.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import selection, robust, stream

jax.config.update("jax_platform_name", "cpu")

# large enough that method=None resolves to 'binned' and real sweeps run
# (above the scalar cap), small enough to stay fast on CPU
N = 1 << 17
METHODS = ["binned", "binned_polish", "cp", "bisection"]
BACKENDS = ["jnp", "pallas_interpret"]


def _data(seed, n=N):
    rng = np.random.default_rng(seed)
    # duplicate-heavy + smooth mix: ties are the hard case for selection
    x = np.where(rng.random(n) < 0.3,
                 rng.integers(-4, 5, size=n).astype(np.float32),
                 rng.standard_normal(n).astype(np.float32))
    return x


def kth(x, k):
    return np.partition(np.asarray(x), k - 1)[k - 1]


# ---------------------------------------------------------------------------
# warm == cold bit-for-bit: method × measure × backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_warm_equals_cold_counting(method, backend):
    x = jnp.asarray(_data(0))
    k = N // 3
    cold = selection.order_statistic(x, k, method=method, backend=backend)
    warm = selection.order_statistic(x, k, method=method, backend=backend,
                                     prior=cold)
    assert np.asarray(warm.value) == np.asarray(cold.value) == kth(x, k)
    assert int(warm.iters) <= int(cold.iters)


@pytest.mark.parametrize("method", ["binned", "binned_polish", "cp"])
@pytest.mark.parametrize("backend", BACKENDS)
def test_warm_equals_cold_weighted(method, backend):
    rng = np.random.default_rng(1)
    x = jnp.asarray(_data(1))
    # dyadic weights: exactly summable, so bit-exact comparison is sound
    w = jnp.asarray((rng.integers(1, 9, size=N) * 0.25).astype(np.float32))
    cold = selection.weighted_median(x, w, method=method, backend=backend)
    warm = selection.weighted_median(x, w, method=method, backend=backend,
                                     prior=cold)
    assert np.asarray(warm.value) == np.asarray(cold.value)
    assert int(warm.iters) <= int(cold.iters)


@pytest.mark.parametrize("method", ["binned", "binned_polish", "cp"])
def test_warm_equals_cold_rows(method):
    rng = np.random.default_rng(2)
    b, n = 6, 30_000
    x = rng.standard_normal((b, n)).astype(np.float32)
    ks = rng.integers(1, n + 1, size=b).astype(np.int32)
    cold = selection.select_rows(jnp.asarray(x), jnp.asarray(ks),
                                 method=method)
    warm = selection.select_rows(jnp.asarray(x), jnp.asarray(ks),
                                 method=method, prior=cold)
    np.testing.assert_array_equal(np.asarray(warm.value),
                                  np.asarray(cold.value))
    np.testing.assert_array_equal(
        np.asarray(cold.value),
        [kth(row, k) for row, k in zip(x, ks)])
    assert int(jnp.max(warm.iters)) <= int(jnp.max(cold.iters))


@pytest.mark.parametrize("method", ["binned", "binned_polish", "cp"])
def test_warm_equals_cold_multi_k(method):
    x = jnp.asarray(_data(3))
    ks = jnp.asarray([1, N // 4, N // 2, 3 * N // 4, N], jnp.int32)
    cold = selection.multi_order_statistic(x, ks, method=method)
    warm = selection.multi_order_statistic(x, ks, method=method, prior=cold)
    np.testing.assert_array_equal(np.asarray(warm.value),
                                  np.asarray(cold.value))


def test_warm_equals_cold_segmented():
    rng = np.random.default_rng(4)
    n, nsegs = 60_000, 5
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    seg = jnp.asarray(rng.integers(0, nsegs, size=n).astype(np.int32))
    sizes = np.bincount(np.asarray(seg), minlength=nsegs)
    ks = jnp.asarray((sizes // 2 + 1).astype(np.int32))
    cold = selection.segmented_order_statistic(x, seg, ks, nsegs=nsegs,
                                               method="binned")
    warm = selection.segmented_order_statistic(x, seg, ks, nsegs=nsegs,
                                               method="binned", prior=cold)
    np.testing.assert_array_equal(np.asarray(warm.value),
                                  np.asarray(cold.value))


def test_warm_equals_cold_log1p_transform():
    """Prior values live in DATA space; the log1p leg must map them into
    transform space before seeding edges."""
    x = np.abs(_data(5)) + 1.0
    x[:16] = 1e20  # extreme magnitudes: the transform's reason to exist
    xj = jnp.asarray(x)
    k = N // 2
    cold = selection.order_statistic(xj, k, method="binned",
                                     transform="log1p")
    warm = selection.order_statistic(xj, k, method="binned",
                                     transform="log1p", prior=cold)
    assert np.asarray(warm.value) == np.asarray(cold.value) == kth(x, k)


def test_warm_equals_cold_weighted_multi():
    rng = np.random.default_rng(6)
    x = jnp.asarray(_data(6))
    w = jnp.asarray((rng.integers(1, 5, size=N) * 0.5).astype(np.float32))
    W = float(np.sum(np.asarray(w, np.float64)))
    wks = jnp.asarray([0.1 * W, 0.5 * W, 0.9 * W], jnp.float32)
    cold = selection.weighted_multi_order_statistic(x, w, wks,
                                                    method="binned")
    warm = selection.weighted_multi_order_statistic(x, w, wks,
                                                    method="binned",
                                                    prior=cold)
    np.testing.assert_array_equal(np.asarray(warm.value),
                                  np.asarray(cold.value))


# ---------------------------------------------------------------------------
# adversarial priors: exactness is NEVER a function of the prior
# ---------------------------------------------------------------------------


def _adversarial_priors(x, k):
    """Priors engineered to be maximally misleading for ``x_(k)``."""
    f32 = np.float32
    mk = lambda v, lo, hi, cut: selection.Prior(
        value=jnp.asarray(v, jnp.float32), y_lo=jnp.asarray(lo, jnp.float32),
        y_hi=jnp.asarray(hi, jnp.float32), cut=jnp.asarray(cut, jnp.float32))
    ans = kth(x, k)
    far = f32(ans + 1000.0)
    return {
        "nan_everything": mk(np.nan, np.nan, np.nan, np.nan),
        "inf_cut": mk(ans, ans - 1, ans + 1, np.inf),
        "neg_inf_cut": mk(ans, ans - 1, ans + 1, -np.inf),
        "inf_bracket": mk(0.0, -np.inf, np.inf, 0.0),
        "bracket_excludes_answer": mk(far, far - 1, far + 1, far),
        "inverted_bracket": mk(ans, ans + 5, ans - 5, ans),
        "zero_width": mk(far, far, far, far),
    }


@pytest.mark.parametrize("method", METHODS)
def test_adversarial_priors_bitexact(method):
    x = _data(7)
    xj = jnp.asarray(x)
    k = N // 2
    cold = selection.order_statistic(xj, k, method=method)
    for name, pr in _adversarial_priors(x, k).items():
        warm = selection.order_statistic(xj, k, method=method, prior=pr)
        assert np.asarray(warm.value) == np.asarray(cold.value), name
        assert int(warm.status) != selection.NOT_CONVERGED, name


def test_prior_from_different_array():
    """A prior realized on array A steers selection on unrelated array B:
    values must still match B's cold answer exactly."""
    a = jnp.asarray(_data(8))
    b = jnp.asarray(_data(9) * 50.0 + 17.0)
    k = N // 4
    pr = selection.order_statistic(a, k, method="binned")
    for method in METHODS:
        cold = selection.order_statistic(b, k, method=method)
        warm = selection.order_statistic(b, k, method=method, prior=pr)
        assert np.asarray(warm.value) == np.asarray(cold.value), method


def test_stale_prior_after_full_replacement():
    """100% data replacement between ticks: the stale prior costs sweeps,
    never exactness."""
    old = jnp.asarray(_data(10))
    new = jnp.asarray(_data(11) * -3.0 + 100.0)
    k = N // 2
    stale = selection.order_statistic(old, k, method="binned")
    cold = selection.order_statistic(new, k, method="binned")
    warm = selection.order_statistic(new, k, method="binned", prior=stale)
    assert np.asarray(warm.value) == np.asarray(cold.value) == kth(new, k)


def test_adversarial_prior_weighted_and_rows():
    rng = np.random.default_rng(12)
    x = jnp.asarray(_data(12))
    w = jnp.asarray((rng.integers(1, 9, size=N) * 0.25).astype(np.float32))
    bad = selection.Prior(value=jnp.asarray(jnp.nan),
                          y_lo=jnp.asarray(-jnp.inf),
                          y_hi=jnp.asarray(jnp.inf),
                          cut=jnp.asarray(jnp.nan))
    cold = selection.weighted_median(x, w, method="binned_polish")
    warm = selection.weighted_median(x, w, method="binned_polish", prior=bad)
    assert np.asarray(warm.value) == np.asarray(cold.value)

    b, n = 4, 20_000
    X = rng.standard_normal((b, n)).astype(np.float32)
    ks = rng.integers(1, n + 1, size=b).astype(np.int32)
    coldr = selection.select_rows(jnp.asarray(X), jnp.asarray(ks),
                                  method="binned")
    warmr = selection.select_rows(jnp.asarray(X), jnp.asarray(ks),
                                  method="binned", prior=bad)
    np.testing.assert_array_equal(np.asarray(warmr.value),
                                  np.asarray(coldr.value))


# ---------------------------------------------------------------------------
# sweep economy: an exact prior resolves in one sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_exact_prior_one_sweep(backend):
    # +0.5 keeps the median tie block away from 0.0: FTZ flushes the
    # denormal ``prev_float(0.0)``, so a zero-valued answer cannot form a
    # collapse pair and legitimately costs an extra sweep (exactness is
    # unaffected — the adversarial tests cover ties at zero)
    x = jnp.asarray(_data(13)) + 0.5
    k = N // 2
    cold = selection.order_statistic(x, k, method="binned", backend=backend)
    warm = selection.order_statistic(x, k, method="binned", backend=backend,
                                     prior=cold)
    assert int(cold.iters) >= 1
    assert int(warm.iters) <= 1
    assert int(warm.status) == selection.EXACT_HIT


def test_exact_prior_one_sweep_all_modes():
    rng = np.random.default_rng(14)
    x = jnp.asarray(_data(14)) + 0.5  # nonzero answers (see above)
    # rows
    b, n = 4, 40_000
    X = jnp.asarray(rng.standard_normal((b, n)).astype(np.float32))
    ks = jnp.asarray(rng.integers(1, n + 1, size=b).astype(np.int32))
    c = selection.select_rows(X, ks, method="binned")
    wres = selection.select_rows(X, ks, method="binned", prior=c)
    assert int(jnp.max(wres.iters)) <= 1
    # multi-k
    kk = jnp.asarray([1, N // 2, N], jnp.int32)
    c = selection.multi_order_statistic(x, kk, method="binned")
    wres = selection.multi_order_statistic(x, kk, method="binned", prior=c)
    assert int(jnp.max(wres.iters)) <= 1
    # weighted
    w = jnp.asarray((rng.integers(1, 5, size=N) * 0.5).astype(np.float32))
    c = selection.weighted_median(x, w, method="binned")
    wres = selection.weighted_median(x, w, method="binned", prior=c)
    assert int(wres.iters) <= 1


# ---------------------------------------------------------------------------
# iterative consumers: warm == cold fits, steady state = 1 sweep
# ---------------------------------------------------------------------------


def _regression(seed, n):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    X = np.stack([np.ones_like(x), x], axis=1)
    y = (2.0 + 3.0 * x + 0.1 * rng.standard_normal(n)).astype(np.float32)
    out = rng.random(n) < 0.2  # 20% gross contamination
    y = np.where(out, 50.0 * rng.standard_normal(n).astype(np.float32), y)
    return jnp.asarray(X), jnp.asarray(y)


def test_irls_warm_equals_cold_and_steady_state():
    X, y = _regression(20, N)
    fw = robust.irls_fit(X, y, loss="huber", iters=8, method="binned",
                         warm=True)
    fc = robust.irls_fit(X, y, loss="huber", iters=8, method="binned",
                         warm=False)
    np.testing.assert_array_equal(np.asarray(fw.theta), np.asarray(fc.theta))
    np.testing.assert_array_equal(np.asarray(fw.scale), np.asarray(fc.scale))
    sw, sc = np.asarray(fw.sweeps), np.asarray(fc.sweeps)
    # monotone warm-up, then steady state: once the scale settles every
    # warm iteration takes ONE sweep
    assert np.all(np.diff(sw) <= 0), sw
    assert np.all(sw[-4:] == 1), sw
    assert np.all(sw <= sc)


def test_lts_warm_equals_cold_and_steady_state():
    X, y = _regression(21, N)
    key = jax.random.PRNGKey(0)
    fw = robust.lts_fit(key, X, y, n_starts=4, c_steps=6, method="binned",
                        warm=True)
    fc = robust.lts_fit(key, X, y, n_starts=4, c_steps=6, method="binned",
                        warm=False)
    np.testing.assert_array_equal(np.asarray(fw.theta), np.asarray(fc.theta))
    np.testing.assert_array_equal(np.asarray(fw.objective),
                                  np.asarray(fc.objective))
    sw, sc = np.asarray(fw.sweeps), np.asarray(fc.sweeps)  # (c_steps, B)
    assert np.all(sw <= sc)
    # steady state: the final concentration step averages ~1 sweep per start
    assert float(sw[-1].mean()) <= 2.0, sw
    assert np.any(sw[1:] == 1), sw


def test_theil_sen_warm_equals_cold():
    rng = np.random.default_rng(22)
    n = 1500
    x = rng.standard_normal(n).astype(np.float32)
    y = (1.5 * x - 0.5 + 0.05 * rng.standard_normal(n)).astype(np.float32)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    cold = robust.theil_sen_fit(xj, yj)
    warm_fit = robust.theil_sen_fit(xj, yj, prior=cold)
    np.testing.assert_array_equal(np.asarray(warm_fit.theta),
                                  np.asarray(cold.theta))
    warm_pair = robust.theil_sen_fit(xj, yj,
                                     prior=(cold.slope, cold.intercept))
    np.testing.assert_array_equal(np.asarray(warm_pair.theta),
                                  np.asarray(cold.theta))


# ---------------------------------------------------------------------------
# drifting stream
# ---------------------------------------------------------------------------


def test_stream_tracker_steady_state_and_exact():
    rng = np.random.default_rng(23)
    base = rng.standard_normal(N).astype(np.float32)
    t = stream.QuantileTracker(0.5, method="binned")
    for tick in range(5):
        x = base + 0.001 * tick * rng.standard_normal(N).astype(np.float32)
        res = t.update(x)
        coldv = selection.quantile(jnp.asarray(x), 0.5,
                                   method="binned").value
        assert np.asarray(res.value) == np.asarray(coldv)
    assert t.sweeps[-1] == 1, t.sweeps
    assert all(s <= t.sweeps[0] for s in t.sweeps)
    t.reset()
    assert t.prior is None and t.sweeps == []


def test_stream_reselect_survives_regime_change():
    """A stream whose distribution jumps mid-flight: warm re-selection on
    the jumped tick still returns the exact answer."""
    rng = np.random.default_rng(24)
    a = rng.standard_normal(N).astype(np.float32)
    b = (100.0 + 50.0 * rng.standard_normal(N)).astype(np.float32)
    k = N // 2
    _, pr = stream.reselect(jnp.asarray(a), k, method="binned")
    res, pr = stream.reselect(jnp.asarray(b), k, prior=pr, method="binned")
    assert np.asarray(res.value) == kth(b, k)
    # and re-selecting the SAME regime again is one sweep
    res2, _ = stream.reselect(jnp.asarray(b), k, prior=pr, method="binned")
    assert np.asarray(res2.value) == kth(b, k)
    assert int(res2.iters) <= 1


# ---------------------------------------------------------------------------
# prior normalization
# ---------------------------------------------------------------------------


def test_as_prior_forms():
    x = jnp.asarray(_data(25))
    k = N // 2
    cold = selection.order_statistic(x, k, method="binned")
    # SelectResult, Prior, bare scalar: all accepted, all bit-exact
    for pr in (cold, selection.as_prior(cold), cold.value, 0.0):
        warm = selection.order_statistic(x, k, method="binned", prior=pr)
        assert np.asarray(warm.value) == np.asarray(cold.value)
    assert selection.as_prior(None) is None
    p = selection.as_prior(1.5)
    assert isinstance(p, selection.Prior)
    assert float(p.y_lo) == float(p.y_hi) == 1.5


def test_prior_is_traced_not_static():
    """Same jitted callsite must serve different prior VALUES without
    retracing (prior is a traced pytree leaf set, not a static arg)."""
    x = jnp.asarray(_data(26))
    k = N // 2
    cold = selection.order_statistic(x, k, method="binned")
    shifted = selection.Prior(*(f + 1.0 for f in selection.as_prior(cold)))
    w1 = selection.order_statistic(x, k, method="binned", prior=cold)
    w2 = selection.order_statistic(x, k, method="binned", prior=shifted)
    assert np.asarray(w1.value) == np.asarray(w2.value)
