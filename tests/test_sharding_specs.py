"""Unit tests for the sharding-rule layer (param/cache specs, plans).

These are pure-metadata tests (no device mesh needed beyond construction):
every spec must be structurally valid — each mesh axis at most once, every
sharded dim divisible by its axis product.
"""
import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch import inputs as I
from repro.launch.mesh import make_plan
from repro.models import model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def mesh():
    # metadata-only 16x16 mesh over the single CPU device (AbstractMesh-like
    # construction is enough for spec validation; nothing is compiled here)
    import jax.sharding as js
    devs = np.array(jax.devices() * 256).reshape(16, 16)
    if hasattr(js, "AxisType"):
        return js.Mesh(devs, ("data", "model"),
                       axis_types=(js.AxisType.Auto,) * 2)
    return js.Mesh(devs, ("data", "model"))


def _axes_of(spec):
    for entry in spec:
        if entry is None:
            continue
        if isinstance(entry, str):
            yield entry
        else:
            yield from entry


def check_specs(shapes_tree, specs_tree, mesh):
    flat_s = jax.tree.leaves(shapes_tree)
    flat_p = jax.tree.leaves(
        specs_tree, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for leaf, spec in zip(flat_s, flat_p):
        # no duplicate axes in one spec
        axes = list(_axes_of(spec))
        assert len(axes) == len(set(axes)), (spec, leaf.shape)
        # divisibility for every sharded dim
        parts = list(spec) + [None] * (leaf.ndim - len(spec))
        for d, entry in zip(leaf.shape, parts):
            if entry is None:
                continue
            size = 1
            for a in ((entry,) if isinstance(entry, str) else entry):
                size *= mesh.shape[a]
            assert d % size == 0, (spec, leaf.shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_valid_all_archs(arch, mesh):
    cfg = get_config(arch)
    plan = make_plan(cfg, SHAPES["train_4k"], mesh)
    shapes = I.params_shapes(cfg)
    specs = model.param_specs(shapes, cfg, plan)
    check_specs(shapes, specs, mesh)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_valid(arch, shape_name, mesh):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        pytest.skip("long_500k: full-attention arch")
    plan = make_plan(cfg, shape, mesh)
    cshapes = I.cache_shapes(cfg, shape, plan)
    cspecs = model.cache_specs(cshapes, cfg, plan)
    check_specs(cshapes, cspecs, mesh)


def test_long_context_plan_uses_seq_axes(mesh):
    cfg = get_config("gemma3-27b")
    plan = make_plan(cfg, SHAPES["long_500k"], mesh)
    assert plan.seq_axes == ("data", "model")
    assert plan.dp_axes == ()


def test_fsdp_plan_requires_non_moe(mesh):
    with pytest.raises(AssertionError):
        make_plan(get_config("mixtral-8x7b"), SHAPES["train_4k"], mesh,
                  strategy="fsdp")
    plan = make_plan(get_config("gemma2-2b"), SHAPES["train_4k"], mesh,
                     strategy="fsdp")
    assert plan.tp_axis is None and plan.fsdp_axis == ("data", "model")


def test_zero1_sharding_extends_opt_state(mesh):
    from repro.train.step import train_state_specs
    cfg = get_config("phi3-mini-3.8b")  # fsdp off: zero1 has room to act
    plan = make_plan(cfg, SHAPES["train_4k"], mesh)
    opt = I.pick_optimizer(cfg)
    state = I.state_shapes(cfg, opt)
    specs = train_state_specs(state, cfg, plan)
    # at least one m-state leaf gains a 'data' axis beyond its param spec
    pl = jax.tree.leaves(specs.params, is_leaf=lambda x: isinstance(x, P))
    ml = jax.tree.leaves(specs.opt["m"], is_leaf=lambda x: isinstance(x, P))
    gained = sum(
        1 for ps, ms in zip(pl, ml)
        if "data" in list(_axes_of(ms)) and "data" not in list(_axes_of(ps)))
    assert gained > 0
    check_specs(state.opt["m"], specs.opt["m"], mesh)
