"""Subprocess worker for the one-sweep multi-k distributed tests.

Run as:  python tests/_dist_multi_k_worker.py <n_devices>
Sets XLA_FLAGS *before* importing jax, then checks on an n = 1M array that
K = 8 deciles resolve through ``sharded_multi_order_statistic`` /
``sharded_quantiles`` with ONE psum of the (K, nbins+2) slot matrix:

* exactness of every decile vs per-k np.partition (counting measure) and
  vs the f64 sorted-cumsum oracle (weighted measure);
* the round-count claim: with ``nbins=512, cap_local=4096`` every bracket
  localizes under the per-shard cap after a single wide sweep, so
  ``iters.max() == 1`` — one collective for the whole decile vector where
  naive per-k dispatch would pay K full descents.

Exits nonzero on failure.
"""
import sys

from _dist_env import force_device_count

n_dev = int(sys.argv[1]) if len(sys.argv) > 1 else 4
force_device_count(n_dev)  # must run BEFORE the jax import below

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import _compat, distributed  # noqa: E402

assert jax.device_count() == n_dev, jax.devices()

NBINS = 512  # one wide sweep localizes all 8 deciles under cap_local
CAP_LOCAL = 4096


def check(cond, msg):
    if not cond:
        print("FAIL:", msg)
        sys.exit(1)


def main():
    mesh = _compat.make_mesh((n_dev,), ("data",))
    rng = np.random.default_rng(42)
    n = 1 << 20
    x = rng.standard_normal(n).astype(np.float32)
    xj = jnp.asarray(x)
    qs = [i / 10.0 for i in range(1, 9)]  # 8 deciles
    ks = np.asarray([int(np.ceil(q * n)) for q in qs], np.int32)
    want = np.partition(x, ks - 1)[ks - 1]

    # --- counting measure: K=8 deciles, 1 psum round ---------------------
    res = distributed.sharded_multi_order_statistic(
        xj, jnp.asarray(ks), mesh, P("data"), method="binned",
        nbins=NBINS, cap_local=CAP_LOCAL)
    np.testing.assert_array_equal(np.asarray(res.value), want)
    rounds = int(np.max(np.asarray(res.iters)))
    check(rounds == 1, f"decile vector took {rounds} psum rounds, not 1")

    # quantile-fraction front door resolves ranks host-side (f64) and
    # routes through the same one-sweep engine
    res_q = distributed.sharded_quantiles(
        xj, qs, mesh, P("data"), method="binned",
        nbins=NBINS, cap_local=CAP_LOCAL)
    np.testing.assert_array_equal(np.asarray(res_q.value), want)
    check(int(np.max(np.asarray(res_q.iters))) == 1, "quantiles rounds != 1")

    # polish steering stays exact on the same knobs
    res_p = distributed.sharded_multi_order_statistic(
        xj, jnp.asarray(ks), mesh, P("data"), method="binned_polish",
        nbins=NBINS, cap_local=CAP_LOCAL)
    np.testing.assert_array_equal(np.asarray(res_p.value), want)
    check(int(np.max(np.asarray(res_p.iters))) == 1, "polish rounds != 1")

    # --- weighted measure ------------------------------------------------
    w = rng.integers(0, 5, n).astype(np.float32)
    w[0] = 1.0
    o = np.argsort(x, kind="stable")
    cumw = np.cumsum(w[o].astype(np.float64))
    W = float(w.sum())
    wks = np.asarray([np.float32(q * W) for q in qs], np.float32)
    wwant = np.array(
        [x[o][min(np.searchsorted(cumw, t, "left"), n - 1)] for t in wks],
        np.float32)
    wres = distributed.sharded_multi_order_statistic(
        xj, jnp.asarray(wks), mesh, P("data"), method="binned",
        nbins=NBINS, cap_local=CAP_LOCAL, weights=jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(wres.value), wwant)
    wrounds = int(np.max(np.asarray(wres.iters)))
    check(wrounds == 1, f"weighted deciles took {wrounds} rounds, not 1")

    print("OK")


if __name__ == "__main__":
    main()
