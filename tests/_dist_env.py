"""Shared XLA_FLAGS handling for the multi-device subprocess tests.

The workers force a host-device count via
``--xla_force_host_platform_device_count``; both the parent test (building
the subprocess env) and the workers themselves (prepending their own count)
must drop ONLY a stale device-count flag and preserve every other caller
flag.  Keep this the single implementation — it is imported by the test
modules and by the workers (before jax is imported; this module must stay
jax-free).
"""
import os


def strip_device_count(flags: str) -> list[str]:
    """Drop any ``--xla_force_host_platform_device_count`` flag, keep the
    rest (order preserved)."""
    return [f for f in flags.split()
            if not f.startswith("--xla_force_host_platform_device_count")]


def subprocess_env(root: str) -> dict:
    """Env for a worker subprocess: PYTHONPATH to ``src``, XLA_FLAGS
    preserved minus a stale device-count (the worker prepends its own)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    flags = strip_device_count(env.get("XLA_FLAGS", ""))
    if flags:
        env["XLA_FLAGS"] = " ".join(flags)
    else:
        env.pop("XLA_FLAGS", None)
    return env


def force_device_count(n_dev: int) -> None:
    """Worker-side: set XLA_FLAGS to force ``n_dev`` host devices while
    preserving the caller's other flags.  Call BEFORE importing jax."""
    os.environ["XLA_FLAGS"] = " ".join(
        [f"--xla_force_host_platform_device_count={n_dev}"]
        + strip_device_count(os.environ.get("XLA_FLAGS", "")))
