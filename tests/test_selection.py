"""Unit + property tests for repro.core.selection against numpy oracles.

Covers the paper's nine data distributions (Sec. V-A), the outlier stress
cases (Sec. V-D), ties, tiny arrays, and all iterative methods.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import selection
from repro.core.objective import eval_fg

jax.config.update("jax_platform_name", "cpu")


def paper_distributions(rng, n):
    """The nine datasets of Sec. V-A."""
    half = lambda m: np.abs(rng.standard_normal(m))
    mix = lambda a, b, frac: np.concatenate(
        [a[: int(n * frac)], b[: n - int(n * frac)]]
    )
    return {
        "uniform": rng.random(n),
        "normal": rng.standard_normal(n),
        "halfnormal": half(n),
        "beta25": rng.beta(2, 5, n),
        "mix1": mix(rng.standard_normal(n), rng.normal(100, 1, n), 2 / 3),
        "mix2": mix(rng.standard_normal(n) + 1, rng.normal(100, 1, n), 0.5),
        "mix3": mix(half(n), np.full(n, 10.0), 0.9),
        "mix4": mix(half(n), rng.normal(100, 1, n), 2 / 3),
        "mix5": mix(half(n) + 1, rng.normal(100, 1, n), 0.5),
    }


def exact_kth(x, k):
    return np.partition(np.asarray(x), k - 1)[k - 1]


@pytest.mark.parametrize("name", [
    "uniform", "normal", "halfnormal", "beta25",
    "mix1", "mix2", "mix3", "mix4", "mix5",
])
def test_median_all_distributions(name):
    rng = np.random.default_rng(0)
    n = 100_001
    x = paper_distributions(rng, n)[name].astype(np.float32)
    k = (n + 1) // 2
    res = selection.median(jnp.asarray(x))
    assert res.status != selection.NOT_CONVERGED
    np.testing.assert_equal(np.float32(res.value), exact_kth(x, k))


@pytest.mark.parametrize("method", ["cp", "bisection", "golden", "brent", "sort"])
@pytest.mark.parametrize("k_frac", [0.1, 0.25, 0.5, 0.9])
def test_order_statistics_methods(method, k_frac):
    rng = np.random.default_rng(1)
    n = 20_000
    x = rng.standard_normal(n).astype(np.float32)
    k = max(1, int(k_frac * n))
    maxit = 64 if method in ("cp", "sort") else 256
    res = selection.order_statistic(jnp.asarray(x), k, method=method, maxit=maxit)
    np.testing.assert_equal(np.float32(res.value), exact_kth(x, k))


def test_cp_converges_in_few_iterations():
    """Paper: <30 iterations for n up to 32M; we check a 1M array."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal(1 << 20).astype(np.float32))
    res = selection.median(x)
    assert int(res.iters) <= 30
    assert res.status != selection.NOT_CONVERGED


def test_cp_insensitive_to_outliers_bisection_is_not():
    """Fig. 5: one element at 1e9 stalls bisection, not cutting planes."""
    rng = np.random.default_rng(3)
    x = rng.standard_normal(100_000).astype(np.float32)
    x[0] = 1e9
    k = (x.size + 1) // 2
    xa = jnp.asarray(x)
    cap = 4096
    r_cp = selection.order_statistic(xa, k, method="cp", cap=cap)
    r_bi = selection.order_statistic(xa, k, method="bisection", maxit=64, cap=cap)
    np.testing.assert_equal(np.float32(r_cp.value), exact_kth(x, k))
    assert int(r_cp.iters) <= 25
    # bisection spends its budget walking the huge empty range
    assert int(r_bi.iters) > int(r_cp.iters)


def test_extreme_values_log_transform():
    """Sec. V-D: components ~1e20 break plain f32 summation; log1p guard."""
    rng = np.random.default_rng(4)
    x = rng.standard_normal(65_536).astype(np.float32)
    x[:16] = 1e20
    k = (x.size + 1) // 2
    res = selection.order_statistic(jnp.asarray(x), k, transform="log1p")
    np.testing.assert_equal(np.float32(res.value), exact_kth(x, k))


def test_ties_heavier_than_cap():
    """> cap duplicates of the answer exercise the tie fallback."""
    rng = np.random.default_rng(5)
    x = np.concatenate([
        rng.standard_normal(10_000),
        np.full(30_000, 0.5, np.float32),
        rng.standard_normal(10_000) + 50.0,
    ]).astype(np.float32)
    rng.shuffle(x)
    k = (x.size + 1) // 2  # the median sits inside the tie block
    res = selection.order_statistic(jnp.asarray(x), k, cap=256, maxit=64)
    np.testing.assert_equal(np.float32(res.value), exact_kth(x, k))
    assert res.status in (selection.EXACT_HIT, selection.TIE_FALLBACK,
                          selection.HYBRID_SORT)


def test_integer_valued_data_all_ties():
    rng = np.random.default_rng(6)
    x = rng.integers(0, 7, 50_001).astype(np.float32)
    for k in [1, 2, 25_000, 25_001, 50_000, 50_001]:
        res = selection.order_statistic(jnp.asarray(x), k, cap=128)
        np.testing.assert_equal(np.float32(res.value), exact_kth(x, k),
                                err_msg=f"k={k}")


def test_all_equal_and_tiny():
    x = jnp.full((1000,), 3.25, jnp.float32)
    assert float(selection.median(x).value) == 3.25
    for n in [1, 2, 3, 5]:
        rng = np.random.default_rng(n)
        x = rng.standard_normal(n).astype(np.float32)
        for k in range(1, n + 1):
            res = selection.order_statistic(jnp.asarray(x), k, cap=4)
            np.testing.assert_equal(np.float32(res.value), exact_kth(x, k))


def test_permutation_invariance():
    """Expression (1) is permutation invariant (paper Sec. V-D)."""
    rng = np.random.default_rng(7)
    x = rng.standard_normal(9_999).astype(np.float32)
    v1 = selection.median(jnp.asarray(x)).value
    v2 = selection.median(jnp.asarray(np.sort(x))).value
    v3 = selection.median(jnp.asarray(np.sort(x)[::-1].copy())).value
    assert float(v1) == float(v2) == float(v3)


def test_subgradient_certificate():
    """0 in [g_lo, g_hi] at y  <=>  n_lt < k <= n_le  <=>  y = x_(k)."""
    rng = np.random.default_rng(8)
    x = rng.standard_normal(101).astype(np.float32)
    k = 51
    xk = exact_kth(x, k)
    fg = eval_fg(jnp.asarray(x), xk, k)
    assert float(fg.g_lo) <= 0.0 <= float(fg.g_hi)
    assert int(fg.n_lt) < k <= int(fg.n_le)
    fg2 = eval_fg(jnp.asarray(x), exact_kth(x, k + 3), k)
    assert not (float(fg2.g_lo) <= 0.0 <= float(fg2.g_hi))


def test_quantile_and_topk():
    rng = np.random.default_rng(9)
    x = rng.random(12_345).astype(np.float32)
    r = selection.quantile(jnp.asarray(x), 0.99)
    k = int(np.ceil(0.99 * x.size))
    np.testing.assert_equal(np.float32(r.value), exact_kth(x, k))
    r2 = selection.topk_threshold(jnp.asarray(x), 10)
    np.testing.assert_equal(np.float32(r2.value), np.sort(x)[-10])


def test_jit_and_traced_k():
    """k may be a traced value; whole pipeline is jit-compatible."""
    rng = np.random.default_rng(10)
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))

    @jax.jit
    def f(x, k):
        return selection.order_statistic(x, k).value

    for k in [1, 17, 2048, 4096]:
        np.testing.assert_equal(np.float32(f(x, k)),
                                exact_kth(np.asarray(x), k))


def test_multi_order_statistic():
    """Batched selection: several k against the same array in one solve."""
    rng = np.random.default_rng(11)
    x = rng.standard_normal(30_000).astype(np.float32)
    ks = [1, 300, 15_000, 29_700, 30_000]
    res = selection.multi_order_statistic(jnp.asarray(x), ks)
    for i, k in enumerate(ks):
        np.testing.assert_equal(np.float32(res.value[i]), exact_kth(x, k),
                                err_msg=f"k={k}")


def test_quantiles_vector():
    rng = np.random.default_rng(12)
    x = np.abs(rng.standard_normal(10_000)).astype(np.float32)
    qs = [0.25, 0.5, 0.75, 0.99]
    res = selection.quantiles(jnp.asarray(x), qs)
    for i, q in enumerate(qs):
        k = int(np.ceil(q * x.size))
        np.testing.assert_equal(np.float32(res.value[i]), exact_kth(x, k))
