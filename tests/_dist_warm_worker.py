"""Subprocess worker for the warm distributed re-selection tests.

Run as:  python tests/_dist_warm_worker.py <n_devices>
Sets XLA_FLAGS *before* importing jax (preserving caller flags other than a
stale device-count), then checks on an n = 1M array:

* warm re-selection (``prior=`` the previous round's replicated result)
  resolves in ONE psum round where the cold run takes >= 2, with a
  bit-identical value — both measures;
* a drifted re-selection (same array + tiny perturbation) stays exact and
  cheap; a 100%-replaced array with a stale prior stays exact (extra
  rounds allowed, never a wrong value);
* an adversarial NaN/inf prior never affects the value.

Exits nonzero on failure.
"""
import sys

from _dist_env import force_device_count

n_dev = int(sys.argv[1]) if len(sys.argv) > 1 else 4
force_device_count(n_dev)  # must run BEFORE the jax import below

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import _compat, distributed, selection  # noqa: E402

assert jax.device_count() == n_dev, jax.devices()


def check(cond, msg):
    if not cond:
        print("FAIL:", msg)
        sys.exit(1)


def main():
    mesh = _compat.make_mesh((n_dev,), ("data",))
    rng = np.random.default_rng(0)
    n = 1 << 20
    x = rng.standard_normal(n).astype(np.float32)
    xj = jnp.asarray(x)
    k = (n + 1) // 2
    want = np.partition(x, k - 1)[k - 1]

    # --- counting measure: cold >= 2 rounds, warm exactly 1 --------------
    cold = distributed.sharded_order_statistic(xj, k, mesh, P("data"),
                                               method="binned")
    check(np.float32(cold.value) == want, f"cold {cold.value} != {want}")
    check(int(cold.iters) >= 2,
          f"cold unexpectedly took {int(cold.iters)} round(s)")
    warm = distributed.sharded_order_statistic(
        xj, k, mesh, P("data"), method="binned",
        prior=selection.as_prior(jax.tree.map(jnp.asarray, cold)))
    check(np.float32(warm.value) == want, f"warm {warm.value} != {want}")
    check(int(warm.iters) == 1,
          f"warm rounds at 1M: {int(warm.iters)} != 1")

    # --- drifted re-selection: still exact, still cheap ------------------
    x2 = x + 1e-4 * rng.standard_normal(n).astype(np.float32)
    want2 = np.partition(x2, k - 1)[k - 1]
    drift = distributed.sharded_order_statistic(
        jnp.asarray(x2), k, mesh, P("data"), method="binned",
        prior=selection.as_prior(cold))
    check(np.float32(drift.value) == want2,
          f"drift {drift.value} != {want2}")
    check(int(drift.iters) <= int(cold.iters),
          f"drift rounds {int(drift.iters)} > cold {int(cold.iters)}")

    # --- stale prior after 100% replacement: exact, rounds may differ ----
    x3 = (100.0 + 50.0 * rng.standard_normal(n)).astype(np.float32)
    want3 = np.partition(x3, k - 1)[k - 1]
    stale = distributed.sharded_order_statistic(
        jnp.asarray(x3), k, mesh, P("data"), method="binned",
        prior=selection.as_prior(cold))
    check(np.float32(stale.value) == want3,
          f"stale {stale.value} != {want3}")

    # --- adversarial prior: NaN/inf fields never affect the value -------
    bad = selection.Prior(value=jnp.asarray(jnp.nan),
                          y_lo=jnp.asarray(-jnp.inf),
                          y_hi=jnp.asarray(jnp.inf),
                          cut=jnp.asarray(jnp.nan))
    adv = distributed.sharded_order_statistic(xj, k, mesh, P("data"),
                                              method="binned", prior=bad)
    check(np.float32(adv.value) == want, f"adv {adv.value} != {want}")

    # --- weighted measure: warm == cold value, 1 psum round --------------
    w = rng.integers(1, 4, n).astype(np.float32)
    o = np.argsort(x, kind="stable")
    cumw = np.cumsum(w[o].astype(np.float64))
    wk = float(np.float32(0.5 * w.sum()))
    wwant = x[o][min(np.searchsorted(cumw, wk, "left"), n - 1)]
    wcold = distributed.sharded_weighted_order_statistic(
        xj, jnp.asarray(w), wk, mesh, P("data"), method="binned")
    check(np.float32(wcold.value) == wwant,
          f"wcold {wcold.value} != {wwant}")
    wwarm = distributed.sharded_weighted_order_statistic(
        xj, jnp.asarray(w), wk, mesh, P("data"), method="binned",
        prior=selection.as_prior(wcold))
    check(np.float32(wwarm.value) == wwant,
          f"wwarm {wwarm.value} != {wwant}")
    check(int(wwarm.iters) == 1,
          f"wwarm rounds at 1M: {int(wwarm.iters)} != 1")
    check(int(wwarm.iters) <= int(wcold.iters), "wwarm costlier than cold")

    print("OK")


if __name__ == "__main__":
    main()
