"""Exactness + scheduling tests for the binned bracket-descent method.

``method='binned'`` must match ``np.partition`` bit-for-bit everywhere the
cutting-plane engine does — duplicate-heavy rows, constant rows, extreme
magnitudes, the log1p monotone guard — while resolving in a handful of
histogram sweeps (the perf claim: ~3 data passes where cp needs ~15).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import selection

jax.config.update("jax_platform_name", "cpu")


def kth_rows(x, ks):
    x = np.asarray(x)
    ks = np.broadcast_to(np.asarray(ks), (x.shape[0],))
    return np.array([np.partition(row, k - 1)[k - 1]
                     for row, k in zip(x, ks)], x.dtype)


# ---------------------------------------------------------------------------
# rows mode: property sweep vs np.partition
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,n", [(1, 1000), (8, 4096), (33, 257),
                                 (4, 100_000)])
def test_binned_rows_match_partition(b, n):
    rng = np.random.default_rng(b * n)
    x = rng.standard_normal((b, n)).astype(np.float32)
    ks = rng.integers(1, n + 1, size=b).astype(np.int32)
    res = selection.select_rows(jnp.asarray(x), jnp.asarray(ks),
                                method="binned")
    np.testing.assert_array_equal(np.asarray(res.value), kth_rows(x, ks))
    assert int(jnp.max(res.status)) <= selection.TIE_FALLBACK


def test_binned_duplicate_heavy_rows_tiny_cap():
    """Mostly ties, answers inside tie blocks, cap far below tie counts."""
    rng = np.random.default_rng(1)
    b, n = 6, 5000
    x = rng.integers(0, 4, size=(b, n)).astype(np.float32)
    ks = rng.integers(1, n + 1, size=b).astype(np.int32)
    res = selection.select_rows(jnp.asarray(x), jnp.asarray(ks),
                                method="binned", cap=8)
    np.testing.assert_array_equal(np.asarray(res.value), kth_rows(x, ks))
    assert np.all(np.asarray(res.status) != selection.NOT_CONVERGED)


def test_binned_constant_rows_and_extreme_k():
    rng = np.random.default_rng(2)
    n = 3000
    x = np.stack([
        np.full(n, 3.25),
        rng.standard_normal(n),
        np.full(n, -7.0),
        rng.standard_normal(n),
    ]).astype(np.float32)
    for ks in ([1] * 4, [n] * 4, [1, 2, n - 1, n], [n // 2] * 4):
        res = selection.select_rows(jnp.asarray(x),
                                    jnp.asarray(ks, jnp.int32),
                                    method="binned", cap=16)
        np.testing.assert_array_equal(np.asarray(res.value), kth_rows(x, ks))


def test_binned_extreme_magnitudes_with_log1p():
    """1e20-scale components: binned sweeps run on the log1p image and the
    bracket maps back count-preservingly — answers stay bit-exact."""
    rng = np.random.default_rng(3)
    b, n = 4, 16_384
    x = rng.standard_normal((b, n)).astype(np.float32)
    x[:, :16] = 1e20
    x[2] *= 1e10
    ks = np.array([n // 2, 1, n // 3, n], np.int32)
    res = selection.select_rows(jnp.asarray(x), jnp.asarray(ks),
                                method="binned", transform="log1p")
    np.testing.assert_array_equal(np.asarray(res.value), kth_rows(x, ks))


def test_binned_extreme_magnitudes_without_transform():
    """Raw 1e9 outlier: value-space bisection would stall; 128 bins per
    sweep keep the sweep count in the single digits and the result exact."""
    rng = np.random.default_rng(4)
    n = 200_000
    x = rng.standard_normal(n).astype(np.float32)
    x[0] = 1e9
    res = selection.order_statistic(jnp.asarray(x), n // 2, method="binned")
    np.testing.assert_equal(np.float32(res.value),
                            np.partition(x, n // 2 - 1)[n // 2 - 1])
    assert int(res.iters) <= 10


def test_binned_full_float_range_bracket():
    """Data spanning ±3e38: the naive bin width (hi-lo)/nbins overflows f32
    to inf — bin_edges must divide before differencing so the descent stays
    exact (and must never mint EXACT_HIT off inconsistent counts)."""
    rng = np.random.default_rng(40)
    n = 100_000
    x = rng.standard_normal(n).astype(np.float32)
    x[0], x[1] = 3e38, -3e38
    for k in [1, 2, n // 2, n - 1, n]:
        res = selection.order_statistic(jnp.asarray(x), k, method="binned")
        np.testing.assert_equal(np.float32(res.value),
                                np.partition(x, k - 1)[k - 1])
        assert int(res.status) != selection.NOT_CONVERGED


def test_binned_edges_overflow_safe():
    """bin_edges stays finite, monotone and inside [lo, hi] at full range."""
    from repro.kernels.ref import bin_edges

    e = np.asarray(bin_edges(jnp.float32(-3.4e38), jnp.float32(3.4e38), 128))
    assert np.all(np.isfinite(e))
    assert np.all(np.diff(e) >= 0)
    assert e[0] == np.float32(-3.4e38) and e[-1] == np.float32(3.4e38)


def test_binned_descent_step_fails_safe_on_bad_counts():
    """A cum vector that never reaches k (violated invariant) must stall,
    not certify: argmax-of-all-False must not masquerade as hit_lo."""
    from repro.kernels.ref import bin_edges

    cum = jnp.asarray([[0, 1, 2, 3]], jnp.int32)     # count(x<=yR) = 3 < k
    yL = jnp.asarray([0.0], jnp.float32)
    yR = jnp.asarray([1.0], jnp.float32)
    kk = jnp.asarray([10], jnp.int32)
    *_, hit_lo, exact, stall = selection.binned_descent_step(
        cum, bin_edges(yL, yR, 3), yL, yR, kk)
    assert not bool(exact[0])
    assert not bool(hit_lo[0])
    assert bool(stall[0])


def test_binned_tiny_normal_magnitudes():
    """Smallest-normal-scale data (1.2e-38): bin arithmetic stays exact."""
    rng = np.random.default_rng(5)
    x = (rng.integers(0, 3, 4096).astype(np.float32)) * 1.2e-38
    for k in [1, 2048, 4096]:
        res = selection.order_statistic(jnp.asarray(x), k, method="binned",
                                        cap=8)
        np.testing.assert_equal(np.float32(res.value),
                                np.partition(x, k - 1)[k - 1])


def test_binned_denormals_consistent_with_cp():
    """True denormals are flushed by XLA:CPU's counting reductions (FTZ;
    ``jnp.sort`` itself does NOT flush, so the sort baseline is excluded) —
    the honest invariant is self-consistency of the two count-based
    engines: binned must agree with cp on whatever the platform's
    comparisons see."""
    rng = np.random.default_rng(6)
    x = (rng.integers(0, 3, 2048).astype(np.float32)) * 1e-44
    for k in [1, 1024, 2048]:
        vb = selection.order_statistic(jnp.asarray(x), k,
                                       method="binned").value
        vc = selection.order_statistic(jnp.asarray(x), k,
                                       method="cp").value
        assert float(vb) == float(vc), k


def test_binned_sweep_count_vs_cp():
    """The tentpole claim at 1M elements: binned uses <= half the fused
    data passes of cp (typically 2 vs ~9)."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal(1 << 20).astype(np.float32))
    k = (x.size + 1) // 2
    sweeps = int(selection.order_statistic(x, k, method="binned").iters)
    cp_iters = int(selection.order_statistic(x, k, method="cp").iters)
    assert sweeps * 2 <= cp_iters, (sweeps, cp_iters)
    assert sweeps <= 4


def test_binned_iters_are_per_row():
    rng = np.random.default_rng(8)
    n = 100_000
    easy = np.full(n, 1.0)                      # exact at min on sweep 1
    hard = rng.standard_normal(n)
    x = np.stack([easy, hard]).astype(np.float32)
    res = selection.select_rows(jnp.asarray(x), (n + 1) // 2,
                                method="binned", cap=64)
    iters = np.asarray(res.iters)
    assert iters[0] <= iters[1]
    assert int(res.status[0]) == selection.EXACT_HIT


def test_method_resolution_is_backend_aware():
    """None/'auto' picks binned for large n on EVERY backend (the verified
    arithmetic pass made the CPU sweep competitive — the acceptance flip);
    explicit wins; nbins stays backend-tuned."""
    big = selection.BINNED_MIN_N
    assert selection._resolve_method(None, big, "pallas") == "binned"
    assert selection._resolve_method("auto", big, "pallas") == "binned"
    assert selection._resolve_method(None, big - 1, "pallas") == "cp"
    # the jnp path now flips to binned too (ROADMAP open item closed: the
    # CPU histogram pass is no longer scatter/searchsorted-bound)
    assert selection._resolve_method(None, big, None) == "binned"
    assert selection._resolve_method(None, 1 << 20, "jnp") == "binned"
    assert selection._resolve_method(None, big - 1, None) == "cp"
    assert selection._resolve_method("binned", 10, None) == "binned"
    # sweep width: wide on the kernel path, narrow on the jnp path (the
    # factored reduction's cost scales with the slot count)
    assert selection._resolve_nbins(None, "pallas") == selection.DEF_NBINS
    assert selection._resolve_nbins(None, "jnp") == selection.DEF_NBINS_JNP
    assert selection._resolve_nbins(None, None) in (
        selection.DEF_NBINS, selection.DEF_NBINS_JNP)  # TPU-dependent
    assert selection._resolve_nbins(64, "pallas") == 64
    # f64 data is rerouted off the kernels by ops, so its sweeps get the
    # jnp-tuned width even when the kernel path was requested ...
    assert selection._resolve_nbins(None, "pallas", jnp.float64) == \
        selection.DEF_NBINS_JNP
    assert selection._resolve_nbins(None, "pallas", jnp.float32) == \
        selection.DEF_NBINS
    # ... except pallas_interpret, which is deliberately not rerouted
    assert selection._resolve_nbins(None, "pallas_interpret",
                                    jnp.float64) == selection.DEF_NBINS
    with pytest.raises(ValueError):
        selection._resolve_method("nope", big, None)


def test_binned_nbins_sweep():
    """Any nbins >= 2 is exact (nbins trades sweeps for bin bookkeeping)."""
    rng = np.random.default_rng(9)
    x = rng.standard_normal(20_000).astype(np.float32)
    k = 7777
    want = np.partition(x, k - 1)[k - 1]
    for nbins in [2, 8, 32, 128, 512]:
        res = selection.order_statistic(jnp.asarray(x), k, method="binned",
                                        nbins=nbins)
        np.testing.assert_equal(np.float32(res.value), want)


# ---------------------------------------------------------------------------
# shared-x mode (multi_order_statistic / quantiles)
# ---------------------------------------------------------------------------


def test_binned_shared_exact():
    rng = np.random.default_rng(10)
    n = 50_001
    x = rng.standard_normal(n).astype(np.float32)
    ks = np.array([1, 7, n // 4, n // 2, n - 1, n], np.int32)
    res = selection.multi_order_statistic(jnp.asarray(x), jnp.asarray(ks),
                                          method="binned")
    want = np.partition(x, ks - 1)[ks - 1]
    np.testing.assert_array_equal(np.asarray(res.value), want)
    assert np.all(np.asarray(res.status) != selection.NOT_CONVERGED)


def test_binned_shared_duplicate_heavy():
    rng = np.random.default_rng(11)
    x = rng.integers(0, 5, 30_000).astype(np.float32)
    ks = np.array([1, 10_000, 15_000, 29_999], np.int32)
    res = selection.multi_order_statistic(jnp.asarray(x), jnp.asarray(ks),
                                          method="binned", cap=8)
    want = np.partition(x, ks - 1)[ks - 1]
    np.testing.assert_array_equal(np.asarray(res.value), want)


def test_binned_shared_log1p():
    rng = np.random.default_rng(12)
    n = 32_768
    x = rng.standard_normal(n).astype(np.float32)
    x[:16] = 1e20
    ks = np.array([n // 4, n // 2, n], np.int32)
    res = selection.multi_order_statistic(jnp.asarray(x), jnp.asarray(ks),
                                          method="binned", transform="log1p")
    want = np.partition(x, ks - 1)[ks - 1]
    np.testing.assert_array_equal(np.asarray(res.value), want)


def test_binned_shared_interpret_kernel_parity():
    """Shared-x binned solve driven by the multi-bracket Pallas kernel
    (interpret mode) matches the jnp-oracle-driven solve bit for bit."""
    rng = np.random.default_rng(13)
    n = 4096
    x = rng.standard_normal(n).astype(np.float32)
    ks = np.array([1, 100, 2048, 4096], np.int32)
    res_jnp = selection.multi_order_statistic(
        jnp.asarray(x), jnp.asarray(ks), method="binned", backend="jnp")
    res_pal = selection.multi_order_statistic(
        jnp.asarray(x), jnp.asarray(ks), method="binned",
        backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(res_jnp.value),
                                  np.asarray(res_pal.value))
    want = np.partition(x, ks - 1)[ks - 1]
    np.testing.assert_array_equal(np.asarray(res_jnp.value), want)


# ---------------------------------------------------------------------------
# x64: the f64 dispatch fix (kernels would downcast; ops must reroute)
# ---------------------------------------------------------------------------


def test_x64_parity_sub_f32_resolution():
    """f64 data distinguishable only below f32 resolution must select
    exactly — the Pallas backend reroutes to the dtype-preserving oracle."""
    import jax.experimental

    from repro.kernels import ops

    with jax.experimental.enable_x64():
        base = 1.0
        eps = 1e-12  # far below f32 ulp at 1.0 (~1.2e-7)
        vals = np.array([base + i * eps for i in range(-40, 41)], np.float64)
        rng = np.random.default_rng(14)
        rng.shuffle(vals)
        x = jnp.asarray(vals)
        assert x.dtype == jnp.float64
        for k in [1, 3, 41, 80, 81]:
            want = np.partition(vals, k - 1)[k - 1]
            for method in ["cp", "binned"]:
                res = selection.order_statistic(x, k, method=method, cap=4)
                assert float(res.value) == want, (method, k)
        # explicit pallas request on f64 lands on the oracle: counts see
        # sub-f32 structure (an f32 kernel would collapse all ties onto y)
        y = jnp.float64(base + eps / 2)
        sp, sn, lt, le = ops.fused_partials(x, y, backend="pallas")
        assert int(lt) == int(np.sum(vals < base + eps / 2))
        assert int(le) == int(lt)
        from repro.kernels.ref import bin_edges
        edges64 = bin_edges(jnp.float64(base - 50 * eps),
                            jnp.float64(base + 50 * eps), 64)
        cnt, bsum = ops.fused_histogram(x, edges64, backend="pallas")
        assert bsum.dtype == jnp.float64
        assert int(jnp.sum(cnt)) == vals.size


# ---------------------------------------------------------------------------
# across-axis binned / auto (single-device mesh; multi-device in
# tests/_dist_worker.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["binned", "auto"])
def test_across_axis_binned_single_device(method):
    from jax.sharding import PartitionSpec as P

    from repro.core import _compat, distributed

    mesh = _compat.make_mesh((1,), ("data",))
    rng = np.random.default_rng(15)
    v = rng.standard_normal((1, 17)).astype(np.float32)

    def run(vl):
        return distributed.median_across_axis(vl, "data", method=method)

    got = _compat.shard_map(run, mesh=mesh, in_specs=P("data"),
                            out_specs=P("data"), check=False)(jnp.asarray(v))
    np.testing.assert_allclose(np.asarray(got)[0], v[0])


def test_sharded_binned_single_device():
    from jax.sharding import PartitionSpec as P

    from repro.core import _compat, distributed

    mesh = _compat.make_mesh((1,), ("data",))
    rng = np.random.default_rng(16)
    x = rng.standard_normal(10_000).astype(np.float32)
    for k in [1, 2500, 10_000]:
        res = distributed.sharded_order_statistic(
            jnp.asarray(x), k, mesh, P("data"), method="binned")
        assert np.float32(res.value) == np.partition(x, k - 1)[k - 1]
