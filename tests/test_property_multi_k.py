"""Property-based differentials for the one-sweep multi-k binned path.

Hypothesis drives ``multi_order_statistic`` / ``weighted_multi_order_statistic``
(methods ``binned`` and ``binned_polish`` — the shared-x one-sweep engine) and
``segmented_quantiles`` against per-k ``np.partition`` / an f64 sorted-cumsum
weighted oracle, asserting BIT-EXACTNESS.  Strategy notes match
tests/test_property_selection.py: dyadic integer-derived floats maximize tie
coverage and keep weighted masses exactly summable; ``scale_exp`` spans
denormal-adjacent (2^-30) to inf-adjacent magnitudes.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import selection  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


def to_f32(ints, scale_exp=0):
    x = np.asarray(ints, np.float64) * (2.0 ** (scale_exp - 10))
    return x.astype(np.float32)


def weighted_oracle(x, w, wk):
    o = np.argsort(x, kind="stable")
    xs, ws = np.asarray(x)[o], np.asarray(w)[o]
    c = np.cumsum(ws.astype(np.float64))
    i = np.searchsorted(c, wk, side="left")
    return xs[min(i, len(xs) - 1)]


ints_small = st.lists(st.integers(-(2**20), 2**20), min_size=2, max_size=260)
# duplicate-heavy: values drawn from a handful of levels (tie storms are the
# hard case for a shared-x descent — every k's bracket collapses onto the
# same handful of realized values)
ints_dupes = st.lists(st.integers(-4, 4), min_size=2, max_size=260)
scale_exps = st.integers(min_value=-20, max_value=97)
methods = st.sampled_from(["binned", "binned_polish"])


@settings(max_examples=40, deadline=None)
@given(ints=ints_small, scale_exp=scale_exps, method=methods,
       data=st.data())
def test_multi_k_one_sweep_bit_exact(ints, scale_exp, method, data):
    """K brackets narrowing off ONE histogram sweep per round must land on
    exactly the same elements as K independent np.partition calls."""
    x = to_f32(ints, scale_exp)
    n = x.size
    ks = np.asarray(
        data.draw(st.lists(st.integers(1, n), min_size=1, max_size=8)),
        np.int32)
    res = selection.multi_order_statistic(
        jnp.asarray(x), jnp.asarray(ks), method=method, backend="jnp",
        maxit=256, cap=8)
    want = np.partition(x, ks - 1)[ks - 1]
    np.testing.assert_array_equal(np.asarray(res.value), want)


@settings(max_examples=30, deadline=None)
@given(ints=ints_dupes, scale_exp=scale_exps, data=st.data())
def test_multi_k_duplicate_storms(ints, scale_exp, data):
    """Handfuls of levels: many ladders straddle the SAME tie block, so the
    per-ladder certificates must each resolve independently."""
    x = to_f32(ints, scale_exp)
    n = x.size
    ks = np.asarray(
        data.draw(st.lists(st.integers(1, n), min_size=1, max_size=8)),
        np.int32)
    want = np.partition(x, ks - 1)[ks - 1]
    for method in ["binned", "binned_polish"]:
        res = selection.multi_order_statistic(
            jnp.asarray(x), jnp.asarray(ks), method=method, backend="jnp",
            maxit=256, cap=4)
        np.testing.assert_array_equal(np.asarray(res.value), want)


@settings(max_examples=30, deadline=None)
@given(ints=ints_small, scale_exp=scale_exps, method=methods,
       data=st.data())
def test_weighted_multi_k_one_sweep_bit_exact(ints, scale_exp, method, data):
    """Weighted measure leg of the shared-x sweep vs the f64 cumsum oracle."""
    x = to_f32(ints, scale_exp)
    n = x.size
    rng = np.random.default_rng(abs(hash(tuple(ints))) % (2**31))
    w = rng.integers(0, 4, n).astype(np.float32)
    w[0] = max(w[0], 1.0)
    fracs = data.draw(st.lists(st.integers(0, 1000), min_size=1, max_size=6))
    wks = np.maximum(np.asarray(fracs, np.float64) / 1000.0 * w.sum(),
                     0.5).astype(np.float32)
    res = selection.weighted_multi_order_statistic(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(wks), method=method,
        backend="jnp", maxit=256, cap=8)
    want = np.array([weighted_oracle(x, w, t) for t in wks], np.float32)
    np.testing.assert_array_equal(np.asarray(res.value), want)


@settings(max_examples=25, deadline=None)
@given(ints=ints_dupes, scale_exp=scale_exps, data=st.data())
def test_weighted_multi_k_zero_mass_ties(ints, scale_exp, data):
    """Tie blocks with massless members — the weighted ladder must skip
    zero-weight elements exactly like the oracle, for every k at once."""
    x = to_f32(ints, scale_exp)
    n = x.size
    w = np.asarray(
        data.draw(st.lists(st.integers(0, 2), min_size=n, max_size=n)),
        np.float32)
    w[0] = max(w[0], 1.0)
    fracs = data.draw(st.lists(st.integers(0, 1000), min_size=1, max_size=5))
    wks = np.maximum(np.asarray(fracs, np.float64) / 1000.0 * w.sum(),
                     0.5).astype(np.float32)
    want = np.array([weighted_oracle(x, w, t) for t in wks], np.float32)
    for method in ["binned", "binned_polish"]:
        res = selection.weighted_multi_order_statistic(
            jnp.asarray(x), jnp.asarray(w), jnp.asarray(wks), method=method,
            backend="jnp", maxit=256, cap=4)
        np.testing.assert_array_equal(np.asarray(res.value), want)


@settings(max_examples=25, deadline=None)
@given(
    sizes=st.lists(st.integers(1, 60), min_size=1, max_size=6),
    scale_exp=scale_exps,
    q=st.integers(min_value=1, max_value=999),
    method=methods,
    data=st.data(),
)
def test_segmented_quantiles_bit_exact(sizes, scale_exp, q, method, data):
    """Per-segment quantiles off one shared sweep vs per-segment sorting."""
    n = sum(sizes)
    ints = data.draw(st.lists(st.integers(-(2**18), 2**18),
                              min_size=n, max_size=n))
    x = to_f32(ints, scale_exp)
    seg = np.concatenate([np.full((s,), i, np.int32)
                          for i, s in enumerate(sizes)])
    rng = np.random.default_rng(abs(hash((tuple(sizes), q))) % (2**31))
    perm = rng.permutation(n)
    x, seg = x[perm], seg[perm]
    res = selection.segmented_quantiles(
        jnp.asarray(x), jnp.asarray(seg), q / 1000.0, sizes, method=method,
        maxit=256)
    want = np.array(
        [np.sort(x[seg == i])[int(np.clip(np.ceil(q / 1000.0 * s), 1, s)) - 1]
         for i, s in enumerate(sizes)], np.float32)
    np.testing.assert_array_equal(np.asarray(res.value), want)
