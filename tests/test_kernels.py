"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernels are written for TPU (BlockSpec VMEM tiling) and validated here in
interpret mode, which executes the kernel body in Python on CPU.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import cp_objective, ops, ref

jax.config.update("jax_platform_name", "cpu")


def check_partials(got, want):
    # float partials: reduction order differs (per-block tree vs flat)
    np.testing.assert_allclose(np.float32(got[0]), np.float32(want[0]),
                               rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(np.float32(got[1]), np.float32(want[1]),
                               rtol=2e-5, atol=1e-5)
    assert int(got[2]) == int(want[2])  # n_lt must be exact
    assert int(got[3]) == int(want[3])  # n_le must be exact


@pytest.mark.parametrize("n", [1, 7, 128, 1024, 4096, 65536, 65537, 100_001])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cp_partials_shapes_dtypes(n, dtype):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal(n), dtype)
    y = jnp.float32(0.1)
    got = cp_objective.cp_partials(x, y, block_rows=8, interpret=True)
    want = ref.cp_partials_ref(x, y)
    check_partials(got, want)


@pytest.mark.parametrize("block_rows", [8, 16, 64])
def test_cp_partials_block_sweep(block_rows):
    rng = np.random.default_rng(0)
    n = 50_000
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    # pivot equal to an existing element exercises the tie lanes
    y = x[1234]
    got = cp_objective.cp_partials(x, y, block_rows=block_rows, interpret=True)
    want = ref.cp_partials_ref(x, y)
    check_partials(got, want)


def test_cp_partials_ties_and_extremes():
    x = jnp.asarray(
        np.array([0.0, 0.0, 0.0, 1e9, -1e9, 0.5, 0.5, -0.5] * 97, np.float32)
    )
    for y in [0.0, 0.5, -0.5, 1e9, -1e9, 2e9]:
        got = cp_objective.cp_partials(x, jnp.float32(y), block_rows=8,
                                       interpret=True)
        want = ref.cp_partials_ref(x, jnp.float32(y))
        check_partials(got, want)


@pytest.mark.parametrize("bsz,n", [(1, 100), (3, 1024), (5, 4097)])
def test_cp_partials_batched(bsz, n):
    rng = np.random.default_rng(bsz * n)
    x = jnp.asarray(rng.standard_normal((bsz, n)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(bsz).astype(np.float32))
    got = cp_objective.cp_partials_batched(x, y, block_rows=8, interpret=True)
    want = ref.cp_partials_batched_ref(x, y)
    for g, w in zip(got[:2], want[:2]):
        np.testing.assert_allclose(np.float32(g), np.float32(w), rtol=1e-5)
    for g, w in zip(got[2:], want[2:]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("n,npiv", [(1, 1), (100, 3), (1024, 8), (4097, 5),
                                    (65537, 2)])
def test_cp_partials_multi(n, npiv):
    """Multi-pivot kernel (interpret) vs the jnp oracle, shape sweep."""
    rng = np.random.default_rng(n * npiv)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(npiv).astype(np.float32))
    got = cp_objective.cp_partials_multi(x, y, block_rows=8, interpret=True)
    want = ref.cp_partials_multi_ref(x, y)
    for g, w in zip(got[:2], want[:2]):
        np.testing.assert_allclose(np.float32(g), np.float32(w), rtol=2e-5,
                                   atol=1e-5)
    for g, w in zip(got[2:], want[2:]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_cp_partials_multi_ties_and_extremes():
    """Pivots sitting ON data values exercise the tie lanes of every pivot
    slot; one pivot outside the data range exercises the all-below case."""
    x = jnp.asarray(
        np.array([0.0, 0.0, 0.0, 1e9, -1e9, 0.5, 0.5, -0.5] * 97, np.float32)
    )
    y = jnp.asarray(np.array([0.0, 0.5, -0.5, 1e9, -1e9, 2e9], np.float32))
    got = cp_objective.cp_partials_multi(x, y, block_rows=8, interpret=True)
    want = ref.cp_partials_multi_ref(x, y)
    for g, w in zip(got[2:], want[2:]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_ops_dispatch_multi():
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(4).astype(np.float32))
    a = ops.fused_partials_multi(x, y, backend="jnp")
    b = ops.fused_partials_multi(x, y, backend="pallas_interpret")
    for g, w in zip(b[:2], a[:2]):
        np.testing.assert_allclose(np.float32(g), np.float32(w), rtol=2e-5,
                                   atol=1e-5)
    for g, w in zip(b[2:], a[2:]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_ops_dispatch():
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
    y = jnp.float32(-0.3)
    a = ops.fused_partials(x, y, backend="jnp")
    b = ops.fused_partials(x, y, backend="pallas_interpret")
    check_partials(b, a)


# ---------------------------------------------------------------------------
# binned histogram kernels (interpret mode) vs jnp oracles
# ---------------------------------------------------------------------------


def check_histogram(got, want, n):
    cnt, bsum = got
    cnt_w, bsum_w = want
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_w))
    np.testing.assert_allclose(np.float32(bsum), np.float32(bsum_w),
                               rtol=2e-5, atol=1e-5)
    # count invariant: the slot layout partitions the whole array
    assert int(jnp.sum(cnt)) == n


@pytest.mark.parametrize("n", [1, 7, 1024, 4097, 65537])
@pytest.mark.parametrize("nbins", [8, 128])
def test_cp_histogram_shapes(n, nbins):
    rng = np.random.default_rng(n + nbins)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    edges = ref.bin_edges(jnp.float32(-1.0), jnp.float32(1.5), nbins)
    got = cp_objective.cp_histogram(x, edges, block_rows=8, interpret=True)
    want = ref.cp_histogram_ref(x, edges)
    check_histogram(got, want, n)


def test_cp_histogram_edges_on_data_and_degenerate():
    """Bracket ends ON data values exercise the open/closed slot bounds;
    lo == hi exercises the collapsed-bracket layout (all mass in the two
    outer slots).  Counts only: the ±1e9 cancellation makes slot sums
    reduction-order-defined (same policy as the FG-kernel tie tests)."""
    x = jnp.asarray(
        np.array([0.0, 0.0, 0.0, 1e9, -1e9, 0.5, 0.5, -0.5] * 97, np.float32)
    )
    for lo, hi in [(0.0, 0.5), (-0.5, 0.5), (-1e9, 1e9), (0.5, 0.5),
                   (2e9, 3e9)]:
        edges = ref.bin_edges(jnp.float32(lo), jnp.float32(hi), 8)
        got = cp_objective.cp_histogram(x, edges, block_rows=8,
                                        interpret=True)
        want = ref.cp_histogram_ref(x, edges)
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(want[0]))
        assert int(jnp.sum(got[0])) == x.size


@pytest.mark.parametrize("bsz,n", [(1, 100), (3, 1024), (5, 4097)])
def test_cp_histogram_batched(bsz, n):
    rng = np.random.default_rng(bsz * n)
    x = jnp.asarray(rng.standard_normal((bsz, n)).astype(np.float32))
    lo = jnp.asarray(rng.standard_normal(bsz).astype(np.float32) - 1.0)
    hi = lo + jnp.asarray(np.abs(rng.standard_normal(bsz)).astype(np.float32)
                          + 0.5)
    edges = ref.bin_edges(lo, hi, 16)
    got = cp_objective.cp_histogram_batched(x, edges, block_rows=8,
                                            interpret=True)
    want = ref.cp_histogram_batched_ref(x, edges)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.float32(got[1]), np.float32(want[1]),
                               rtol=2e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(jnp.sum(got[0], axis=1)),
                                  np.full(bsz, n))


@pytest.mark.parametrize("n,npiv", [(1, 1), (100, 3), (4097, 5), (65537, 2)])
def test_cp_histogram_multi(n, npiv):
    rng = np.random.default_rng(n * npiv + 1)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    lo = jnp.asarray(rng.standard_normal(npiv).astype(np.float32) - 1.0)
    hi = lo + 1.25
    edges = ref.bin_edges(lo, hi, 16)
    got = cp_objective.cp_histogram_multi(x, edges, block_rows=8,
                                          interpret=True)
    want = ref.cp_histogram_multi_ref(x, edges)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.float32(got[1]), np.float32(want[1]),
                               rtol=2e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(jnp.sum(got[0], axis=1)),
                                  np.full(npiv, n))


def test_cp_histogram_infinities_and_full_range():
    """-inf/+inf data values must land in the outer slots (slot 0 has no
    lower bound), and full-f32-range brackets must not overflow the bin
    width — kernel and oracle stay bit-identical in counts."""
    x = jnp.asarray(np.array(
        [-np.inf, np.inf, -3e38, 3e38, 0.0, 1.0, -1.0] * 23, np.float32))
    for lo, hi in [(0.0, 1.0), (-3e38, 3e38), (-1.0, 1.0)]:
        edges = ref.bin_edges(jnp.float32(lo), jnp.float32(hi), 8)
        got = cp_objective.cp_histogram(x, edges, block_rows=8,
                                        interpret=True)
        want = ref.cp_histogram_ref(x, edges)
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(want[0]))
        assert int(jnp.sum(got[0])) == x.size
    # batched + multi variants share _bin_tile; one spot-check each
    xb = x.reshape(1, -1)
    eb = ref.bin_edges(jnp.asarray([-3e38], jnp.float32),
                       jnp.asarray([3e38], jnp.float32), 8)
    gb = cp_objective.cp_histogram_batched(xb, eb, block_rows=8,
                                           interpret=True)
    wb = ref.cp_histogram_batched_ref(xb, eb)
    np.testing.assert_array_equal(np.asarray(gb[0]), np.asarray(wb[0]))
    em = ref.bin_edges(jnp.asarray([0.0], jnp.float32),
                       jnp.asarray([1.0], jnp.float32), 8)
    gm = cp_objective.cp_histogram_multi(x, em, block_rows=8,
                                         interpret=True)
    wm = ref.cp_histogram_multi_ref(x, em)
    np.testing.assert_array_equal(np.asarray(gm[0]), np.asarray(wm[0]))


def test_ops_dispatch_histogram():
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
    e = ref.bin_edges(jnp.float32(-0.7), jnp.float32(0.9), 32)
    a = ops.fused_histogram(x, e, backend="jnp")
    b = ops.fused_histogram(x, e, backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(b[0]), np.asarray(a[0]))
    np.testing.assert_allclose(np.float32(b[1]), np.float32(a[1]),
                               rtol=2e-5, atol=1e-5)
    xb = x.reshape(4, 1024)
    e4 = ref.bin_edges(jnp.full((4,), -0.7, jnp.float32),
                       jnp.full((4,), 0.9, jnp.float32), 32)
    a = ops.fused_histogram_batched(xb, e4, backend="jnp")
    b = ops.fused_histogram_batched(xb, e4, backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(b[0]), np.asarray(a[0]))
    a = ops.fused_histogram_multi(x, e4, backend="jnp")
    b = ops.fused_histogram_multi(x, e4, backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(b[0]), np.asarray(a[0]))


# ---------------------------------------------------------------------------
# weighted kernels (interpret mode) vs jnp oracles
# ---------------------------------------------------------------------------


def check_weighted_partials(got, want):
    # four float partials (reduction order differs), two exact counts
    for g, w in zip(got[:4], want[:4]):
        np.testing.assert_allclose(np.asarray(g, np.float64),
                                   np.asarray(w, np.float64),
                                   rtol=2e-5, atol=1e-5)
    for g, w in zip(got[4:], want[4:]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("n", [1, 7, 1024, 4097, 65537])
def test_wcp_partials_shapes(n):
    rng = np.random.default_rng(n + 3)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    w = jnp.asarray(rng.uniform(0, 2, n).astype(np.float32))
    y = jnp.float32(0.1)
    got = cp_objective.wcp_partials(x, w, y, block_rows=8, interpret=True)
    want = ref.wcp_partials_ref(x, w, y)
    check_weighted_partials(got, want)


def test_wcp_partials_ties_zero_weights_and_extremes():
    x = jnp.asarray(
        np.array([0.0, 0.0, 0.0, 1e9, -1e9, 0.5, 0.5, -0.5] * 97, np.float32)
    )
    w = jnp.asarray(
        np.array([0.0, 1.0, 2.0, 1.0, 0.5, 0.0, 3.0, 1.0] * 97, np.float32)
    )
    for y in [0.0, 0.5, -0.5, 1e9, 2e9]:
        got = cp_objective.wcp_partials(x, w, jnp.float32(y), block_rows=8,
                                        interpret=True)
        want = ref.wcp_partials_ref(x, w, jnp.float32(y))
        check_weighted_partials(got, want)


@pytest.mark.parametrize("bsz,n", [(1, 100), (3, 1024), (5, 4097)])
def test_wcp_partials_batched(bsz, n):
    rng = np.random.default_rng(bsz * n + 1)
    x = jnp.asarray(rng.standard_normal((bsz, n)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0, 2, (bsz, n)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(bsz).astype(np.float32))
    got = cp_objective.wcp_partials_batched(x, w, y, block_rows=8,
                                            interpret=True)
    want = ref.wcp_partials_batched_ref(x, w, y)
    check_weighted_partials(got, want)


@pytest.mark.parametrize("n,npiv", [(100, 3), (4097, 5), (65537, 2)])
def test_wcp_partials_multi(n, npiv):
    rng = np.random.default_rng(n * npiv + 2)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    w = jnp.asarray(rng.uniform(0, 2, n).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(npiv).astype(np.float32))
    got = cp_objective.wcp_partials_multi(x, w, y, block_rows=8,
                                          interpret=True)
    want = ref.wcp_partials_multi_ref(x, w, y)
    check_weighted_partials(got, want)


def check_weighted_histogram(got, want, n):
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.asarray(got[1], np.float64),
                               np.asarray(want[1], np.float64),
                               rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got[2], np.float64),
                               np.asarray(want[2], np.float64),
                               rtol=2e-5, atol=1e-5)
    assert int(jnp.sum(got[0])) == n  # slot layout partitions the array


@pytest.mark.parametrize("n", [1, 7, 4097, 65537])
@pytest.mark.parametrize("nbins", [8, 128])
def test_wcp_histogram_shapes(n, nbins):
    rng = np.random.default_rng(n + nbins + 5)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    w = jnp.asarray(rng.uniform(0, 2, n).astype(np.float32))
    edges = ref.bin_edges(jnp.float32(-1.0), jnp.float32(1.5), nbins)
    got = cp_objective.wcp_histogram(x, w, edges, block_rows=8,
                                     interpret=True)
    want = ref.wcp_histogram_ref(x, w, edges)
    check_weighted_histogram(got, want, n)


def test_wcp_histogram_edges_on_data_and_zero_weights():
    """Bracket ends ON data values + zero-weight lanes: counts and masses
    must stay bit-consistent with the searchsorted oracle's slotting."""
    x = jnp.asarray(
        np.array([0.0, 0.0, 0.5, 0.5, -0.5, 1.0, 2.0, -2.0] * 61,
                 np.float32))
    w = jnp.asarray(
        np.array([0.0, 2.0, 1.0, 0.0, 1.5, 1.0, 0.5, 1.0] * 61, np.float32))
    for lo, hi in [(0.0, 0.5), (-0.5, 0.5), (0.5, 0.5), (3.0, 4.0)]:
        edges = ref.bin_edges(jnp.float32(lo), jnp.float32(hi), 8)
        got = cp_objective.wcp_histogram(x, w, edges, block_rows=8,
                                         interpret=True)
        want = ref.wcp_histogram_ref(x, w, edges)
        check_weighted_histogram(got, want, x.size)


@pytest.mark.parametrize("bsz,n", [(1, 100), (3, 1024), (5, 4097)])
def test_wcp_histogram_batched(bsz, n):
    rng = np.random.default_rng(bsz * n + 7)
    x = jnp.asarray(rng.standard_normal((bsz, n)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0, 2, (bsz, n)).astype(np.float32))
    lo = jnp.asarray(rng.standard_normal(bsz).astype(np.float32) - 1.0)
    hi = lo + 1.5
    edges = ref.bin_edges(lo, hi, 16)
    got = cp_objective.wcp_histogram_batched(x, w, edges, block_rows=8,
                                             interpret=True)
    want = ref.wcp_histogram_batched_ref(x, w, edges)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.float32(got[1]), np.float32(want[1]),
                               rtol=2e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(jnp.sum(got[0], axis=1)),
                                  np.full(bsz, n))


@pytest.mark.parametrize("n,npiv", [(100, 3), (4097, 5)])
def test_wcp_histogram_multi(n, npiv):
    rng = np.random.default_rng(n * npiv + 9)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    w = jnp.asarray(rng.uniform(0, 2, n).astype(np.float32))
    lo = jnp.asarray(rng.standard_normal(npiv).astype(np.float32) - 1.0)
    edges = ref.bin_edges(lo, lo + 1.25, 16)
    got = cp_objective.wcp_histogram_multi(x, w, edges, block_rows=8,
                                           interpret=True)
    want = ref.wcp_histogram_multi_ref(x, w, edges)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.float32(got[1]), np.float32(want[1]),
                               rtol=2e-5, atol=1e-5)


def test_ops_dispatch_weighted():
    rng = np.random.default_rng(21)
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
    w = jnp.asarray(rng.uniform(0, 2, 4096).astype(np.float32))
    y = jnp.float32(-0.3)
    a = ops.fused_weighted_partials(x, w, y, backend="jnp")
    b = ops.fused_weighted_partials(x, w, y, backend="pallas_interpret")
    check_weighted_partials(b, a)
    e = ref.bin_edges(jnp.float32(-0.7), jnp.float32(0.9), 32)
    a = ops.fused_weighted_histogram(x, w, e, backend="jnp")
    b = ops.fused_weighted_histogram(x, w, e, backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(b[0]), np.asarray(a[0]))
    np.testing.assert_allclose(np.float32(b[1]), np.float32(a[1]),
                               rtol=2e-5, atol=1e-5)
    xb = x.reshape(4, 1024)
    wb = w.reshape(4, 1024)
    yb = jnp.asarray(rng.standard_normal(4).astype(np.float32))
    a = ops.fused_weighted_partials_batched(xb, wb, yb, backend="jnp")
    b = ops.fused_weighted_partials_batched(xb, wb, yb,
                                            backend="pallas_interpret")
    check_weighted_partials(b, a)
    e4 = ref.bin_edges(jnp.full((4,), -0.7, jnp.float32),
                       jnp.full((4,), 0.9, jnp.float32), 32)
    a = ops.fused_weighted_histogram_multi(x, w, e4, backend="jnp")
    b = ops.fused_weighted_histogram_multi(x, w, e4,
                                           backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(b[0]), np.asarray(a[0]))


def test_selection_through_kernel_backend():
    """End-to-end: CP selection driven by the Pallas (interpret) kernel
    through a custom FnEvaluator (B=1 view of the unified batched engine)."""
    from repro.core import selection
    from repro.core.objective import FnEvaluator

    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal(20_000).astype(np.float32))
    n = x.size
    k = (n + 1) // 2

    def partials(t):
        one = lambda v: jnp.reshape(v, (1,))
        return tuple(one(p) for p in ops.fused_partials(
            x, t.reshape(()), backend="pallas_interpret"))

    def init_stats():
        one = lambda v: jnp.reshape(v, (1,))
        return (one(jnp.min(x)), one(jnp.max(x)),
                one(jnp.mean(x, dtype=x.dtype)))

    ev = FnEvaluator(partials, jnp.asarray(n, jnp.int32),
                     jnp.asarray([k], jnp.int32), init_stats)
    s, xmin, xmax = selection.bracket_loop_batched(
        ev, method="cp", maxit=64, cap=4096)
    res = selection._finalize_rows(
        x[None, :], jnp.asarray([k], jnp.int32), s, 4096, xmin, xmax)
    expected = np.partition(np.asarray(x), k - 1)[k - 1]
    np.testing.assert_equal(np.float32(res.value[0]), expected)
