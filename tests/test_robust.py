"""Tests for the robust-statistics layer (paper Sec. VI + framework glue)."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import _compat, robust

jax.config.update("jax_platform_name", "cpu")


def make_regression(rng, n=400, p=4, outlier_frac=0.3, out_scale=500.0):
    X = rng.standard_normal((n, p)).astype(np.float32)
    X[:, -1] = 1.0  # intercept column
    theta = rng.standard_normal(p).astype(np.float32)
    y = X @ theta + 0.01 * rng.standard_normal(n).astype(np.float32)
    n_out = int(outlier_frac * n)
    idx = rng.choice(n, n_out, replace=False)
    y[idx] += out_scale * (1 + rng.random(n_out).astype(np.float32))
    return X, y, theta, idx


def test_lts_objective_equals_sorted_sum():
    """rho/(a,b) trick == sum of h smallest squared residuals (paper Eq. 4)."""
    rng = np.random.default_rng(0)
    for trial in range(5):
        r = rng.standard_normal(101).astype(np.float32)
        if trial == 2:  # tie stress: quantized residuals
            r = np.round(r * 4) / 4
        for h in [30, 51, 76, 101]:
            got = robust.lts_objective_from_residuals(jnp.asarray(r), h)
            want = np.sort(r.astype(np.float64) ** 2)[:h].sum()
            np.testing.assert_allclose(float(got), want, rtol=2e-5,
                                       err_msg=f"h={h} trial={trial}")


def test_lts_fit_resists_30pct_outliers():
    rng = np.random.default_rng(1)
    X, y, theta_true, out_idx = make_regression(rng)
    key = jax.random.PRNGKey(0)
    fit = robust.lts_fit(key, jnp.asarray(X), jnp.asarray(y), n_starts=128)
    # plain least squares is destroyed by the outliers
    theta_ls = np.linalg.lstsq(X, y, rcond=None)[0]
    err_lts = np.linalg.norm(np.asarray(fit.theta) - theta_true)
    err_ls = np.linalg.norm(theta_ls - theta_true)
    assert err_lts < 0.05, f"LTS should recover truth, err={err_lts}"
    assert err_ls > 10 * err_lts
    # outliers get zero weight
    w = np.asarray(fit.inlier_weights)
    assert w[out_idx].sum() == 0.0


def test_lms_fit_high_breakdown():
    rng = np.random.default_rng(2)
    X, y, theta_true, _ = make_regression(rng, outlier_frac=0.4)
    fit = robust.lms_fit(jax.random.PRNGKey(1), jnp.asarray(X),
                         jnp.asarray(y), n_starts=512)
    err = np.linalg.norm(np.asarray(fit.theta) - theta_true)
    assert err < 0.2, f"LMS err={err}"


def test_knn_regression_matches_sort_impl():
    rng = np.random.default_rng(3)
    tx = rng.standard_normal((200, 3)).astype(np.float32)
    ty = rng.standard_normal(200).astype(np.float32)
    qx = rng.standard_normal((17, 3)).astype(np.float32)
    k = 7
    got = robust.knn_predict(jnp.asarray(tx), jnp.asarray(ty),
                             jnp.asarray(qx), k)
    d2 = ((qx[:, None, :] - tx[None, :, :]) ** 2).sum(-1)
    idx = np.argsort(d2, axis=1)[:, :k]
    want = ty[idx].mean(axis=1)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)


def test_knn_classify():
    rng = np.random.default_rng(4)
    tx = np.concatenate([rng.standard_normal((50, 2)) + 4,
                         rng.standard_normal((50, 2)) - 4]).astype(np.float32)
    ty = np.concatenate([np.zeros(50), np.ones(50)]).astype(np.int32)
    qx = np.array([[4.0, 4.0], [-4.0, -4.0]], np.float32)
    pred = robust.knn_predict(jnp.asarray(tx), jnp.asarray(ty),
                              jnp.asarray(qx), 5, classify=True, n_classes=2)
    assert list(np.asarray(pred)) == [0, 1]


def test_pytree_quantile_close_to_numpy():
    rng = np.random.default_rng(5)
    tree = {
        "a": jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32)),
        "b": [jnp.asarray(rng.standard_normal(1000).astype(np.float32) * 5)],
    }
    flat = np.abs(np.concatenate(
        [np.asarray(l).ravel() for l in jax.tree.leaves(tree)]))
    n = flat.size
    for q in [0.5, 0.9, 0.99]:
        got = float(robust.pytree_quantile(tree, q, maxit=32))
        k = int(np.ceil(q * n))
        want = np.partition(flat, k - 1)[k - 1]  # lower empirical quantile
        # CP bracket after 32 iterations is tight (or exact via certificate)
        assert abs(got - want) <= 1e-3 * max(1.0, abs(want)), (q, got, want)


def test_clip_by_quantile():
    rng = np.random.default_rng(6)
    g = jnp.asarray(rng.standard_normal(10_000).astype(np.float32))
    tree = {"w": g, "b": g[:100] * 100.0}  # b has huge entries
    clipped, thr = robust.clip_by_quantile(tree, q=0.9)
    thr = float(thr)
    assert thr > 0
    for leaf in jax.tree.leaves(clipped):
        assert float(jnp.max(jnp.abs(leaf))) <= thr * (1 + 1e-6)
    # unclipped coordinates are untouched
    mask = np.abs(np.asarray(g)) <= thr
    np.testing.assert_array_equal(np.asarray(clipped["w"])[mask],
                                  np.asarray(g)[mask])


def test_robust_aggregate_median_beats_byzantine():
    """One corrupt replica cannot move the coordinate-wise median."""
    mesh = _compat.make_mesh((1,), ("data",))
    # single-device path sanity (multi-device covered by _dist_worker.py)
    from jax.sharding import PartitionSpec as P
    g = jnp.ones((1, 8), jnp.float32)

    def agg(gl):
        return robust.robust_aggregate({"g": gl}, "data", method="median")

    out = _compat.shard_map(agg, mesh=mesh, in_specs=P("data"),
                            out_specs=P("data"), check=False)(g)
    np.testing.assert_allclose(np.asarray(out["g"]), 1.0)


def test_hist_quantile_resolution():
    """2-pass histogram quantile within bin resolution of the exact value."""
    rng = np.random.default_rng(7)
    tree = {"a": jnp.asarray(rng.standard_normal(200_000).astype(np.float32)),
            "b": jnp.asarray(rng.standard_normal(1000).astype(np.float32)
                             * 30.0)}
    flat = np.abs(np.concatenate(
        [np.asarray(l).ravel() for l in jax.tree.leaves(tree)]))
    for q in [0.9, 0.99, 0.999]:
        got = float(robust.hist_quantile(tree, q))
        k = int(np.ceil(q * flat.size))
        want = np.partition(flat, k - 1)[k - 1]
        assert want <= got * 1.0000001, (q, got, want)  # conservative side
        assert got <= want * 1.05, (q, got, want)       # within ~bin width
