"""Differential suite for the verified arithmetic binning pass.

The contract under test is ELEMENT-WISE: ``kernels.ref.bin_slots(...,
impl='arithmetic')`` must equal the searchsorted slot oracle for every
element, not just produce the same final order statistics — PR 2 proved
recomputed edge arithmetic unsound exactly in the regimes generated here
(full-f32-range brackets where the realized edges clip-collapse, denormal/
FTZ floors, tie-storms, ulp-wide bins where consecutive edges round
together), so the equality must come from the verified ±1 widening + the
self-certifying rescue, not from luck.

The adversarial leg disables the widening (``arithmetic_slots(...,
widen=False)``) and proves the suite WOULD catch an unverified
implementation: raw candidates provably misplace boundary elements.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import selection
from repro.kernels import ops, ref

# The deterministic adversarial tests below run everywhere; the hypothesis
# strategies only where it is installed (same policy as test_property.py,
# but without skipping the whole module).
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal containers
    HAVE_HYPOTHESIS = False

    def given(*a, **k):  # noqa: D103 - stub so decorators still apply
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):  # noqa: D103
        return lambda f: f

    class _St:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _St()

jax.config.update("jax_platform_name", "cpu")


def to_f32(ints, scale_exp=0):
    x = np.asarray(ints, np.float64) * (2.0 ** (scale_exp - 10))
    return x.astype(np.float32)


def slot_oracle(x, edges):
    """The differential target: the searchsorted slot oracle under the
    PLATFORM's comparison semantics (``ref.searchsorted_slots``).  On FTZ
    hardware (XLA:CPU) denormal values compare as zero in BOTH the oracle
    and the arithmetic path — the equality under test is bit-identity with
    the oracle the engine actually narrows against, which numpy (non-FTZ)
    deliberately is not in the denormal regime."""
    return np.asarray(ref.searchsorted_slots(jnp.asarray(x),
                                             jnp.asarray(edges)))


def np_slot_oracle(x, edges):
    """Pure-numpy count(edges < x) — used where the data is normal-range
    (there the platform and numpy agree, making the test independent of
    the jnp implementation)."""
    return np.searchsorted(np.asarray(edges), np.asarray(x),
                           side="left").astype(np.int32)


# integer-derived dyadic floats (FTZ-safe, tie-heavy); scale_exp stretches
# from denormal-adjacent to within a few octaves of f32 max
ints_small = st.lists(st.integers(-(2**20), 2**20), min_size=1, max_size=300)
ints_dupes = st.lists(st.integers(-4, 4), min_size=1, max_size=300)
scale_exps = st.integers(min_value=-20, max_value=97)
nbins_s = st.sampled_from([2, 3, 8, 16, 128])


@settings(max_examples=80, deadline=None)
@given(ints=ints_small, scale_exp=scale_exps, nbins=nbins_s,
       data=st.data())
def test_arithmetic_slots_elementwise(ints, scale_exp, nbins, data):
    """bin_slots('arithmetic') == searchsorted slots ELEMENT-WISE, with the
    bracket drawn from the data itself (the engine's regime: realized
    bin_edges of an in-range bracket, including lo == hi collapses)."""
    x = to_f32(ints, scale_exp)
    i = data.draw(st.integers(0, x.size - 1))
    j = data.draw(st.integers(0, x.size - 1))
    lo, hi = np.float32(min(x[i], x[j])), np.float32(max(x[i], x[j]))
    edges = ref.bin_edges(jnp.float32(lo), jnp.float32(hi), nbins)
    got = np.asarray(ref.bin_slots(jnp.asarray(x), edges, "arithmetic"))
    np.testing.assert_array_equal(got, slot_oracle(x, edges))
    # normal-range dyadic data: the platform oracle and numpy agree, so the
    # equality is also pinned against an independent implementation
    np.testing.assert_array_equal(got, np_slot_oracle(x, edges))


@settings(max_examples=50, deadline=None)
@given(ints=ints_dupes, scale_exp=scale_exps, nbins=nbins_s)
def test_arithmetic_slots_tie_storms_full_bracket(ints, scale_exp, nbins):
    """Handfuls of duplicated levels, bracket = [min, max] (the first-sweep
    regime, including the full-f32-range clip-collapsed edges)."""
    x = to_f32(ints, scale_exp)
    edges = ref.bin_edges(jnp.float32(x.min()), jnp.float32(x.max()), nbins)
    got = np.asarray(ref.bin_slots(jnp.asarray(x), edges, "arithmetic"))
    np.testing.assert_array_equal(got, slot_oracle(x, edges))


def test_arithmetic_slots_adversarial_regimes():
    """Deterministic worst cases: full-range brackets (edges clip-collapse
    at the top — candidates land ~30 bins out), ulp-wide brackets
    (consecutive edges round together), denormal-scale widths (inv_w
    overflows f32), ±inf data, and edge-exact values."""
    cases = []
    # full f32 range: w*j overflows for large j, top edges collapse to hi
    x = np.array([-3.4e38, -1e38, -1.0, 0.0, 1.0, 2e38, 3.4e38, np.inf,
                  -np.inf], np.float32)
    cases.append((x, np.float32(-3.4e38), np.float32(3.4e38), 128))
    # ulp-wide bracket: duplicate realized edges
    lo = np.float32(1.0)
    hi = np.nextafter(lo, np.float32(np.inf))
    cases.append((np.array([0.5, lo, hi, 2.0], np.float32), lo, hi, 128))
    # denormal-scale width: 1/w overflows f32 (candidate must rescue)
    cases.append((np.linspace(0, 1e-38, 64, dtype=np.float32),
                  np.float32(0.0), np.float32(1e-38), 128))
    # collapsed bracket lo == hi
    cases.append((np.array([-1.0, 0.0, 1.0], np.float32),
                  np.float32(0.0), np.float32(0.0), 8))
    # values exactly ON interior edges (the inherent ±1 boundary case)
    edges8 = np.asarray(ref.bin_edges(jnp.float32(-2.0), jnp.float32(2.0),
                                      8))
    cases.append((edges8.astype(np.float32), np.float32(-2.0),
                  np.float32(2.0), 8))
    for x, lo, hi, nbins in cases:
        edges = ref.bin_edges(jnp.asarray(lo), jnp.asarray(hi), nbins)
        got = np.asarray(ref.bin_slots(jnp.asarray(x), edges, "arithmetic"))
        np.testing.assert_array_equal(got, slot_oracle(x, edges),
                                      err_msg=f"lo={lo} hi={hi}")


def test_unverified_arithmetic_is_caught():
    """The adversarial leg: with the ±1 widening DISABLED the raw clipped
    candidate misplaces edge-exact elements — proving this suite would
    catch an unverified implementation — while the widened version is
    already exact in this (non-degenerate) regime without any rescue."""
    edges = ref.bin_edges(jnp.float32(-2.0), jnp.float32(2.0), 8)
    x = jnp.asarray(edges)[1:-1]  # interior edge-exact values
    want = slot_oracle(x, edges)
    raw = np.asarray(ref.arithmetic_slots(x, edges, widen=False))
    assert np.any(raw != want), "raw candidates unexpectedly exact"
    widened = np.asarray(ref.arithmetic_slots(x, edges, widen=True))
    np.testing.assert_array_equal(widened, want)


@settings(max_examples=40, deadline=None)
@given(ints=ints_small, scale_exp=scale_exps)
def test_batched_and_multi_slot_paths(ints, scale_exp):
    """The batched (per-row edges) and shared-x (per-pivot edges) slot
    paths run the same verified code: element-wise equality there too."""
    x = to_f32(ints, scale_exp)
    n = x.size
    lo = np.float32(x.min())
    hi = np.float32(x.max())
    mid = np.float32(lo / 2 + hi / 2)
    los = jnp.asarray([lo, lo, mid])
    his = jnp.asarray([hi, mid if mid > lo else hi, hi])
    edges = ref.bin_edges(los, jnp.maximum(his, los), 16)
    got = np.asarray(ref.bin_slots(jnp.asarray(x), edges, "arithmetic"))
    for r in range(3):
        np.testing.assert_array_equal(got[r],
                                      slot_oracle(x, np.asarray(edges)[r]))
    # batched rows: each row binned against its own edges
    xb = jnp.asarray(np.stack([x, x[::-1], x]))
    gotb = np.asarray(ref.bin_slots(xb, edges, "arithmetic"))
    for r, row in enumerate([x, x[::-1], x]):
        np.testing.assert_array_equal(gotb[r],
                                      slot_oracle(row, np.asarray(edges)[r]))


@settings(max_examples=30, deadline=None)
@given(ints=ints_small, scale_exp=scale_exps, nbins=st.sampled_from([8, 16]),
       data=st.data())
def test_polish_edges_slots_rescue(ints, scale_exp, nbins, data):
    """Non-uniform (polish) edge arrays break the uniform candidate by
    construction — the verification must detect it and the rescue must
    still return bit-exact slots."""
    x = to_f32(ints, scale_exp)
    lo = np.float32(x.min())
    hi = np.float32(x.max())
    tq = data.draw(st.integers(0, 1000))
    t = np.float32(lo + (hi - lo) * (tq / 1000.0))
    edges = selection.polish_edges(jnp.asarray(lo), jnp.asarray(hi),
                                   jnp.asarray(t), nbins)
    got = np.asarray(ref.bin_slots(jnp.asarray(x), edges, "arithmetic"))
    np.testing.assert_array_equal(got, slot_oracle(x, np.asarray(edges)))


@settings(max_examples=40, deadline=None)
@given(ints=ints_small, scale_exp=scale_exps,
       kf=st.integers(min_value=0, max_value=1000))
def test_binned_impl_differential_engine(ints, scale_exp, kf):
    """End-to-end: the two slotting impls drive the binned engine to the
    same (np.partition-exact) answers."""
    x = to_f32(ints, scale_exp)
    n = x.size
    k = max(1, min(n, 1 + (kf * n) // 1001))
    expected = np.partition(x, k - 1)[k - 1]
    for impl in ["searchsorted", "arithmetic"]:
        res = selection.order_statistic(jnp.asarray(x), k, method="binned",
                                        binned_impl=impl, maxit=256, cap=8)
        np.testing.assert_equal(np.float32(res.value), expected)


@settings(max_examples=25, deadline=None)
@given(ints=ints_dupes, scale_exp=scale_exps,
       wf=st.integers(min_value=0, max_value=1000), data=st.data())
def test_binned_impl_differential_weighted(ints, scale_exp, wf, data):
    """Weighted leg: both impls equal the f64 sorted-cumsum oracle under
    tie storms with zero-mass members."""
    x = to_f32(ints, scale_exp)
    n = x.size
    w = np.asarray(
        data.draw(st.lists(st.integers(0, 3), min_size=n, max_size=n)),
        np.float32)
    w[0] = max(w[0], 1.0)
    wk = float(np.float32(max(float(w.sum()) * wf / 1000.0, 0.5)))
    o = np.argsort(x, kind="stable")
    c = np.cumsum(w[o].astype(np.float64))
    want = x[o][min(np.searchsorted(c, wk, "left"), n - 1)]
    for impl in ["searchsorted", "arithmetic"]:
        res = selection.weighted_order_statistic(
            jnp.asarray(x), jnp.asarray(w), wk, method="binned",
            binned_impl=impl, maxit=256, cap=8)
        np.testing.assert_equal(np.float32(res.value), want)


def test_histogram_counts_match_and_msum_demand():
    """ops-layer contract: both impls produce identical counts; the
    arithmetic pass skips the per-slot sums unless asked (want_sums)."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
    e = ref.bin_edges(jnp.float32(-0.9), jnp.float32(1.1), 16)
    c_ss, b_ss = ops.fused_histogram(x, e, backend="jnp",
                                     impl="searchsorted")
    c_ar, b_ar = ops.fused_histogram(x, e, backend="jnp",
                                     impl="arithmetic")
    np.testing.assert_array_equal(np.asarray(c_ss), np.asarray(c_ar))
    np.testing.assert_allclose(np.asarray(b_ss), np.asarray(b_ar),
                               rtol=2e-5, atol=1e-4)
    c_no, b_no = ops.fused_histogram(x, e, backend="jnp",
                                     impl="arithmetic", want_sums=False)
    assert b_no is None
    np.testing.assert_array_equal(np.asarray(c_ss), np.asarray(c_no))
    # weighted: the mass vector always rides, only wsum is demand-driven
    w = jnp.asarray(rng.integers(0, 4, 4096).astype(np.float32))
    cw, ww, sw = ops.fused_weighted_histogram(x, w, e, backend="jnp",
                                              impl="arithmetic",
                                              want_sums=False)
    cw2, ww2, sw2 = ops.fused_weighted_histogram(x, w, e, backend="jnp",
                                                 impl="searchsorted")
    assert sw is None
    np.testing.assert_array_equal(np.asarray(cw), np.asarray(cw2))
    np.testing.assert_array_equal(np.asarray(ww), np.asarray(ww2))


def test_bad_impl_rejected():
    x = jnp.zeros((8,), jnp.float32)
    e = ref.bin_edges(jnp.float32(0.0), jnp.float32(1.0), 4)
    with pytest.raises(ValueError):
        ops.fused_histogram(x, e, backend="jnp", impl="florble")
    with pytest.raises(ValueError):
        selection.order_statistic(x, 1, method="binned",
                                  binned_impl="florble")


@pytest.mark.parametrize("use_x64", [False, True])
def test_x64_reroute_keeps_arithmetic_exact(use_x64):
    """The f64 reroute lands on the jnp oracle with the arithmetic impl:
    sub-f32-resolution data must still slot exactly."""
    import jax.experimental

    if use_x64:
        with jax.experimental.enable_x64():
            base = np.float64(1.0)
            eps = np.finfo(np.float64).eps
            x = jnp.asarray(base + np.arange(64) * 50 * eps)
            edges = ref.bin_edges(jnp.float64(base),
                                  jnp.float64(base + 3200 * eps), 8)
            got = np.asarray(ref.bin_slots(x, edges, "arithmetic"))
            np.testing.assert_array_equal(got, slot_oracle(x, edges))
    else:
        x = jnp.asarray(np.linspace(-1, 1, 64, dtype=np.float32))
        edges = ref.bin_edges(jnp.float32(-1.0), jnp.float32(1.0), 8)
        got = np.asarray(ref.bin_slots(x, edges, "arithmetic"))
        np.testing.assert_array_equal(got, slot_oracle(x, edges))
