"""Step factories: train_step (grad + robust clip + optimizer), prefill_step
and serve_step — the functions that get pjit'd and dry-run compiled.

The paper's technique is a first-class training feature here:
``clip='quantile'`` clips gradient magnitudes at their global q-quantile via
the cutting-plane selector running over the *sharded* gradient pytree —
``maxit`` fused passes + all-reduces of four scalars each, no gather
(core.robust.clip_by_quantile).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShardingPlan
from repro.core import robust
from repro.models import model


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: jax.Array

    def tree_flatten(self):
        return (self.params, self.opt, self.step), None


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten,
    lambda aux, children: TrainState(*children))


def _loss_from_batch(params, batch, cfg: ModelConfig, plan: ShardingPlan,
                     rwkv_impl: str):
    """Fused-unembed chunked CE: full logits are never materialized."""
    hidden, aux = model.forward(params, batch, cfg, plan, mode="train",
                                rwkv_impl=rwkv_impl, return_hidden=True)
    tokens = batch["tokens"]
    if cfg.frontend == "patch_stub" and "patches" in batch:
        n_img = batch["patches"].shape[1]
        hidden = hidden[:, n_img:]
    loss, metrics = model.lm_loss_fused(
        hidden[:, :-1], params["embed"], tokens[:, 1:],
        jnp.ones_like(tokens[:, 1:]), cfg, plan)
    if cfg.moe is not None:
        loss = loss + (cfg.moe.router_aux_weight * aux["moe_aux"]
                       + cfg.moe.router_z_weight * aux["moe_z"])
        metrics = dict(metrics, moe_aux=aux["moe_aux"], moe_z=aux["moe_z"])
    return loss, metrics


def make_train_step(cfg: ModelConfig, plan: ShardingPlan, optimizer, *,
                    clip: str = "quantile", clip_q: float = 0.99,
                    clip_maxit: int = 12, rwkv_impl: str = "scan",
                    accum_steps: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    ``accum_steps > 1`` enables gradient accumulation: the global batch is
    split into microbatches scanned sequentially (activation memory scales
    down by the factor; grads accumulate in f32).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(_loss_from_batch, has_aux=True)(
            params, batch, cfg, plan, rwkv_impl)

    def accum_grads(params, batch):
        if accum_steps <= 1:
            return grads_of(params, batch)

        def split(x):
            b = x.shape[0]
            assert b % accum_steps == 0, (b, accum_steps)
            return x.reshape((accum_steps, b // accum_steps) + x.shape[1:])

        micro = jax.tree.map(split, batch)

        def mb_step(carry, mb):
            loss_a, metrics_a, g_a = carry
            (loss, metrics), g = grads_of(params, mb)
            g_a = jax.tree.map(
                lambda a, b_: a + b_.astype(jnp.float32), g_a, g)
            metrics_a = jax.tree.map(lambda a, b_: a + b_, metrics_a, metrics)
            return (loss_a + loss, metrics_a, g_a), None

        zeros_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        mb0 = jax.tree.map(lambda x: x[0], micro)
        zero_metrics = jax.tree.map(
            lambda s: jnp.zeros((), jnp.float32),
            jax.eval_shape(lambda p, b: grads_of(p, b)[0][1], params, mb0))
        (loss, metrics, grads), _ = jax.lax.scan(
            mb_step, (jnp.zeros(()), zero_metrics, zeros_g), micro)
        inv = 1.0 / accum_steps
        grads = jax.tree.map(lambda g: g * inv, grads)
        metrics = jax.tree.map(lambda v: v * inv, metrics)
        return (loss * inv, metrics), grads

    def train_step(state: TrainState, batch):
        (loss, metrics), grads = accum_grads(state.params, batch)

        if clip == "quantile":
            grads, thr = robust.clip_by_quantile(grads, clip_q,
                                                 maxit=clip_maxit)
            metrics = dict(metrics, clip_thr=thr)
        elif clip == "quantile_hist":
            # 2-pass histogram variant (§Perf): ~1.8% bin resolution,
            # 2 gradient sweeps instead of maxit
            thr = jnp.maximum(robust.hist_quantile(grads, clip_q), 1e-8)
            grads = jax.tree.map(
                lambda g: jnp.clip(g, -thr.astype(g.dtype),
                                   thr.astype(g.dtype)), grads)
            metrics = dict(metrics, clip_thr=thr)
        elif clip == "global_norm":
            gn = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, 1.0 / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
            metrics = dict(metrics, grad_norm=gn)
        elif clip != "none":
            raise ValueError(clip)

        params, opt = optimizer.update(grads, state.opt, state.params)
        metrics = dict(metrics, loss=loss)
        return TrainState(params=params, opt=opt, step=state.step + 1), metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, plan: ShardingPlan,
                      rwkv_impl: str = "scan"):
    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch, cfg, plan, mode="prefill",
                                  rwkv_impl=rwkv_impl)
        return logits

    return prefill_step


def make_serve_step(cfg: ModelConfig, plan: ShardingPlan):
    """One-token decode: (params, cache, token, index) -> (next_token,
    logits, new_cache) — greedy argmax sampling."""

    def serve_step(params, cache, token, index):
        logits, new_cache = model.decode_step(params, cache, token, index,
                                              cfg, plan)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, new_cache

    return serve_step


# ---------------------------------------------------------------------------
# sharding of the train state (ZeRO-1: opt state over data axis where legal)
# ---------------------------------------------------------------------------


def _zero1_spec(spec: P, leaf, plan: ShardingPlan) -> P:
    """Extend a param spec with the data axis on the largest unsharded dim
    (ZeRO-1).  Only when the dim is divisible by the axis size."""
    if plan.mesh is None or not plan.dp_axes:
        return spec
    axis = plan.dp_axes[-1]  # 'data'
    size = plan.mesh.shape[axis]
    parts = list(spec) + [None] * (leaf.ndim - len(spec))
    used = {a for p_ in parts if p_ is not None
            for a in ((p_,) if isinstance(p_, str) else p_)}
    if axis in used:  # already sharded over data (fsdp) — nothing to add
        return spec
    best, best_dim = -1, -1
    for i, (p_, d) in enumerate(zip(parts, leaf.shape)):
        if p_ is None and d % size == 0 and d > best:
            best, best_dim = d, i
    if best_dim < 0:
        return spec
    parts[best_dim] = axis
    return P(*parts)


def train_state_specs(state: TrainState, cfg: ModelConfig,
                      plan: ShardingPlan, *, zero1: bool = True):
    """PartitionSpec pytree for the full TrainState."""
    pspecs = model.param_specs(state.params, cfg, plan)

    def opt_entry(subtree_params_specs, subtree):
        # m/v/master mirror the param structure
        return subtree_params_specs

    opt_specs = {}
    for k, sub in state.opt.items():
        if k in ("count",):
            opt_specs[k] = P()
        elif k in ("m", "v", "master"):
            if zero1:
                opt_specs[k] = jax.tree.map(
                    lambda s, l: _zero1_spec(s, l, plan), pspecs,
                    state.opt[k])
            else:
                opt_specs[k] = pspecs
        elif k == "stats":  # adafactor
            def sspec(path, leaf):
                return P()
            opt_specs[k] = jax.tree_util.tree_map_with_path(
                sspec, state.opt[k])
        else:
            opt_specs[k] = jax.tree.map(lambda _: P(), state.opt[k])
    return TrainState(params=pspecs, opt=opt_specs, step=P())
