"""Fault-tolerant training loop.

* checkpoint/restart: resumes from the latest complete checkpoint; the data
  pipeline is stateless-resumable so recovery is bit-deterministic.
* step-retry: a failed step (device error) restores the last checkpoint and
  replays — the single-process analogue of a cluster's node-failure restart.
* straggler/step-time telemetry: p50/p99 step times computed with the
  paper's own selection primitive (no sort), logged every ``log_every``.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core import selection
from repro.data import SyntheticPipeline
from repro.train.step import TrainState


def fit(
    *,
    train_step: Callable,
    state: TrainState,
    pipeline: SyntheticPipeline,
    steps: int,
    ckpt: Optional[CheckpointManager] = None,
    ckpt_every: int = 50,
    log_every: int = 10,
    max_retries: int = 2,
    log_fn: Callable[[str], None] = print,
) -> Dict[str, Any]:
    """Run ``steps`` optimizer steps with checkpoint/restart."""
    jit_step = jax.jit(train_step, donate_argnums=(0,))

    start = int(state.step)
    if ckpt is not None and ckpt.latest_step() is not None:
        s = ckpt.latest_step()
        state, manifest = ckpt.restore(s, state)
        start = manifest["step"]
        log_fn(f"[loop] restored checkpoint step={start}")

    times = []
    losses = []
    retries = 0
    i = start
    while i < steps:
        batch = next(pipeline)
        t0 = time.perf_counter()
        try:
            state, metrics = jit_step(state, batch)
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {i}")
        except Exception as e:  # node-failure analogue: restore + replay
            retries += 1
            if ckpt is None or retries > max_retries:
                raise
            s = ckpt.latest_step()
            if s is None:
                raise
            log_fn(f"[loop] step {i} failed ({e}); restoring step {s}")
            state, manifest = ckpt.restore(s, state)
            i = manifest["step"]
            pipeline.step = i
            continue

        dt = time.perf_counter() - t0
        times.append(dt)
        losses.append(loss)
        i += 1

        if ckpt is not None and i % ckpt_every == 0:
            ckpt.save(i, state, extra={"pipeline": pipeline.state()})

        if i % log_every == 0:
            ts = jnp.asarray(times[-100:], jnp.float32)
            p50 = float(selection.median(ts).value)
            p99 = float(selection.quantile(ts, 0.99).value)
            log_fn(f"[step {i}] loss={loss:.4f} "
                   f"p50={p50*1e3:.1f}ms p99={p99*1e3:.1f}ms "
                   f"(straggler ratio {p99 / max(p50, 1e-9):.2f})")

    if ckpt is not None:
        ckpt.save(steps, state, extra={"pipeline": pipeline.state()})
        ckpt.wait()
    return {"losses": losses, "times": times, "state": state,
            "retries": retries}
