from repro.train.step import (
    TrainState, make_prefill_step, make_serve_step, make_train_step,
    train_state_specs,
)
from repro.train.loop import fit
