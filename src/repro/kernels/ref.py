"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cp_partials_ref(x: jax.Array, y: jax.Array):
    """Oracle for kernels.cp_objective.cp_partials."""
    x = x.reshape(-1).astype(jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    d = x - y
    sum_pos = jnp.sum(jnp.maximum(d, 0))
    sum_neg = jnp.sum(jnp.maximum(-d, 0))
    n_lt = jnp.sum(d < 0, dtype=jnp.int32)
    n_le = jnp.sum(d <= 0, dtype=jnp.int32)
    return sum_pos, sum_neg, n_lt, n_le


def cp_partials_batched_ref(x: jax.Array, y: jax.Array):
    """Oracle for kernels.cp_objective.cp_partials_batched."""
    return jax.vmap(cp_partials_ref)(
        x.astype(jnp.float32), jnp.asarray(y, jnp.float32)
    )
