"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def _accum_dtype(x):
    # The TPU kernel accumulates in f32, so the oracle promotes low-precision
    # inputs (bf16) to f32 for bit-comparable partials — but NEVER downcasts:
    # f64 selection (x64 mode on CPU) must keep full precision or the count
    # certificates lie about exactness.
    return jnp.promote_types(x.dtype, jnp.float32)


def cp_partials_ref(x: jax.Array, y: jax.Array):
    """Oracle for kernels.cp_objective.cp_partials."""
    dt = _accum_dtype(x)
    x = x.reshape(-1).astype(dt)
    y = jnp.asarray(y, dt)
    d = x - y
    sum_pos = jnp.sum(jnp.maximum(d, 0))
    sum_neg = jnp.sum(jnp.maximum(-d, 0))
    n_lt = jnp.sum(d < 0, dtype=jnp.int32)
    n_le = jnp.sum(d <= 0, dtype=jnp.int32)
    return sum_pos, sum_neg, n_lt, n_le


def cp_partials_batched_ref(x: jax.Array, y: jax.Array):
    """Oracle for kernels.cp_objective.cp_partials_batched."""
    dt = _accum_dtype(x)
    return jax.vmap(cp_partials_ref)(x.astype(dt), jnp.asarray(y, dt))


def cp_partials_multi_ref(x: jax.Array, y: jax.Array):
    """Oracle for kernels.cp_objective.cp_partials_multi: one shared ``x``
    (n,), ``y`` is (K,) pivots; returns four (K,) vectors."""
    dt = _accum_dtype(x)
    return jax.vmap(cp_partials_ref, in_axes=(None, 0))(
        x.reshape(-1).astype(dt), jnp.asarray(y, dt)
    )


# ---------------------------------------------------------------------------
# Binned bracket descent: slot assignment + histogram oracles
# ---------------------------------------------------------------------------

BIN_IMPLS = ("searchsorted", "arithmetic")

# Chunk length for the factored one-hot accumulation below: one chunk's
# factor matrices stay L2-resident while the GEMM reduces them, which is
# what makes the arithmetic pass map-reduce-fast on CPU.
HIST_CHUNK = 1 << 14


def bin_edges(lo, hi, nbins: int):
    """Realized fp bin-edge values ``e_j = clip(lo + w*j, lo, hi)`` with
    ``w = hi/nbins - lo/nbins`` and ``e_nbins`` forced to ``hi`` exactly,
    appended as a trailing axis of size ``nbins + 1``.

    SINGLE SOURCE OF TRUTH for edge construction: the engine computes the
    edges ONCE per sweep with this function and passes the realized array
    to the histogram kernels/oracles, which only COMPARE against it — no
    consumer ever recomputes edge arithmetic (XLA FMA contraction makes
    recomputed ``lo + w*j`` fusion-context-dependent), so histogram counts
    stay bit-consistent with the engine's later ``x <= e_j`` narrowing and
    finalize comparisons.  The sequence is monotone non-decreasing in fp
    (``w >= 0``, ``w*j`` and ``lo + t`` are monotone, clip preserves
    order), which the bin-index search relies on.

    Overflow safety: ``(hi - lo)`` overflows f32 for full-range brackets
    (e.g. data spanning ±3e38 — width inf, NaN edges, garbage descent), so
    ``w`` divides BEFORE differencing (each term <= f32max/nbins; their
    difference <= f32max for nbins >= 2), the width is clamped into the
    finite range (nbins == 1 — reachable through ``polish_edges`` with a
    tiny bin budget — would otherwise make ``w = inf`` and ``w * 0 = NaN``)
    and ``lo + w*j`` — which can still overflow for large j — is clipped
    into ``[lo, hi]`` (collapsed top bins are just empty).
    """
    lo = jnp.asarray(lo)
    hi = jnp.asarray(hi, lo.dtype)
    w = jnp.clip(hi / nbins - lo / nbins, 0,
                 jnp.asarray(jnp.finfo(lo.dtype).max, lo.dtype))
    j = jnp.arange(nbins + 1)
    e = jnp.clip(lo[..., None] + w[..., None] * j.astype(lo.dtype),
                 lo[..., None], hi[..., None])
    return jnp.where(j == nbins, hi[..., None], e)


def searchsorted_slots(x: jax.Array, edges: jax.Array) -> jax.Array:
    """THE slot oracle: ``slot = count(edges < x)`` by binary search.

    Slot layout (``nbins + 2`` slots): 0 = ``x <= e_0``; j in 1..nbins =
    ``e_{j-1} < x <= e_j``; nbins+1 = ``x > e_nbins`` —
    ``searchsorted('left')`` on the monotone realized edges.  ``x``
    ``(..., n)`` and ``edges`` ``(..., nbins+1)`` broadcast over leading
    dims; returns int32 slots shaped like the broadcast ``x``.
    """
    if edges.ndim == 1:
        return jnp.searchsorted(edges, x, side="left").astype(jnp.int32)
    lead = jnp.broadcast_shapes(x.shape[:-1], edges.shape[:-1])
    xb = jnp.broadcast_to(x, lead + x.shape[-1:])
    eb = jnp.broadcast_to(edges, lead + edges.shape[-1:])
    out = jax.vmap(lambda e, xi: jnp.searchsorted(e, xi, side="left"))(
        eb.reshape((-1,) + eb.shape[-1:]),
        xb.reshape((-1,) + xb.shape[-1:]))
    return out.reshape(lead + x.shape[-1:]).astype(jnp.int32)


def _take_last(a, idx):
    """Per-element gather along the trailing axis with broadcast leading
    dims (``a`` (..., m), ``idx`` (..., n) int32)."""
    lead = jnp.broadcast_shapes(a.shape[:-1], idx.shape[:-1])
    a = jnp.broadcast_to(a, lead + a.shape[-1:])
    idx = jnp.broadcast_to(idx, lead + idx.shape[-1:])
    return jnp.take_along_axis(a, idx, axis=-1)


def _arith_candidates(x: jax.Array, edges: jax.Array) -> jax.Array:
    """Raw arithmetic slot candidates ``clip(floor((x - lo) * inv_w) + 1)``.

    MONOTONE NON-DECREASING in ``x`` by construction (every stage —
    multiply by a positive constant, subtract a constant, floor, the
    inf-saturating sanitize, clip — is fp-monotone), which is what lets
    :func:`bin_slots` verify soundness at the ``nbins + 1`` edges alone.
    Overflow-safe: ``x*inv_w - lo*inv_w`` keeps each product ~``nbins``
    for in-bracket data, so full-f32-range brackets never overflow the
    difference (out-of-bracket infinities saturate to the end slots).
    NaN data maps to the top slot, matching binary search (every NaN
    comparison is false, so searchsorted walks right).
    """
    nbins = edges.shape[-1] - 1
    dt = edges.dtype
    x = jnp.asarray(x, dt)
    lo, hi = edges[..., :1], edges[..., -1:]
    # candidate-only width/reciprocal (same divide-before-diff trick as
    # bin_edges); rounding here is harmless — soundness is verified
    # against the realized edges, never against this arithmetic
    w = hi / nbins - lo / nbins
    iw = jnp.where(w > 0, 1.0 / jnp.where(w > 0, w, 1), 0).astype(dt)
    ok_w = (w > 0) & jnp.isfinite(iw) & (iw > 0)
    pos = x * iw - lo * iw
    cand = jnp.where(ok_w, jnp.floor(pos) + 1,
                     # degenerate bracket (w == 0 / FTZ-flushed): interior
                     # values land in the top real bin (all realized
                     # interior edges collapse onto lo there)
                     jnp.where(x <= lo, 0.0,
                               jnp.where(x > hi, float(nbins + 1),
                                         float(nbins))))
    cand = jnp.where(jnp.isnan(x), float(nbins + 1),
                     jnp.nan_to_num(cand, nan=0.0, posinf=float(nbins + 1),
                                    neginf=0.0))
    return jnp.clip(cand, 0.0, nbins + 1).astype(jnp.int32)


def arithmetic_slots(x: jax.Array, edges: jax.Array, *,
                     widen: bool = True) -> jax.Array:
    """Arithmetic slot candidates + the ±1 widening step (Tibshirani's
    successive-binning slotting, made sound against the REALIZED edges).

    The widening compares each element against the realized ``edges`` at
    its candidate's two neighboring boundaries: a candidate one too high
    (``x <= e_{c-1}``, e.g. ``x`` exactly on an edge, where fp rounding of
    the reciprocal multiply puts ``pos`` at the integer) steps down, one
    too low (``x > e_c``) steps up.  For any candidate within ±1 of the
    true slot the corrected result is bit-identical to
    :func:`searchsorted_slots` — recomputed edge arithmetic appears ONLY
    in the candidate, never in a comparison that decides the final slot.

    ``widen=False`` disables the correction (the raw clipped candidate):
    it exists for the differential suite's adversarial leg, which proves
    an unverified implementation is caught.  This function never falls
    back to binary search; callers that need the full bit-exactness
    guarantee in degenerate regimes (clip-collapsed edges of full-range
    brackets, duplicate edges of ulp-wide brackets, denormal-underflowed
    bin widths) go through :func:`bin_slots`, which certifies the
    candidate map at the edges and rescues failures through the
    searchsorted oracle.
    """
    c = _arith_candidates(x, edges)
    if not widen:
        return c
    nbins = edges.shape[-1] - 1
    x = jnp.asarray(x, edges.dtype)
    # ±1 widening against the REALIZED edges (never recomputed)
    e_dn = _take_last(edges, jnp.maximum(c - 1, 0))
    e_up = _take_last(edges, jnp.minimum(c, nbins))
    down = (c > 0) & (x <= e_dn)
    up = (c <= nbins) & (x > e_up) & ~down
    return c - down.astype(jnp.int32) + up.astype(jnp.int32)


def _candidates_certified_rows(edges: jax.Array) -> jax.Array:
    """Per-ladder soundness certificate: the all-edges test of
    :func:`_candidates_certified`, reduced over the trailing (edge) axis
    only.  For ``(K, nbins+1)`` edge ladders this returns a ``(K,)`` bool
    vector — one degenerate ladder (a collapsed bracket, a polish ladder
    whose uniform candidate misfires) rescues ONLY its own row, the other
    K-1 ladders keep the arithmetic fast path."""
    nbins = edges.shape[-1] - 1
    ce = _arith_candidates(edges, edges)
    i = jnp.arange(nbins + 1, dtype=jnp.int32)
    return jnp.all((ce >= i) & (ce <= i + 1), axis=-1)


def _candidates_certified(edges: jax.Array) -> jax.Array:
    """O(nbins) soundness certificate for the arithmetic candidates.

    The candidate map is monotone in ``x`` (see :func:`_arith_candidates`),
    so for any ``x`` with true slot ``j`` — i.e. ``e_{j-1} < x <= e_j`` —
    the candidate is bracketed by the candidates AT those two edges.  If
    ``i <= cand(e_i) <= i + 1`` holds for every edge ``i`` (trivially true
    in exact arithmetic, where ``cand(e_i) = i + 1``), every element's
    candidate is within ±1 of its true slot and the widening makes the
    final slots exactly searchsorted's.  Degenerate regimes (duplicate or
    clip-collapsed edges, FTZ-flushed widths, polish's non-uniform
    ladders) break the bound AT AN EDGE, so checking the ``nbins + 1``
    edges — instead of all ``n`` elements — loses nothing.
    """
    return jnp.all(_candidates_certified_rows(edges))


def bin_slots(x: jax.Array, edges: jax.Array,
              impl: str = "searchsorted") -> jax.Array:
    """Slot assignment, bit-identical to :func:`searchsorted_slots` under
    BOTH impls.

    ``impl='arithmetic'`` replaces the per-element binary search with the
    fused multiply/floor/clip candidate + ±1 widening of
    :func:`arithmetic_slots`, VERIFIED by the edge-level certificate of
    :func:`_candidates_certified`; if the certificate fails (possible only
    in degenerate regimes — clip-collapsed or duplicate edges, underflowed
    widths, non-uniform polish ladders — where a candidate can be further
    than one bin out), that call falls back to the searchsorted oracle
    wholesale, so exactness never depends on the candidate quality.  The
    certificate makes the fast path self-certifying: arithmetic slots ship
    only when provably equal.
    """
    if impl == "searchsorted":
        return searchsorted_slots(x, edges)
    if impl != "arithmetic":
        raise ValueError(f"unknown binning impl {impl!r}; one of "
                         f"{BIN_IMPLS}")
    return jax.lax.cond(
        _candidates_certified(edges),
        lambda: arithmetic_slots(x, edges),
        lambda: searchsorted_slots(x, edges),
    )


def _factored_hist(slot, rows, nslots: int, dt):
    """Per-slot sums by chunked FACTORED one-hot contraction (map-reduce).

    ``slot`` (..., n) int32 in [0, nslots); ``rows`` is a tuple of
    (..., n) value arrays (each gets a per-slot sum; the count row is
    implicit).  The slot one-hot factors through ``slot = hi*B + lo`` into
    two skinny factor matrices (m, A) and (m, B) per chunk, so the per-slot
    reduction is a tiny batched GEMM with A+B one-hot columns instead of
    ``nslots`` — the XLA:CPU-fast formulation of the histogram reduce
    (scatter-add lowers to a serialized loop there, ~10x a fused pass).

    Counts stay exact for any n: each chunk's products are 0/1 floats whose
    per-chunk sums are <= HIST_CHUNK < 2^24 (exact in f32), accumulated
    across chunks in int32.  Value rows accumulate in ``dt`` (chunk-major
    order; exactly summable inputs — integer/dyadic weights — stay exact,
    same contract as the kernels' tile accumulation).

    Returns ``[cnt int32, *sums dt]``, each shaped ``lead + (nslots,)``.
    """
    lead = slot.shape[:-1]
    n = slot.shape[-1]
    r = max(1, int(np.prod(lead)) if lead else 1)
    m = min(HIST_CHUNK, max(n, 1))
    npad = -(-n // m) * m
    nc = npad // m
    bf = int(np.ceil(np.sqrt(nslots)))
    af = -(-nslots // bf)
    # pad slots into the all-zero one-hot row (hi == af matches no factor)
    pad = [(0, 0)] * len(lead) + [(0, npad - n)]
    sl = jnp.pad(slot, pad, constant_values=af * bf).reshape(r, nc, m)
    sl = jnp.moveaxis(sl, 1, 0)                          # (nc, r, m)
    vals = [jnp.pad(jnp.broadcast_to(jnp.asarray(v, dt), slot.shape),
                    pad).reshape(r, nc, m) for v in rows]
    vals = [jnp.moveaxis(v, 1, 0) for v in vals]
    ia = jnp.arange(af, dtype=jnp.int32)
    ib = jnp.arange(bf, dtype=jnp.int32)

    def body(acc, args):
        si = args[0]
        hi_oh = (si[..., None] // bf == ia).astype(dt)   # (r, m, A)
        lo_oh = (si[..., None] % bf == ib).astype(dt)    # (r, m, B)
        contract = lambda lhs: jnp.einsum(
            "rma,rmb->rab", lhs, lo_oh).reshape(r, -1)[:, :nslots]
        cnt = contract(hi_oh)
        out = [acc[0] + cnt.astype(jnp.int32)]
        for k, v in enumerate(args[1:]):
            out.append(acc[k + 1] + contract(hi_oh * v[..., None]))
        return tuple(out), None

    acc0 = (jnp.zeros((r, nslots), jnp.int32),) + tuple(
        jnp.zeros((r, nslots), dt) for _ in rows)
    acc, _ = jax.lax.scan(body, acc0, (sl, *vals))
    return [a.reshape(lead + (nslots,)) for a in acc]


def _hist_multi_shared(x, edges, rows, nslots: int, dt):
    """ONE-SWEEP shared-x multi-ladder histogram (the jnp analogue of the
    multi-bracket Pallas kernel): ``x`` (n,) is read once per chunk and
    every ladder's ``(nslots,)`` slot vector is accumulated from the
    resident chunk — the K ladders share every data pass instead of
    paying K broadcast passes, and no ``(K, n)`` intermediate ever exists
    (everything per-chunk is capped at ``(K, HIST_CHUNK)``).

    Exactness: the per-chunk slots are the verified arithmetic candidates
    + ±1 widening of :func:`arithmetic_slots`, certified PER LADDER by
    :func:`_candidates_certified_rows`; when every ladder certifies, the
    scan runs arithmetic-only, otherwise a mixed scan also binary-searches
    the chunk and each uncertified ladder takes the searchsorted slots —
    per-k rescue, bit-identical counts to the searchsorted oracle either
    way.  Count/sum accumulation follows :func:`_factored_hist` (per-chunk
    0/1 sums exact in f32, int32 across chunks; value rows in ``dt``).

    Returns ``[cnt int32, *sums dt]``, each shaped ``(K, nslots)``.
    """
    kk = edges.shape[0]
    n = x.shape[-1]
    m = min(HIST_CHUNK, max(n, 1))
    npad = -(-n // m) * m
    nc = npad // m
    bf = int(np.ceil(np.sqrt(nslots)))
    af = -(-nslots // bf)
    sent = af * bf  # pad sentinel: hi factor == af matches no column
    xp = jnp.pad(x, (0, npad - n)).reshape(nc, m)
    validc = (jnp.arange(npad, dtype=jnp.int32) < n).reshape(nc, m)
    vals = [jnp.pad(jnp.asarray(v, dt), (0, npad - n)).reshape(nc, m)
            for v in rows]
    certs = _candidates_certified_rows(edges)  # (K,)
    ia = jnp.arange(af, dtype=jnp.int32)
    ib = jnp.arange(bf, dtype=jnp.int32)

    def _slots_arith(xc):
        return arithmetic_slots(xc, edges)  # (K, m)

    def _slots_mixed(xc):
        # per-k rescue: only uncertified ladders take the binary search
        return jnp.where(certs[:, None], arithmetic_slots(xc, edges),
                         searchsorted_slots(xc, edges))

    def _body(chunk_slots):
        def body(acc, args):
            xc, vc = args[0], args[1]
            si = jnp.where(vc, chunk_slots(xc), sent)  # (K, m)
            hi_oh = (si[..., None] // bf == ia).astype(dt)  # (K, m, A)
            lo_oh = (si[..., None] % bf == ib).astype(dt)   # (K, m, B)
            contract = lambda lhs: jnp.einsum(
                "kma,kmb->kab", lhs, lo_oh).reshape(kk, -1)[:, :nslots]
            out = [acc[0] + contract(hi_oh).astype(jnp.int32)]
            for i, v in enumerate(args[2:]):
                out.append(acc[i + 1] + contract(hi_oh * v[None, :, None]))
            return tuple(out), None
        return body

    acc0 = (jnp.zeros((kk, nslots), jnp.int32),) + tuple(
        jnp.zeros((kk, nslots), dt) for _ in rows)
    run = lambda cs: jax.lax.scan(_body(cs), acc0, (xp, validc, *vals))[0]
    acc = jax.lax.cond(jnp.all(certs),
                       lambda: run(_slots_arith),
                       lambda: run(_slots_mixed))
    return list(acc)


def _hist_ref(x, edges, rows, *, impl, want_sums):
    """Shared histogram-oracle core: slot assignment (per ``impl``) + the
    per-slot reductions.  ``rows(x)`` builds the value rows to sum (beyond
    the implicit count row); sums are skipped when ``want_sums`` is False
    AND the impl has separate sum cost.  Leading dims of ``x``/``edges``
    broadcast (rows mode: (B, n) x with (B, nbins+1) edges; multi mode:
    (n,) x with (K, nbins+1) edges)."""
    nbins = edges.shape[-1] - 1
    nslots = nbins + 2
    dt = edges.dtype
    if impl == "searchsorted":
        # legacy scatter accumulation: bit-compatible with the historical
        # oracle (sums in data order), the differential reference
        slot = searchsorted_slots(x, edges)
        lead = slot.shape[:-1]
        xb = jnp.broadcast_to(x, slot.shape)
        vals = [jnp.broadcast_to(jnp.asarray(v, dt), slot.shape)
                for v in rows]

        def one(si, *vi):
            cnt = jnp.zeros((nslots,), jnp.int32).at[si].add(1)
            return (cnt,) + tuple(
                jnp.zeros((nslots,), dt).at[si].add(v) for v in vi)

        if lead:
            flat = jax.vmap(one)(
                slot.reshape((-1,) + slot.shape[-1:]),
                *(v.reshape((-1,) + slot.shape[-1:]) for v in vals))
            return [a.reshape(lead + (nslots,)) for a in flat]
        return list(one(slot, *vals))
    if x.ndim == 1 and edges.ndim == 2:
        # shared-x multi mode: one sweep serves every ladder (no (K, n))
        return _hist_multi_shared(x, edges, rows if want_sums else (),
                                  nslots, dt)
    slot = bin_slots(x, edges, impl)
    return _factored_hist(slot, rows if want_sums else (), nslots, dt)


def cp_histogram_ref(x: jax.Array, edges: jax.Array, *,
                     impl: str = "searchsorted", want_sums: bool = True):
    """Oracle for kernels.cp_objective.cp_histogram: ``x`` (n,), realized
    edges ``(nbins+1,)`` (monotone, from :func:`bin_edges`).

    Slot layout in :func:`searchsorted_slots`.  Counts int32, sums in the
    promoted accumulate dtype (f64 stays f64 — the x64-exact path).
    ``impl`` selects the slotting: ``'searchsorted'`` (binary search +
    scatter, the historical reference) or ``'arithmetic'`` (verified
    multiply/floor/clip slots + factored one-hot reduction — bit-identical
    counts, CPU-fast; see :func:`bin_slots`).  ``want_sums=False`` skips
    the per-slot sums on the arithmetic path (plain binned sweeps never
    read them) and returns ``bsum=None``.
    """
    dt = _accum_dtype(x)
    x = x.reshape(-1).astype(dt)
    nbins = edges.shape[-1] - 1
    # no value-changing cast: the engine builds edges at (at least) the
    # promoted dtype, so this astype is an identity
    edges = jnp.asarray(edges, dt).reshape(nbins + 1)
    out = _hist_ref(x, edges, (x,), impl=impl, want_sums=want_sums)
    return out[0], (out[1] if len(out) > 1 else None)


def cp_histogram_batched_ref(x: jax.Array, edges: jax.Array, *,
                             impl: str = "searchsorted",
                             want_sums: bool = True):
    """Oracle for kernels.cp_objective.cp_histogram_batched: ``x`` (B, n),
    per-row edges ``(B, nbins+1)``; returns ``(cnt, bsum)`` of shape
    ``(B, nbins + 2)``."""
    dt = _accum_dtype(x)
    x = x.astype(dt)
    edges = jnp.asarray(edges, dt)
    out = _hist_ref(x, edges, (x,), impl=impl, want_sums=want_sums)
    return out[0], (out[1] if len(out) > 1 else None)


def cp_histogram_multi_ref(x: jax.Array, edges: jax.Array, *,
                           impl: str = "searchsorted",
                           want_sums: bool = True):
    """Oracle for kernels.cp_objective.cp_histogram_multi: one shared ``x``
    (n,), per-pivot edges ``(K, nbins+1)``; returns ``(cnt, bsum)`` of
    shape ``(K, nbins + 2)``."""
    dt = _accum_dtype(x)
    x = x.reshape(-1).astype(dt)
    edges = jnp.asarray(edges, dt)
    out = _hist_ref(x, edges, (x,), impl=impl, want_sums=want_sums)
    return out[0], (out[1] if len(out) > 1 else None)


# ---------------------------------------------------------------------------
# Weighted selection: fused weighted-partials and weighted-histogram oracles
# ---------------------------------------------------------------------------


def _waccum_dtype(x, w):
    # Weighted accumulation promotes BOTH operands (f64 weights on f32 data
    # must accumulate mass in f64 — the x64-exact path mirrors counts).
    return jnp.promote_types(jnp.promote_types(x.dtype, w.dtype),
                             jnp.float32)


def wcp_partials_ref(x: jax.Array, w: jax.Array, y: jax.Array):
    """Oracle for kernels.cp_objective.wcp_partials: six additive partials
    ``(wsum_pos, wsum_neg, w_lt, w_le, n_lt, n_le)`` — weighted objective
    terms, weight masses below/at-or-below the pivot, and the element
    counts (which still drive the cap-based stopping rule)."""
    dt = _waccum_dtype(x, w)
    x = x.reshape(-1).astype(dt)
    w = w.reshape(-1).astype(dt)
    y = jnp.asarray(y, dt)
    d = x - y
    zero = jnp.zeros_like(x)
    wsum_pos = jnp.sum(jnp.where(d > 0, w * d, zero))
    wsum_neg = jnp.sum(jnp.where(d < 0, -w * d, zero))
    w_lt = jnp.sum(jnp.where(d < 0, w, zero))
    w_le = jnp.sum(jnp.where(d <= 0, w, zero))
    n_lt = jnp.sum(d < 0, dtype=jnp.int32)
    n_le = jnp.sum(d <= 0, dtype=jnp.int32)
    return wsum_pos, wsum_neg, w_lt, w_le, n_lt, n_le


def wcp_partials_batched_ref(x: jax.Array, w: jax.Array, y: jax.Array):
    """Oracle for kernels.cp_objective.wcp_partials_batched: ``x``/``w``
    (B, n), ``y`` (B,); returns six (B,) vectors."""
    dt = _waccum_dtype(x, w)
    return jax.vmap(wcp_partials_ref)(x.astype(dt), w.astype(dt),
                                      jnp.asarray(y, dt))


def wcp_partials_multi_ref(x: jax.Array, w: jax.Array, y: jax.Array):
    """Oracle for kernels.cp_objective.wcp_partials_multi: shared ``x``/``w``
    (n,), ``y`` (K,) pivots; returns six (K,) vectors."""
    dt = _waccum_dtype(x, w)
    return jax.vmap(wcp_partials_ref, in_axes=(None, None, 0))(
        x.reshape(-1).astype(dt), w.reshape(-1).astype(dt),
        jnp.asarray(y, dt)
    )


def _whist_ref(x, w, edges, *, impl, want_sums):
    """Weighted histogram core: the mass row ``w`` always rides (it is the
    narrowing signal), ``w*x`` only when ``want_sums`` (the polish
    ingredient).  On the arithmetic path ``want_sums=False`` therefore
    still returns ``(cnt, wcnt, None)``."""
    if impl == "searchsorted":
        out = _hist_ref(x, edges, (w, w * x), impl=impl,
                        want_sums=want_sums)
        return out[0], out[1], out[2]
    nslots = edges.shape[-1] + 1
    rows = (w, w * x) if want_sums else (w,)
    if x.ndim == 1 and edges.ndim == 2:
        # shared-x multi mode: one sweep serves every ladder (no (K, n))
        out = _hist_multi_shared(x, edges, rows, nslots, edges.dtype)
    else:
        slot = bin_slots(x, edges, impl)
        out = _factored_hist(slot, rows, nslots, edges.dtype)
    return out[0], out[1], (out[2] if len(out) > 2 else None)


def wcp_histogram_ref(x: jax.Array, w: jax.Array, edges: jax.Array, *,
                      impl: str = "searchsorted", want_sums: bool = True):
    """Oracle for kernels.cp_objective.wcp_histogram: same slot layout as
    :func:`cp_histogram_ref`, returning ``(cnt, wcnt, wsum)`` — counts,
    per-slot weight mass sum(w_i) and per-slot sum(w_i * x_i).  ``impl``
    as in :func:`cp_histogram_ref`; ``want_sums=False`` skips ``wsum``
    (returned as ``None``) on the arithmetic path — the mass vector
    ``wcnt`` always rides (it IS the weighted narrowing signal)."""
    dt = _waccum_dtype(x, w)
    x = x.reshape(-1).astype(dt)
    w = w.reshape(-1).astype(dt)
    nbins = edges.shape[-1] - 1
    # no value-changing cast: the engine builds edges at (at least) the
    # promoted dtype, so this astype is an identity
    edges = jnp.asarray(edges, dt).reshape(nbins + 1)
    return _whist_ref(x, w, edges, impl=impl, want_sums=want_sums)


def wcp_histogram_batched_ref(x: jax.Array, w: jax.Array,
                              edges: jax.Array, *,
                              impl: str = "searchsorted",
                              want_sums: bool = True):
    """Oracle for kernels.cp_objective.wcp_histogram_batched: ``x``/``w``
    (B, n), per-row edges ``(B, nbins+1)``; outputs ``(B, nbins + 2)``."""
    dt = _waccum_dtype(x, w)
    return _whist_ref(x.astype(dt), jnp.asarray(w, dt),
                      jnp.asarray(edges, dt), impl=impl,
                      want_sums=want_sums)


def wcp_histogram_multi_ref(x: jax.Array, w: jax.Array, edges: jax.Array, *,
                            impl: str = "searchsorted",
                            want_sums: bool = True):
    """Oracle for kernels.cp_objective.wcp_histogram_multi: shared
    ``x``/``w`` (n,), per-pivot edges ``(K, nbins+1)``; outputs
    ``(K, nbins + 2)``."""
    dt = _waccum_dtype(x, w)
    return _whist_ref(x.reshape(-1).astype(dt),
                      jnp.asarray(w, dt).reshape(-1),
                      jnp.asarray(edges, dt), impl=impl,
                      want_sums=want_sums)


# ---------------------------------------------------------------------------
# Segmented selection: per-segment slot assignment + histogram (each element
# binned against its OWN segment's edge ladder — the per-leaf quantile pass)
# ---------------------------------------------------------------------------


def segmented_slots(x: jax.Array, seg: jax.Array,
                    edges: jax.Array) -> jax.Array:
    """Per-element slot within its own segment's ladder:
    ``searchsorted_slots(x_i, edges[seg_i])`` without materializing
    per-element edge rows.

    Branchless binary search over the flattened ``(K, nbins+1)`` edge
    array — ``ceil(log2(nbins+2))`` rounds of (n,)-shaped gathers, so K
    ladders cost no extra memory traffic and no ``(n, nbins)`` or
    ``(K, n)`` intermediate exists.  Comparisons run under the platform's
    fp semantics against the REALIZED edges (the exactness contract), and
    the result is bit-identical to the searchsorted oracle applied
    segment-wise: ``pos = count(edges[seg] < x)`` with NaN forced to the
    top slot (every NaN comparison is false — binary search walks right).
    """
    ne = edges.shape[-1]
    ef = edges.reshape(-1)
    seg = jnp.asarray(seg, jnp.int32)
    base = seg * ne
    pos = jnp.zeros(x.shape, jnp.int32)
    step = 1
    while step * 2 <= ne:
        step *= 2
    # invariant: all edges[seg][:pos] < x; steps p, p/2, .., 1 reach any
    # count in [0, ne] (2p - 1 >= ne)
    while step:
        cand = pos + step
        e = ef[jnp.clip(base + cand - 1, 0, ef.shape[0] - 1)]
        pos = jnp.where((cand <= ne) & (e < x), cand, pos)
        step //= 2
    return jnp.where(jnp.isnan(x), ne, pos).astype(jnp.int32)


def segmented_histogram_ref(x: jax.Array, seg: jax.Array, edges: jax.Array,
                            rows=()):
    """Per-segment histogram in ONE data pass: element ``i`` lands in slot
    ``segmented_slots(x, seg, edges)[i]`` of segment ``seg[i]``'s
    ``(nbins+2,)`` vector.  The flattened slot id ``seg*(nbins+2) + slot``
    feeds the factored one-hot reduction, so all K segment histograms come
    from one chunked sweep.  Returns ``[cnt int32, *sums]``, each
    ``(K, nbins+2)`` (``rows`` as in :func:`_factored_hist`)."""
    kk = edges.shape[0]
    nslots = edges.shape[-1] + 1
    dt = edges.dtype
    x = jnp.asarray(x, dt)
    slot = segmented_slots(x, seg, edges)
    flat = jnp.asarray(seg, jnp.int32) * nslots + slot
    out = _factored_hist(flat, tuple(jnp.asarray(v, dt) for v in rows),
                         kk * nslots, dt)
    return [a.reshape(kk, nslots) for a in out]
