"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _accum_dtype(x):
    # The TPU kernel accumulates in f32, so the oracle promotes low-precision
    # inputs (bf16) to f32 for bit-comparable partials — but NEVER downcasts:
    # f64 selection (x64 mode on CPU) must keep full precision or the count
    # certificates lie about exactness.
    return jnp.promote_types(x.dtype, jnp.float32)


def cp_partials_ref(x: jax.Array, y: jax.Array):
    """Oracle for kernels.cp_objective.cp_partials."""
    dt = _accum_dtype(x)
    x = x.reshape(-1).astype(dt)
    y = jnp.asarray(y, dt)
    d = x - y
    sum_pos = jnp.sum(jnp.maximum(d, 0))
    sum_neg = jnp.sum(jnp.maximum(-d, 0))
    n_lt = jnp.sum(d < 0, dtype=jnp.int32)
    n_le = jnp.sum(d <= 0, dtype=jnp.int32)
    return sum_pos, sum_neg, n_lt, n_le


def cp_partials_batched_ref(x: jax.Array, y: jax.Array):
    """Oracle for kernels.cp_objective.cp_partials_batched."""
    dt = _accum_dtype(x)
    return jax.vmap(cp_partials_ref)(x.astype(dt), jnp.asarray(y, dt))


def cp_partials_multi_ref(x: jax.Array, y: jax.Array):
    """Oracle for kernels.cp_objective.cp_partials_multi: one shared ``x``
    (n,), ``y`` is (K,) pivots; returns four (K,) vectors."""
    dt = _accum_dtype(x)
    return jax.vmap(cp_partials_ref, in_axes=(None, 0))(
        x.reshape(-1).astype(dt), jnp.asarray(y, dt)
    )
