"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _accum_dtype(x):
    # The TPU kernel accumulates in f32, so the oracle promotes low-precision
    # inputs (bf16) to f32 for bit-comparable partials — but NEVER downcasts:
    # f64 selection (x64 mode on CPU) must keep full precision or the count
    # certificates lie about exactness.
    return jnp.promote_types(x.dtype, jnp.float32)


def cp_partials_ref(x: jax.Array, y: jax.Array):
    """Oracle for kernels.cp_objective.cp_partials."""
    dt = _accum_dtype(x)
    x = x.reshape(-1).astype(dt)
    y = jnp.asarray(y, dt)
    d = x - y
    sum_pos = jnp.sum(jnp.maximum(d, 0))
    sum_neg = jnp.sum(jnp.maximum(-d, 0))
    n_lt = jnp.sum(d < 0, dtype=jnp.int32)
    n_le = jnp.sum(d <= 0, dtype=jnp.int32)
    return sum_pos, sum_neg, n_lt, n_le


def cp_partials_batched_ref(x: jax.Array, y: jax.Array):
    """Oracle for kernels.cp_objective.cp_partials_batched."""
    dt = _accum_dtype(x)
    return jax.vmap(cp_partials_ref)(x.astype(dt), jnp.asarray(y, dt))


def cp_partials_multi_ref(x: jax.Array, y: jax.Array):
    """Oracle for kernels.cp_objective.cp_partials_multi: one shared ``x``
    (n,), ``y`` is (K,) pivots; returns four (K,) vectors."""
    dt = _accum_dtype(x)
    return jax.vmap(cp_partials_ref, in_axes=(None, 0))(
        x.reshape(-1).astype(dt), jnp.asarray(y, dt)
    )


# ---------------------------------------------------------------------------
# Binned bracket descent: histogram oracles
# ---------------------------------------------------------------------------


def bin_edges(lo, hi, nbins: int):
    """Realized fp bin-edge values ``e_j = clip(lo + w*j, lo, hi)`` with
    ``w = hi/nbins - lo/nbins`` and ``e_nbins`` forced to ``hi`` exactly,
    appended as a trailing axis of size ``nbins + 1``.

    SINGLE SOURCE OF TRUTH for edge construction: the engine computes the
    edges ONCE per sweep with this function and passes the realized array
    to the histogram kernels/oracles, which only COMPARE against it — no
    consumer ever recomputes edge arithmetic (XLA FMA contraction makes
    recomputed ``lo + w*j`` fusion-context-dependent), so histogram counts
    stay bit-consistent with the engine's later ``x <= e_j`` narrowing and
    finalize comparisons.  The sequence is monotone non-decreasing in fp
    (``w >= 0``, ``w*j`` and ``lo + t`` are monotone, clip preserves
    order), which the bin-index search relies on.

    Overflow safety: ``(hi - lo)`` overflows f32 for full-range brackets
    (e.g. data spanning ±3e38 — width inf, NaN edges, garbage descent), so
    ``w`` divides BEFORE differencing (each term <= f32max/nbins; their
    difference <= f32max for nbins >= 2) and ``lo + w*j`` — which can still
    overflow for large j — is clipped into ``[lo, hi]`` (collapsed top bins
    are just empty).
    """
    lo = jnp.asarray(lo)
    hi = jnp.asarray(hi, lo.dtype)
    w = hi / nbins - lo / nbins
    j = jnp.arange(nbins + 1)
    e = jnp.clip(lo[..., None] + w[..., None] * j.astype(lo.dtype),
                 lo[..., None], hi[..., None])
    return jnp.where(j == nbins, hi[..., None], e)


def cp_histogram_ref(x: jax.Array, edges: jax.Array):
    """Oracle for kernels.cp_objective.cp_histogram: ``x`` (n,), realized
    edges ``(nbins+1,)`` (monotone, from :func:`bin_edges`).

    Slot layout (``nbins + 2`` slots): 0 = ``x <= e_0``; j in 1..nbins =
    ``e_{j-1} < x <= e_j``; nbins+1 = ``x > e_nbins``.  Counts int32, sums
    in the promoted accumulate dtype (f64 stays f64 — the x64-exact path).
    Memory O(n): bin indices by binary search against the realized edges,
    then one scatter-add per output.
    """
    dt = _accum_dtype(x)
    x = x.reshape(-1).astype(dt)
    nbins = edges.shape[-1] - 1
    # no value-changing cast: the engine builds edges at (at least) the
    # promoted dtype, so this astype is an identity
    edges = jnp.asarray(edges, dt).reshape(nbins + 1)
    # slot = count(edges < x): 0 for x <= e_0, j for e_{j-1} < x <= e_j,
    # nbins+1 for x > e_nbins — searchsorted('left') on the sorted edges.
    slot = jnp.searchsorted(edges, x, side="left").astype(jnp.int32)
    nslots = nbins + 2
    cnt = jnp.zeros((nslots,), jnp.int32).at[slot].add(1)
    bsum = jnp.zeros((nslots,), dt).at[slot].add(x)
    return cnt, bsum


def cp_histogram_batched_ref(x: jax.Array, edges: jax.Array):
    """Oracle for kernels.cp_objective.cp_histogram_batched: ``x`` (B, n),
    per-row edges ``(B, nbins+1)``; returns ``(cnt, bsum)`` of shape
    ``(B, nbins + 2)``."""
    return jax.vmap(cp_histogram_ref)(x, edges)


def cp_histogram_multi_ref(x: jax.Array, edges: jax.Array):
    """Oracle for kernels.cp_objective.cp_histogram_multi: one shared ``x``
    (n,), per-pivot edges ``(K, nbins+1)``; returns ``(cnt, bsum)`` of
    shape ``(K, nbins + 2)``."""
    return jax.vmap(cp_histogram_ref, in_axes=(None, 0))(x.reshape(-1),
                                                         edges)


# ---------------------------------------------------------------------------
# Weighted selection: fused weighted-partials and weighted-histogram oracles
# ---------------------------------------------------------------------------


def _waccum_dtype(x, w):
    # Weighted accumulation promotes BOTH operands (f64 weights on f32 data
    # must accumulate mass in f64 — the x64-exact path mirrors counts).
    return jnp.promote_types(jnp.promote_types(x.dtype, w.dtype),
                             jnp.float32)


def wcp_partials_ref(x: jax.Array, w: jax.Array, y: jax.Array):
    """Oracle for kernels.cp_objective.wcp_partials: six additive partials
    ``(wsum_pos, wsum_neg, w_lt, w_le, n_lt, n_le)`` — weighted objective
    terms, weight masses below/at-or-below the pivot, and the element
    counts (which still drive the cap-based stopping rule)."""
    dt = _waccum_dtype(x, w)
    x = x.reshape(-1).astype(dt)
    w = w.reshape(-1).astype(dt)
    y = jnp.asarray(y, dt)
    d = x - y
    zero = jnp.zeros_like(x)
    wsum_pos = jnp.sum(jnp.where(d > 0, w * d, zero))
    wsum_neg = jnp.sum(jnp.where(d < 0, -w * d, zero))
    w_lt = jnp.sum(jnp.where(d < 0, w, zero))
    w_le = jnp.sum(jnp.where(d <= 0, w, zero))
    n_lt = jnp.sum(d < 0, dtype=jnp.int32)
    n_le = jnp.sum(d <= 0, dtype=jnp.int32)
    return wsum_pos, wsum_neg, w_lt, w_le, n_lt, n_le


def wcp_partials_batched_ref(x: jax.Array, w: jax.Array, y: jax.Array):
    """Oracle for kernels.cp_objective.wcp_partials_batched: ``x``/``w``
    (B, n), ``y`` (B,); returns six (B,) vectors."""
    dt = _waccum_dtype(x, w)
    return jax.vmap(wcp_partials_ref)(x.astype(dt), w.astype(dt),
                                      jnp.asarray(y, dt))


def wcp_partials_multi_ref(x: jax.Array, w: jax.Array, y: jax.Array):
    """Oracle for kernels.cp_objective.wcp_partials_multi: shared ``x``/``w``
    (n,), ``y`` (K,) pivots; returns six (K,) vectors."""
    dt = _waccum_dtype(x, w)
    return jax.vmap(wcp_partials_ref, in_axes=(None, None, 0))(
        x.reshape(-1).astype(dt), w.reshape(-1).astype(dt),
        jnp.asarray(y, dt)
    )


def wcp_histogram_ref(x: jax.Array, w: jax.Array, edges: jax.Array):
    """Oracle for kernels.cp_objective.wcp_histogram: same slot layout as
    :func:`cp_histogram_ref`, returning ``(cnt, wcnt, wsum)`` — counts,
    per-slot weight mass sum(w_i) and per-slot sum(w_i * x_i)."""
    dt = _waccum_dtype(x, w)
    x = x.reshape(-1).astype(dt)
    w = w.reshape(-1).astype(dt)
    nbins = edges.shape[-1] - 1
    # no value-changing cast: the engine builds edges at (at least) the
    # promoted dtype, so this astype is an identity
    edges = jnp.asarray(edges, dt).reshape(nbins + 1)
    slot = jnp.searchsorted(edges, x, side="left").astype(jnp.int32)
    nslots = nbins + 2
    cnt = jnp.zeros((nslots,), jnp.int32).at[slot].add(1)
    wcnt = jnp.zeros((nslots,), dt).at[slot].add(w)
    wsum = jnp.zeros((nslots,), dt).at[slot].add(w * x)
    return cnt, wcnt, wsum


def wcp_histogram_batched_ref(x: jax.Array, w: jax.Array,
                              edges: jax.Array):
    """Oracle for kernels.cp_objective.wcp_histogram_batched: ``x``/``w``
    (B, n), per-row edges ``(B, nbins+1)``; outputs ``(B, nbins + 2)``."""
    return jax.vmap(wcp_histogram_ref)(x, w, edges)


def wcp_histogram_multi_ref(x: jax.Array, w: jax.Array, edges: jax.Array):
    """Oracle for kernels.cp_objective.wcp_histogram_multi: shared
    ``x``/``w`` (n,), per-pivot edges ``(K, nbins+1)``; outputs
    ``(K, nbins + 2)``."""
    return jax.vmap(wcp_histogram_ref, in_axes=(None, None, 0))(
        x.reshape(-1), w.reshape(-1), edges)
