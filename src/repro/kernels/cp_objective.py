"""Pallas TPU kernel: fused selection-objective transform-reduce.

This is the compute hot-spot of the paper — the GPU code's
``thrust::transform_reduce`` (Fig. 1), executed ``maxit`` times per
selection.  On TPU we tile the array HBM -> VMEM in ``(block_rows, 128)``
blocks and emit *per-block partials*

    (sum_pos, sum_neg)  f32   and   (n_lt, n_le)  i32

for the pivot ``y``.  Partials are combined by a tiny tree-reduce outside the
kernel (parallel across MegaCore, no cross-grid accumulation races).  The
four partials are additive, which is exactly what makes the paper's method
shard-friendly: the same quadruple is psum'd across chips in
``core.distributed``.

Counts are carried as int32 (f32 mantissa overflows beyond 2^24 elements —
the paper's n reaches 1.34e8).

Layout notes (TPU-native, not a CUDA port):
  * last dim is the 128-lane VPU axis; ``block_rows`` a multiple of 8
    (f32 sublane tiling) — default (512, 128) = 256 KiB f32 per input tile,
    comfortably inside ~16 MiB VMEM with double buffering;
  * the pivot ``y`` is an SMEM scalar (prefetched, uniform across the tile);
  * masking by global element index handles the tail block, so any ``n``
    is supported without host-side padding corrections.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEF_BLOCK_ROWS = 512

# The histogram kernels build a (block_rows, LANES, nbins + 2) one-hot
# intermediate per tile (nbins comes from the caller's edge array; the
# engine default lives in core.selection.DEF_NBINS); 64 rows keeps that
# under ~4 MiB f32 in VMEM at the default 128 bins.
DEF_HIST_BLOCK_ROWS = 64


def _pad_to_tiles(x: jax.Array, block_rows: int):
    """Shared prologue of every kernel wrapper: pad the trailing dim of
    ``x`` to a whole number of ``(block_rows, LANES)`` tiles and expose the
    tile grid as the two trailing axes.

    Returns ``(x_tiled, nblocks)`` where ``x_tiled`` has shape
    ``(*leading, nblocks * block_rows, LANES)``.  The padded tail is masked
    inside the kernels via the global element index, so any ``n`` is
    supported without host-side padding corrections.
    """
    n = x.shape[-1]
    block = block_rows * LANES
    nblocks = max(1, -(-n // block))
    padded = nblocks * block
    if padded != n:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, padded - n)]
        x = jnp.pad(x, pad)
    return x.reshape(x.shape[:-1] + (nblocks * block_rows, LANES)), nblocks


def _valid_mask(b, shape, n, block_rows):
    """Tail mask for tile ``b`` of the grid: global element index < n."""
    rows = jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    return (b * block_rows + rows) * LANES + cols < n


def _partials_kernel(y_ref, x_ref, fsum_ref, cnt_ref, *, n, block_rows):
    b = pl.program_id(0)
    y = y_ref[0]
    x = x_ref[...].astype(jnp.float32)  # (block_rows, LANES)
    valid = _valid_mask(b, x.shape, n, block_rows)

    d = x - y
    zero = jnp.zeros_like(x)
    sum_pos = jnp.sum(jnp.where(valid & (d > 0), d, zero))
    sum_neg = jnp.sum(jnp.where(valid & (d < 0), -d, zero))
    lt = jnp.sum(jnp.where(valid & (d < 0), 1, 0).astype(jnp.int32))
    le = jnp.sum(jnp.where(valid & (d <= 0), 1, 0).astype(jnp.int32))

    fsum_ref[0, 0] = sum_pos
    fsum_ref[0, 1] = sum_neg
    cnt_ref[0, 0] = lt
    cnt_ref[0, 1] = le


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret")
)
def cp_partials(
    x: jax.Array,
    y: jax.Array,
    *,
    block_rows: int = DEF_BLOCK_ROWS,
    interpret: bool = False,
):
    """Per-pivot fused partials of the selection objective.

    Returns ``(sum_pos, sum_neg, n_lt, n_le)`` scalars, bit-identical in
    count terms to the pure-jnp oracle ``kernels.ref.cp_partials_ref``.
    """
    n = x.size
    x2, nblocks = _pad_to_tiles(x.reshape(-1), block_rows)
    y = jnp.asarray(y, jnp.float32).reshape(1)

    fsum, cnt = pl.pallas_call(
        functools.partial(_partials_kernel, n=n, block_rows=block_rows),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # y: tiny, whole-array
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 2), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, 2), jnp.float32),
            jax.ShapeDtypeStruct((nblocks, 2), jnp.int32),
        ],
        interpret=interpret,
    )(y, x2)
    sums = jnp.sum(fsum, axis=0)
    cnts = jnp.sum(cnt, axis=0)
    return sums[0], sums[1], cnts[0], cnts[1]


def _batched_kernel(y_ref, x_ref, fsum_ref, cnt_ref, *, n, block_rows):
    r = pl.program_id(0)  # problem row
    b = pl.program_id(1)  # block within the row
    y = y_ref[r]
    x = x_ref[0].astype(jnp.float32)  # (block_rows, LANES)
    valid = _valid_mask(b, x.shape, n, block_rows)

    d = x - y
    zero = jnp.zeros_like(x)
    fsum_ref[0, 0, 0] = jnp.sum(jnp.where(valid & (d > 0), d, zero))
    fsum_ref[0, 0, 1] = jnp.sum(jnp.where(valid & (d < 0), -d, zero))
    cnt_ref[0, 0, 0] = jnp.sum(jnp.where(valid & (d < 0), 1, 0).astype(jnp.int32))
    cnt_ref[0, 0, 1] = jnp.sum(jnp.where(valid & (d <= 0), 1, 0).astype(jnp.int32))


def _multi_kernel(y_ref, x_ref, fsum_ref, cnt_ref, *, n, npiv, block_rows):
    """One x tile, ALL K pivots: the tile is read HBM -> VMEM once and the
    K per-pivot partial quadruples are computed from registers/VMEM — K× less
    HBM traffic than K independent passes (the win behind shared-x batched
    selection: a quantile set costs one sweep per iteration, not K).

    K is static (the pivot vector's shape), so the pivot loop is unrolled at
    trace time; all stores use static indices.
    """
    b = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # (block_rows, LANES)
    valid = _valid_mask(b, x.shape, n, block_rows)

    zero = jnp.zeros_like(x)
    for j in range(npiv):  # static unroll: npiv is a trace-time constant
        d = x - y_ref[j]
        fsum_ref[0, j, 0] = jnp.sum(jnp.where(valid & (d > 0), d, zero))
        fsum_ref[0, j, 1] = jnp.sum(jnp.where(valid & (d < 0), -d, zero))
        cnt_ref[0, j, 0] = jnp.sum(
            jnp.where(valid & (d < 0), 1, 0).astype(jnp.int32))
        cnt_ref[0, j, 1] = jnp.sum(
            jnp.where(valid & (d <= 0), 1, 0).astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def cp_partials_multi(
    x: jax.Array,
    y: jax.Array,
    *,
    block_rows: int = DEF_BLOCK_ROWS,
    interpret: bool = False,
):
    """Shared-x multi-pivot partials: ``x`` is (n,), ``y`` is (K,) pivots.

    Returns four (K,) vectors ``(sum_pos, sum_neg, n_lt, n_le)``; count
    terms bit-identical to ``kernels.ref.cp_partials_multi_ref``.  This is
    the data pass of shared-x batched selection (``multi_order_statistic`` /
    ``quantiles``): all K brackets iterate against one sweep of ``x``.
    """
    n = x.size
    npiv = y.shape[0]
    x2, nblocks = _pad_to_tiles(x.reshape(-1), block_rows)
    y = jnp.asarray(y, jnp.float32).reshape(npiv)

    fsum, cnt = pl.pallas_call(
        functools.partial(_multi_kernel, n=n, npiv=npiv,
                          block_rows=block_rows),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # y: tiny, whole-array
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, npiv, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, npiv, 2), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, npiv, 2), jnp.float32),
            jax.ShapeDtypeStruct((nblocks, npiv, 2), jnp.int32),
        ],
        interpret=interpret,
    )(y, x2)
    sums = jnp.sum(fsum, axis=0)
    cnts = jnp.sum(cnt, axis=0)
    return sums[:, 0], sums[:, 1], cnts[:, 0], cnts[:, 1]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def cp_partials_batched(
    x: jax.Array,
    y: jax.Array,
    *,
    block_rows: int = DEF_BLOCK_ROWS,
    interpret: bool = False,
):
    """Row-wise partials: ``x`` is (B, n), ``y`` is (B,) pivots.

    Used by the vectorized selection solver (coordinate-wise medians for
    robust gradient aggregation solve millions of small problems at once).
    Returns four (B,) vectors.
    """
    bsz, n = x.shape
    x3, nblocks = _pad_to_tiles(x, block_rows)
    y = jnp.asarray(y, jnp.float32).reshape(bsz)

    fsum, cnt = pl.pallas_call(
        functools.partial(_batched_kernel, n=n, block_rows=block_rows),
        grid=(bsz, nblocks),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, block_rows, LANES), lambda r, b: (r, b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 2), lambda r, b: (r, b, 0)),
            pl.BlockSpec((1, 1, 2), lambda r, b: (r, b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nblocks, 2), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nblocks, 2), jnp.int32),
        ],
        interpret=interpret,
    )(y, x3)
    sums = jnp.sum(fsum, axis=1)
    cnts = jnp.sum(cnt, axis=1)
    return sums[..., 0], sums[..., 1], cnts[..., 0], cnts[..., 1]


# ---------------------------------------------------------------------------
# Weighted selection objective: fused weighted partials
# ---------------------------------------------------------------------------
#
# The weighted generalization F_w(y) = sum_i w_i * rho(x_i - y) (whose
# minimizer is the weighted order statistic — the primitive behind weighted
# medians in Theil-Sen and IRLS reweighting) needs SIX additive partials per
# pivot instead of four:
#
#     (wsum_pos, wsum_neg)   f32   sum of w*(x-y)+ / w*(y-x)+
#     (w_lt, w_le)           f32   weight MASS below / at-or-below the pivot
#     (n_lt, n_le)           i32   element COUNTS (drive the cap-based
#                                  stopping rule — buffer capacity is a
#                                  count, not a mass)
#
# All six are additive over blocks/shards, so the multi-device combine stays
# a psum, exactly like the unweighted quadruple.  Weights ride the same tile
# layout as x (padded tail masked by the global element index; padded weight
# lanes contribute nothing because the mask gates every accumulation).


def _wpartials_tile(x, w, valid, y):
    """Per-tile weighted partials for one pivot: six accumulators."""
    d = x - y
    zero = jnp.zeros_like(x)
    wsp = jnp.sum(jnp.where(valid & (d > 0), w * d, zero))
    wsn = jnp.sum(jnp.where(valid & (d < 0), -w * d, zero))
    wlt = jnp.sum(jnp.where(valid & (d < 0), w, zero))
    wle = jnp.sum(jnp.where(valid & (d <= 0), w, zero))
    nlt = jnp.sum(jnp.where(valid & (d < 0), 1, 0).astype(jnp.int32))
    nle = jnp.sum(jnp.where(valid & (d <= 0), 1, 0).astype(jnp.int32))
    return wsp, wsn, wlt, wle, nlt, nle


def _wpartials_kernel(y_ref, x_ref, w_ref, fsum_ref, cnt_ref, *, n,
                      block_rows):
    b = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # (block_rows, LANES)
    w = w_ref[...].astype(jnp.float32)
    valid = _valid_mask(b, x.shape, n, block_rows)
    wsp, wsn, wlt, wle, nlt, nle = _wpartials_tile(x, w, valid, y_ref[0])
    fsum_ref[0, 0] = wsp
    fsum_ref[0, 1] = wsn
    fsum_ref[0, 2] = wlt
    fsum_ref[0, 3] = wle
    cnt_ref[0, 0] = nlt
    cnt_ref[0, 1] = nle


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def wcp_partials(
    x: jax.Array,
    w: jax.Array,
    y: jax.Array,
    *,
    block_rows: int = DEF_BLOCK_ROWS,
    interpret: bool = False,
):
    """Weighted fused partials: ``x``/``w`` (n,), scalar pivot ``y``.

    Returns ``(wsum_pos, wsum_neg, w_lt, w_le, n_lt, n_le)`` scalars; count
    terms bit-identical to ``kernels.ref.wcp_partials_ref``.
    """
    n = x.size
    x2, nblocks = _pad_to_tiles(x.reshape(-1), block_rows)
    w2, _ = _pad_to_tiles(w.reshape(-1), block_rows)
    y = jnp.asarray(y, jnp.float32).reshape(1)

    fsum, cnt = pl.pallas_call(
        functools.partial(_wpartials_kernel, n=n, block_rows=block_rows),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # y: tiny, whole-array
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 4), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, 4), jnp.float32),
            jax.ShapeDtypeStruct((nblocks, 2), jnp.int32),
        ],
        interpret=interpret,
    )(y, x2, w2)
    s = jnp.sum(fsum, axis=0)
    c = jnp.sum(cnt, axis=0)
    return s[0], s[1], s[2], s[3], c[0], c[1]


def _wbatched_kernel(y_ref, x_ref, w_ref, fsum_ref, cnt_ref, *, n,
                     block_rows):
    r = pl.program_id(0)  # problem row
    b = pl.program_id(1)  # block within the row
    x = x_ref[0].astype(jnp.float32)  # (block_rows, LANES)
    w = w_ref[0].astype(jnp.float32)
    valid = _valid_mask(b, x.shape, n, block_rows)
    wsp, wsn, wlt, wle, nlt, nle = _wpartials_tile(x, w, valid, y_ref[r])
    fsum_ref[0, 0, 0] = wsp
    fsum_ref[0, 0, 1] = wsn
    fsum_ref[0, 0, 2] = wlt
    fsum_ref[0, 0, 3] = wle
    cnt_ref[0, 0, 0] = nlt
    cnt_ref[0, 0, 1] = nle


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def wcp_partials_batched(
    x: jax.Array,
    w: jax.Array,
    y: jax.Array,
    *,
    block_rows: int = DEF_BLOCK_ROWS,
    interpret: bool = False,
):
    """Row-wise weighted partials: ``x``/``w`` (B, n), ``y`` (B,) pivots.

    Returns six (B,) vectors ``(wsum_pos, wsum_neg, w_lt, w_le, n_lt,
    n_le)``.
    """
    bsz, n = x.shape
    x3, nblocks = _pad_to_tiles(x, block_rows)
    w3, _ = _pad_to_tiles(w, block_rows)
    y = jnp.asarray(y, jnp.float32).reshape(bsz)

    fsum, cnt = pl.pallas_call(
        functools.partial(_wbatched_kernel, n=n, block_rows=block_rows),
        grid=(bsz, nblocks),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, block_rows, LANES), lambda r, b: (r, b, 0)),
            pl.BlockSpec((1, block_rows, LANES), lambda r, b: (r, b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 4), lambda r, b: (r, b, 0)),
            pl.BlockSpec((1, 1, 2), lambda r, b: (r, b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nblocks, 4), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nblocks, 2), jnp.int32),
        ],
        interpret=interpret,
    )(y, x3, w3)
    s = jnp.sum(fsum, axis=1)
    c = jnp.sum(cnt, axis=1)
    return (s[..., 0], s[..., 1], s[..., 2], s[..., 3],
            c[..., 0], c[..., 1])


def _wmulti_kernel(y_ref, x_ref, w_ref, fsum_ref, cnt_ref, *, n, npiv,
                   block_rows):
    """One x/w tile pair, ALL K pivots — same VMEM-residency win as the
    unweighted multi kernel (K is static, the pivot loop unrolls)."""
    b = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # (block_rows, LANES)
    w = w_ref[...].astype(jnp.float32)
    valid = _valid_mask(b, x.shape, n, block_rows)
    for j in range(npiv):  # static unroll
        wsp, wsn, wlt, wle, nlt, nle = _wpartials_tile(x, w, valid, y_ref[j])
        fsum_ref[0, j, 0] = wsp
        fsum_ref[0, j, 1] = wsn
        fsum_ref[0, j, 2] = wlt
        fsum_ref[0, j, 3] = wle
        cnt_ref[0, j, 0] = nlt
        cnt_ref[0, j, 1] = nle


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def wcp_partials_multi(
    x: jax.Array,
    w: jax.Array,
    y: jax.Array,
    *,
    block_rows: int = DEF_BLOCK_ROWS,
    interpret: bool = False,
):
    """Shared-x weighted multi-pivot partials: ``x``/``w`` (n,), ``y`` (K,).

    Returns six (K,) vectors.
    """
    n = x.size
    npiv = y.shape[0]
    x2, nblocks = _pad_to_tiles(x.reshape(-1), block_rows)
    w2, _ = _pad_to_tiles(w.reshape(-1), block_rows)
    y = jnp.asarray(y, jnp.float32).reshape(npiv)

    fsum, cnt = pl.pallas_call(
        functools.partial(_wmulti_kernel, n=n, npiv=npiv,
                          block_rows=block_rows),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, npiv, 4), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, npiv, 2), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, npiv, 4), jnp.float32),
            jax.ShapeDtypeStruct((nblocks, npiv, 2), jnp.int32),
        ],
        interpret=interpret,
    )(y, x2, w2)
    s = jnp.sum(fsum, axis=0)
    c = jnp.sum(cnt, axis=0)
    return s[:, 0], s[:, 1], s[:, 2], s[:, 3], c[:, 0], c[:, 1]


# ---------------------------------------------------------------------------
# Binned bracket descent: multi-bin histogram kernels
# ---------------------------------------------------------------------------
#
# One sweep bins x against the current bracket's NBINS sub-intervals and
# emits additive (count, sum) partials per slot — the count vector
# localizes x_(k) to ONE bin (log2(NBINS) bisection steps of information
# per data pass) and the per-bin sums are the CP support-line ingredients
# (sum_pos/sum_neg at every edge by prefix sums), all for the HBM cost of a
# single fused pass.  Both outputs are additive over blocks/shards, so they
# psum across a mesh exactly like the FG quadruple.
#
# Slot layout (nbins + 2 slots for edges e_0 <= ... <= e_nbins):
#   slot 0          x <= e_0
#   slot j          e_{j-1} < x <= e_j          (j = 1..nbins)
#   slot nbins+1    x > e_nbins
# so prefix sums over slots 0..j give exact count(x <= e_j) / sum(x <= e_j)
# at every edge, and sum(cnt) == n is the per-row count invariant.
#
# EXACTNESS CONTRACT: the kernels take the REALIZED edge values — computed
# ONCE by the engine via ``kernels.ref.bin_edges`` — and only COMPARE
# against them.  Recomputing edges here from (lo, hi) would be unsound:
# XLA may contract ``lo + w*j`` into an FMA in one fusion context and not
# another, yielding different fp edges (observed at full-f32-range
# brackets); comparisons against one shared array cannot diverge, so the
# histogram counts are exactly consistent with the engine's later
# ``x <= e_j`` narrowing and finalize comparisons.


def _slot_bounds(edges):
    """``(..., nbins+1)`` edges -> ``(..., nbins+2)`` (lower, upper) slot
    bounds.  Pure concatenation — NO fp arithmetic (see the exactness
    contract above)."""
    ninf = jnp.full_like(edges[..., :1], -jnp.inf)
    pinf = jnp.full_like(edges[..., :1], jnp.inf)
    return (jnp.concatenate([ninf, edges], axis=-1),
            jnp.concatenate([edges, pinf], axis=-1))


def _bin_tile(x, valid, lower, upper):
    """Per-tile slot (count, sum) partials for one bracket.

    ``x``/``valid`` are ``(block_rows, LANES)``; ``lower``/``upper`` the
    ``(nbins + 2,)`` slot bounds.  Returns ``(cnt, bsum)`` of shape
    ``(nbins + 2,)``.  The one-hot intermediate is
    ``(block_rows, LANES, nbins + 2)`` — callers bound ``block_rows``
    accordingly (DEF_HIST_BLOCK_ROWS).
    """
    nslots = lower.shape[-1]
    j = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nslots), 2)
    lo3 = lower.reshape(1, 1, nslots)
    up3 = upper.reshape(1, 1, nslots)
    x3 = x[:, :, None]
    # slot 0 has no lower bound — `x > -inf` would drop x == -inf, so the
    # first slot escapes the strict lower test (keeps sum(cnt) == n and
    # parity with the searchsorted oracle)
    m = valid[:, :, None] & ((x3 > lo3) | (j == 0)) & (x3 <= up3)
    cnt = jnp.sum(m.astype(jnp.int32), axis=(0, 1))
    bsum = jnp.sum(jnp.where(m, x3, jnp.float32(0.0)), axis=(0, 1))
    return cnt, bsum


def _histogram_kernel(y_ref, x_ref, cnt_ref, sum_ref, *, n, block_rows):
    b = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # (block_rows, LANES)
    valid = _valid_mask(b, x.shape, n, block_rows)
    cnt, bsum = _bin_tile(x, valid, y_ref[0], y_ref[1])
    cnt_ref[0, :] = cnt
    sum_ref[0, :] = bsum


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def cp_histogram(
    x: jax.Array,
    edges: jax.Array,
    *,
    block_rows: int = DEF_HIST_BLOCK_ROWS,
    interpret: bool = False,
):
    """Binned data pass: ``x`` (n,), realized bracket edges (nbins+1,)
    (monotone non-decreasing; build them with ``kernels.ref.bin_edges``).

    Returns ``(cnt, bsum)`` of shape ``(nbins + 2,)`` — counts int32
    (bit-identical to ``kernels.ref.cp_histogram_ref``), sums f32.
    """
    n = x.size
    nbins = edges.shape[-1] - 1
    x2, nblocks = _pad_to_tiles(x.reshape(-1), block_rows)
    lower, upper = _slot_bounds(
        jnp.asarray(edges, jnp.float32).reshape(nbins + 1))
    y = jnp.stack([lower, upper])  # (2, nbins + 2)

    cnt, bsum = pl.pallas_call(
        functools.partial(_histogram_kernel, n=n, block_rows=block_rows),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # slot bounds: tiny
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, nbins + 2), lambda i: (i, 0)),
            pl.BlockSpec((1, nbins + 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, nbins + 2), jnp.int32),
            jax.ShapeDtypeStruct((nblocks, nbins + 2), jnp.float32),
        ],
        interpret=interpret,
    )(y, x2)
    return jnp.sum(cnt, axis=0), jnp.sum(bsum, axis=0)


def _histogram_batched_kernel(y_ref, x_ref, cnt_ref, sum_ref, *, n,
                              block_rows):
    r = pl.program_id(0)  # problem row
    b = pl.program_id(1)  # block within the row
    x = x_ref[0].astype(jnp.float32)  # (block_rows, LANES)
    valid = _valid_mask(b, x.shape, n, block_rows)
    cnt, bsum = _bin_tile(x, valid, y_ref[0, r], y_ref[1, r])
    cnt_ref[0, 0, :] = cnt
    sum_ref[0, 0, :] = bsum


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def cp_histogram_batched(
    x: jax.Array,
    edges: jax.Array,
    *,
    block_rows: int = DEF_HIST_BLOCK_ROWS,
    interpret: bool = False,
):
    """Row-wise binned pass: ``x`` (B, n), per-row realized edges
    ``(B, nbins+1)``.  Returns ``(cnt, bsum)`` of shape ``(B, nbins + 2)``."""
    bsz, n = x.shape
    nbins = edges.shape[-1] - 1
    x3, nblocks = _pad_to_tiles(x, block_rows)
    lower, upper = _slot_bounds(
        jnp.asarray(edges, jnp.float32).reshape(bsz, nbins + 1))
    y = jnp.stack([lower, upper])  # (2, B, nbins + 2)

    cnt, bsum = pl.pallas_call(
        functools.partial(_histogram_batched_kernel, n=n,
                          block_rows=block_rows),
        grid=(bsz, nblocks),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, block_rows, LANES), lambda r, b: (r, b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, nbins + 2), lambda r, b: (r, b, 0)),
            pl.BlockSpec((1, 1, nbins + 2), lambda r, b: (r, b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nblocks, nbins + 2), jnp.int32),
            jax.ShapeDtypeStruct((bsz, nblocks, nbins + 2), jnp.float32),
        ],
        interpret=interpret,
    )(y, x3)
    return jnp.sum(cnt, axis=1), jnp.sum(bsum, axis=1)


def _histogram_multi_kernel(y_ref, x_ref, cnt_ref, sum_ref, *, n, npiv,
                            block_rows):
    """One x tile, ALL K brackets: like ``_multi_kernel``, the tile is read
    HBM -> VMEM once and every live bracket's histogram is computed from the
    resident tile (K is static, the bracket loop unrolls at trace time)."""
    b = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # (block_rows, LANES)
    valid = _valid_mask(b, x.shape, n, block_rows)
    for j in range(npiv):  # static unroll
        cnt, bsum = _bin_tile(x, valid, y_ref[0, j], y_ref[1, j])
        cnt_ref[0, j, :] = cnt
        sum_ref[0, j, :] = bsum


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def cp_histogram_multi(
    x: jax.Array,
    edges: jax.Array,
    *,
    block_rows: int = DEF_HIST_BLOCK_ROWS,
    interpret: bool = False,
):
    """Shared-x multi-bracket binned pass: ``x`` (n,), per-pivot realized
    edges ``(K, nbins+1)``.  Returns ``(cnt, bsum)`` of shape
    ``(K, nbins + 2)``."""
    n = x.size
    npiv, nbins = edges.shape[0], edges.shape[-1] - 1
    x2, nblocks = _pad_to_tiles(x.reshape(-1), block_rows)
    lower, upper = _slot_bounds(jnp.asarray(edges, jnp.float32))
    y = jnp.stack([lower, upper])  # (2, K, nbins + 2)

    cnt, bsum = pl.pallas_call(
        functools.partial(_histogram_multi_kernel, n=n, npiv=npiv,
                          block_rows=block_rows),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, npiv, nbins + 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, npiv, nbins + 2), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, npiv, nbins + 2), jnp.int32),
            jax.ShapeDtypeStruct((nblocks, npiv, nbins + 2), jnp.float32),
        ],
        interpret=interpret,
    )(y, x2)
    return jnp.sum(cnt, axis=0), jnp.sum(bsum, axis=0)


# ---------------------------------------------------------------------------
# Weighted histogram kernels: per-slot weight MASS next to the counts
# ---------------------------------------------------------------------------
#
# The weighted binned descent narrows against a target cumulative weight W_k,
# so each sweep needs the per-slot weight mass sum(w_i : x_i in slot) next to
# the integer count (the count still drives the cap-based stopping rule and
# certifies sum(cnt) == n).  Per slot the kernels emit
#
#     cnt    i32   element count          (exactness bookkeeping, cap rule)
#     wcnt   f32   sum of w_i             (the narrowing signal)
#     wsum   f32   sum of w_i * x_i       (CP-polish ingredient, additive)
#
# all additive across blocks/shards — the distributed combine psums the
# (nbins + 2,) mass vector exactly like the unweighted count vector.  The
# EXACTNESS CONTRACT is unchanged: realized edges come from the engine via
# ``kernels.ref.bin_edges`` and are only COMPARED against.


def _wbin_tile(x, w, valid, lower, upper):
    """Per-tile weighted slot partials for one bracket.

    Returns ``(cnt, wcnt, wsum)`` of shape ``(nbins + 2,)``; same one-hot
    membership (and VMEM sizing) as :func:`_bin_tile`.
    """
    nslots = lower.shape[-1]
    j = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nslots), 2)
    lo3 = lower.reshape(1, 1, nslots)
    up3 = upper.reshape(1, 1, nslots)
    x3 = x[:, :, None]
    w3 = w[:, :, None]
    # slot 0 escapes the strict lower test (x == -inf), as in _bin_tile
    m = valid[:, :, None] & ((x3 > lo3) | (j == 0)) & (x3 <= up3)
    cnt = jnp.sum(m.astype(jnp.int32), axis=(0, 1))
    wcnt = jnp.sum(jnp.where(m, w3, jnp.float32(0.0)), axis=(0, 1))
    wsum = jnp.sum(jnp.where(m, w3 * x3, jnp.float32(0.0)), axis=(0, 1))
    return cnt, wcnt, wsum


def _whistogram_kernel(y_ref, x_ref, w_ref, cnt_ref, wcnt_ref, sum_ref, *,
                       n, block_rows):
    b = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # (block_rows, LANES)
    w = w_ref[...].astype(jnp.float32)
    valid = _valid_mask(b, x.shape, n, block_rows)
    cnt, wcnt, wsum = _wbin_tile(x, w, valid, y_ref[0], y_ref[1])
    cnt_ref[0, :] = cnt
    wcnt_ref[0, :] = wcnt
    sum_ref[0, :] = wsum


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def wcp_histogram(
    x: jax.Array,
    w: jax.Array,
    edges: jax.Array,
    *,
    block_rows: int = DEF_HIST_BLOCK_ROWS,
    interpret: bool = False,
):
    """Weighted binned pass: ``x``/``w`` (n,), realized edges (nbins+1,).

    Returns ``(cnt, wcnt, wsum)`` of shape ``(nbins + 2,)`` — counts int32
    (bit-identical to ``kernels.ref.wcp_histogram_ref``), masses/sums f32.
    """
    n = x.size
    nbins = edges.shape[-1] - 1
    x2, nblocks = _pad_to_tiles(x.reshape(-1), block_rows)
    w2, _ = _pad_to_tiles(w.reshape(-1), block_rows)
    lower, upper = _slot_bounds(
        jnp.asarray(edges, jnp.float32).reshape(nbins + 1))
    y = jnp.stack([lower, upper])  # (2, nbins + 2)

    cnt, wcnt, wsum = pl.pallas_call(
        functools.partial(_whistogram_kernel, n=n, block_rows=block_rows),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # slot bounds: tiny
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, nbins + 2), lambda i: (i, 0)),
            pl.BlockSpec((1, nbins + 2), lambda i: (i, 0)),
            pl.BlockSpec((1, nbins + 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, nbins + 2), jnp.int32),
            jax.ShapeDtypeStruct((nblocks, nbins + 2), jnp.float32),
            jax.ShapeDtypeStruct((nblocks, nbins + 2), jnp.float32),
        ],
        interpret=interpret,
    )(y, x2, w2)
    return (jnp.sum(cnt, axis=0), jnp.sum(wcnt, axis=0),
            jnp.sum(wsum, axis=0))


def _whistogram_batched_kernel(y_ref, x_ref, w_ref, cnt_ref, wcnt_ref,
                               sum_ref, *, n, block_rows):
    r = pl.program_id(0)  # problem row
    b = pl.program_id(1)  # block within the row
    x = x_ref[0].astype(jnp.float32)  # (block_rows, LANES)
    w = w_ref[0].astype(jnp.float32)
    valid = _valid_mask(b, x.shape, n, block_rows)
    cnt, wcnt, wsum = _wbin_tile(x, w, valid, y_ref[0, r], y_ref[1, r])
    cnt_ref[0, 0, :] = cnt
    wcnt_ref[0, 0, :] = wcnt
    sum_ref[0, 0, :] = wsum


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def wcp_histogram_batched(
    x: jax.Array,
    w: jax.Array,
    edges: jax.Array,
    *,
    block_rows: int = DEF_HIST_BLOCK_ROWS,
    interpret: bool = False,
):
    """Row-wise weighted binned pass: ``x``/``w`` (B, n), per-row edges
    ``(B, nbins+1)``.  Returns ``(cnt, wcnt, wsum)``, each
    ``(B, nbins + 2)``."""
    bsz, n = x.shape
    nbins = edges.shape[-1] - 1
    x3, nblocks = _pad_to_tiles(x, block_rows)
    w3, _ = _pad_to_tiles(w, block_rows)
    lower, upper = _slot_bounds(
        jnp.asarray(edges, jnp.float32).reshape(bsz, nbins + 1))
    y = jnp.stack([lower, upper])  # (2, B, nbins + 2)

    cnt, wcnt, wsum = pl.pallas_call(
        functools.partial(_whistogram_batched_kernel, n=n,
                          block_rows=block_rows),
        grid=(bsz, nblocks),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, block_rows, LANES), lambda r, b: (r, b, 0)),
            pl.BlockSpec((1, block_rows, LANES), lambda r, b: (r, b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, nbins + 2), lambda r, b: (r, b, 0)),
            pl.BlockSpec((1, 1, nbins + 2), lambda r, b: (r, b, 0)),
            pl.BlockSpec((1, 1, nbins + 2), lambda r, b: (r, b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nblocks, nbins + 2), jnp.int32),
            jax.ShapeDtypeStruct((bsz, nblocks, nbins + 2), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nblocks, nbins + 2), jnp.float32),
        ],
        interpret=interpret,
    )(y, x3, w3)
    return (jnp.sum(cnt, axis=1), jnp.sum(wcnt, axis=1),
            jnp.sum(wsum, axis=1))


def _whistogram_multi_kernel(y_ref, x_ref, w_ref, cnt_ref, wcnt_ref, sum_ref,
                             *, n, npiv, block_rows):
    b = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # (block_rows, LANES)
    w = w_ref[...].astype(jnp.float32)
    valid = _valid_mask(b, x.shape, n, block_rows)
    for j in range(npiv):  # static unroll
        cnt, wcnt, wsum = _wbin_tile(x, w, valid, y_ref[0, j], y_ref[1, j])
        cnt_ref[0, j, :] = cnt
        wcnt_ref[0, j, :] = wcnt
        sum_ref[0, j, :] = wsum


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def wcp_histogram_multi(
    x: jax.Array,
    w: jax.Array,
    edges: jax.Array,
    *,
    block_rows: int = DEF_HIST_BLOCK_ROWS,
    interpret: bool = False,
):
    """Shared-x weighted multi-bracket binned pass: ``x``/``w`` (n,),
    per-pivot realized edges ``(K, nbins+1)``.  Returns ``(cnt, wcnt,
    wsum)``, each ``(K, nbins + 2)``."""
    n = x.size
    npiv, nbins = edges.shape[0], edges.shape[-1] - 1
    x2, nblocks = _pad_to_tiles(x.reshape(-1), block_rows)
    w2, _ = _pad_to_tiles(w.reshape(-1), block_rows)
    lower, upper = _slot_bounds(jnp.asarray(edges, jnp.float32))
    y = jnp.stack([lower, upper])  # (2, K, nbins + 2)

    cnt, wcnt, wsum = pl.pallas_call(
        functools.partial(_whistogram_multi_kernel, n=n, npiv=npiv,
                          block_rows=block_rows),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, npiv, nbins + 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, npiv, nbins + 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, npiv, nbins + 2), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, npiv, nbins + 2), jnp.int32),
            jax.ShapeDtypeStruct((nblocks, npiv, nbins + 2), jnp.float32),
            jax.ShapeDtypeStruct((nblocks, npiv, nbins + 2), jnp.float32),
        ],
        interpret=interpret,
    )(y, x2, w2)
    return (jnp.sum(cnt, axis=0), jnp.sum(wcnt, axis=0),
            jnp.sum(wsum, axis=0))
