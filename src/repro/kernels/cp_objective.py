"""Pallas TPU kernel: fused selection-objective transform-reduce.

This is the compute hot-spot of the paper — the GPU code's
``thrust::transform_reduce`` (Fig. 1), executed ``maxit`` times per
selection.  On TPU we tile the array HBM -> VMEM in ``(block_rows, 128)``
blocks and emit *per-block partials* for the pivot(s) ``y``.  Partials are
combined by a tiny tree-reduce outside the kernel (parallel across
MegaCore, no cross-grid accumulation races); they are additive, which is
exactly what makes the paper's method shard-friendly: the same vectors are
psum'd across chips in ``core.distributed``.

ONE kernel family serves both measures (see ``core.objective``): every
body shares the tile prologue (HBM tile fetch + f32 cast + tail mask) and
the per-tile accumulators in :func:`_fg_tile` / :func:`_bin_tile`; the
weights leg is a static specialization that rides a second tile stream and
two extra mass accumulators.  The counting leg keeps its SMALLER partial
vectors — two f32 sums + two i32 counts per pivot, and no weights array
read from HBM at all (the specialization is resolved at trace time, so the
unweighted kernels are byte-identical in memory traffic to the
pre-unification ones).

Counts are carried as int32 (f32 mantissa overflows beyond 2^24 elements —
the paper's n reaches 1.34e8).

Layout notes (TPU-native, not a CUDA port):
  * last dim is the 128-lane VPU axis; ``block_rows`` a multiple of 8
    (f32 sublane tiling) — default (512, 128) = 256 KiB f32 per input tile,
    comfortably inside ~16 MiB VMEM with double buffering;
  * the pivot ``y`` is an SMEM scalar (prefetched, uniform across the tile);
  * masking by global element index handles the tail block, so any ``n``
    is supported without host-side padding corrections;
  * scalar (one-pivot) entry points are the K=1 view of the multi-pivot
    kernels — same tile reductions, same block tree-reduce, one less body
    to tune.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEF_BLOCK_ROWS = 512

# The histogram kernels build a (block_rows, LANES, nbins + 2) one-hot
# intermediate per tile (nbins comes from the caller's edge array; the
# engine default lives in core.selection.DEF_NBINS); 64 rows keeps that
# under ~4 MiB f32 in VMEM at the default 128 bins.
DEF_HIST_BLOCK_ROWS = 64


def _pad_to_tiles(x: jax.Array, block_rows: int):
    """Shared prologue of every kernel wrapper: pad the trailing dim of
    ``x`` to a whole number of ``(block_rows, LANES)`` tiles and expose the
    tile grid as the two trailing axes.

    Returns ``(x_tiled, nblocks)`` where ``x_tiled`` has shape
    ``(*leading, nblocks * block_rows, LANES)``.  The padded tail is masked
    inside the kernels via the global element index, so any ``n`` is
    supported without host-side padding corrections.
    """
    n = x.shape[-1]
    block = block_rows * LANES
    nblocks = max(1, -(-n // block))
    padded = nblocks * block
    if padded != n:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, padded - n)]
        x = jnp.pad(x, pad)
    return x.reshape(x.shape[:-1] + (nblocks * block_rows, LANES)), nblocks


def _valid_mask(b, shape, n, block_rows):
    """Tail mask for tile ``b`` of the grid: global element index < n."""
    rows = jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    return (b * block_rows + rows) * LANES + cols < n


# ---------------------------------------------------------------------------
# Shared per-tile accumulators (the single implementation of both measures)
# ---------------------------------------------------------------------------


def _fg_tile(x, valid, y, w=None):
    """Per-tile additive FG partials for one pivot.

    Counting leg (``w=None``): ``((sum_pos, sum_neg), (n_lt, n_le))``.
    Weights leg: ``((wsum_pos, wsum_neg, w_lt, w_le), (n_lt, n_le))`` — the
    weighted objective terms and the weight masses below / at-or-below the
    pivot; the integer counts ride along on both legs (they drive the
    engine's cap-based stopping rule).
    """
    d = x - y
    zero = jnp.zeros_like(x)
    if w is None:
        fsums = (jnp.sum(jnp.where(valid & (d > 0), d, zero)),
                 jnp.sum(jnp.where(valid & (d < 0), -d, zero)))
    else:
        fsums = (jnp.sum(jnp.where(valid & (d > 0), w * d, zero)),
                 jnp.sum(jnp.where(valid & (d < 0), -w * d, zero)),
                 jnp.sum(jnp.where(valid & (d < 0), w, zero)),
                 jnp.sum(jnp.where(valid & (d <= 0), w, zero)))
    # dtype pinned: under global x64 an unpinned int sum accumulates int64,
    # which the int32 output refs reject (and the engine carries int32)
    cnts = (jnp.sum(valid & (d < 0), dtype=jnp.int32),
            jnp.sum(valid & (d <= 0), dtype=jnp.int32))
    return fsums, cnts


def _bin_tile(x, valid, lower, upper, w=None, want_sums=True):
    """Per-tile slot partials for one bracket's ``(nbins + 2,)`` bounds.

    Counting leg: ``(cnt, bsum)``; weights leg: ``(cnt, wcnt, wsum)`` —
    per-slot element count, weight mass and ``sum(w*x)``.  The one-hot
    membership intermediate is ``(block_rows, LANES, nbins + 2)`` — callers
    bound ``block_rows`` accordingly (DEF_HIST_BLOCK_ROWS).

    ``want_sums=False`` (static) drops the trailing per-slot sum — only
    the in-bin polish reads ``bsum``/``wsum``; plain binned sweeps skip
    that accumulator and its HBM writeback entirely (the weighted mass
    vector ``wcnt`` always rides: it IS the weighted narrowing signal).
    """
    nslots = lower.shape[-1]
    j = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nslots), 2)
    lo3 = lower.reshape(1, 1, nslots)
    up3 = upper.reshape(1, 1, nslots)
    x3 = x[:, :, None]
    # slot 0 has no lower bound — `x > -inf` would drop x == -inf, so the
    # first slot escapes the strict lower test (keeps sum(cnt) == n and
    # parity with the searchsorted oracle)
    m = valid[:, :, None] & ((x3 > lo3) | (j == 0)) & (x3 <= up3)
    cnt = jnp.sum(m, axis=(0, 1), dtype=jnp.int32)
    if w is None:
        if not want_sums:
            return (cnt,)
        return (cnt, jnp.sum(jnp.where(m, x3, jnp.float32(0.0)),
                             axis=(0, 1)))
    w3 = w[:, :, None]
    wcnt = jnp.sum(jnp.where(m, w3, jnp.float32(0.0)), axis=(0, 1))
    if not want_sums:
        return (cnt, wcnt)
    wsum = jnp.sum(jnp.where(m, w3 * x3, jnp.float32(0.0)), axis=(0, 1))
    return (cnt, wcnt, wsum)


# ---------------------------------------------------------------------------
# Kernel bodies: one multi-pivot + one row-batched body per pass kind, each
# statically specialized on the weights leg (the extra tile stream and
# wider partial vector exist only when weighted=True)
# ---------------------------------------------------------------------------


def _fg_kernel_multi(y_ref, *refs, n, npiv, block_rows, weighted):
    """One x (or x/w) tile, ALL K pivots: the tile is read HBM -> VMEM once
    and the K per-pivot partial vectors are computed from registers/VMEM —
    K× less HBM traffic than K independent passes (the win behind shared-x
    batched selection: a quantile set costs one sweep per iteration, not
    K).  K is static (the pivot vector's shape), so the pivot loop is
    unrolled at trace time; all stores use static indices.  Scalar
    ``cp_partials`` / ``wcp_partials`` are the K=1 view."""
    b = pl.program_id(0)
    if weighted:
        x_ref, w_ref, fsum_ref, cnt_ref = refs
    else:
        x_ref, fsum_ref, cnt_ref = refs
    x = x_ref[...].astype(jnp.float32)  # (block_rows, LANES)
    w = w_ref[...].astype(jnp.float32) if weighted else None
    valid = _valid_mask(b, x.shape, n, block_rows)
    for j in range(npiv):  # static unroll: npiv is a trace-time constant
        fsums, cnts = _fg_tile(x, valid, y_ref[j], w)
        for i, v in enumerate(fsums):
            fsum_ref[0, j, i] = v
        for i, v in enumerate(cnts):
            cnt_ref[0, j, i] = v


def _fg_kernel_batched(y_ref, *refs, n, block_rows, weighted):
    """Row-wise body: grid (B, nblocks), one pivot per problem row."""
    r = pl.program_id(0)  # problem row
    b = pl.program_id(1)  # block within the row
    if weighted:
        x_ref, w_ref, fsum_ref, cnt_ref = refs
    else:
        x_ref, fsum_ref, cnt_ref = refs
    x = x_ref[0].astype(jnp.float32)  # (block_rows, LANES)
    w = w_ref[0].astype(jnp.float32) if weighted else None
    valid = _valid_mask(b, x.shape, n, block_rows)
    fsums, cnts = _fg_tile(x, valid, y_ref[r], w)
    for i, v in enumerate(fsums):
        fsum_ref[0, 0, i] = v
    for i, v in enumerate(cnts):
        cnt_ref[0, 0, i] = v


def _hist_kernel_multi(y_ref, *refs, n, npiv, block_rows, weighted,
                       want_sums):
    """One x (or x/w) tile, ALL K brackets: like :func:`_fg_kernel_multi`,
    the tile is resident once and every live bracket's histogram is
    computed from it (K static, bracket loop unrolls at trace time)."""
    b = pl.program_id(0)
    if weighted:
        x_ref, w_ref, *out_refs = refs
    else:
        x_ref, *out_refs = refs
    x = x_ref[...].astype(jnp.float32)  # (block_rows, LANES)
    w = w_ref[...].astype(jnp.float32) if weighted else None
    valid = _valid_mask(b, x.shape, n, block_rows)
    for j in range(npiv):  # static unroll
        outs = _bin_tile(x, valid, y_ref[0, j], y_ref[1, j], w,
                         want_sums=want_sums)
        for ref, v in zip(out_refs, outs):
            ref[0, j, :] = v


def _hist_kernel_batched(y_ref, *refs, n, block_rows, weighted, want_sums):
    """Row-wise histogram body: grid (B, nblocks), per-row slot bounds."""
    r = pl.program_id(0)  # problem row
    b = pl.program_id(1)  # block within the row
    if weighted:
        x_ref, w_ref, *out_refs = refs
    else:
        x_ref, *out_refs = refs
    x = x_ref[0].astype(jnp.float32)  # (block_rows, LANES)
    w = w_ref[0].astype(jnp.float32) if weighted else None
    valid = _valid_mask(b, x.shape, n, block_rows)
    outs = _bin_tile(x, valid, y_ref[0, r], y_ref[1, r], w,
                     want_sums=want_sums)
    for ref, v in zip(out_refs, outs):
        ref[0, 0, :] = v


# ---------------------------------------------------------------------------
# pallas_call builders (shared pad/spec/tree-reduce plumbing)
# ---------------------------------------------------------------------------


def _fg_call_multi(x, w, y, *, block_rows, interpret):
    """Shared-x multi-pivot launch; returns per-pivot (K,) partial vectors
    (the counting leg's four or the weights leg's six)."""
    weighted = w is not None
    n = x.size
    npiv = y.shape[0]
    x2, nblocks = _pad_to_tiles(x.reshape(-1), block_rows)
    data = [x2]
    if weighted:
        data.append(_pad_to_tiles(w.reshape(-1), block_rows)[0])
    y = jnp.asarray(y, jnp.float32).reshape(npiv)
    nf = 4 if weighted else 2

    fsum, cnt = pl.pallas_call(
        functools.partial(_fg_kernel_multi, n=n, npiv=npiv,
                          block_rows=block_rows, weighted=weighted),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)]  # y: tiny, whole-array
        + [pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))] * len(data),
        out_specs=[
            pl.BlockSpec((1, npiv, nf), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, npiv, 2), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, npiv, nf), jnp.float32),
            jax.ShapeDtypeStruct((nblocks, npiv, 2), jnp.int32),
        ],
        interpret=interpret,
    )(y, *data)
    s = jnp.sum(fsum, axis=0)
    c = jnp.sum(cnt, axis=0, dtype=jnp.int32)  # int32 under global x64 too
    return tuple(s[:, i] for i in range(nf)) + (c[:, 0], c[:, 1])


def _fg_call_batched(x, w, y, *, block_rows, interpret):
    """Row-wise launch over (B, n) problems; returns (B,) partial vectors."""
    weighted = w is not None
    bsz, n = x.shape
    x3, nblocks = _pad_to_tiles(x, block_rows)
    data = [x3]
    if weighted:
        data.append(_pad_to_tiles(w, block_rows)[0])
    y = jnp.asarray(y, jnp.float32).reshape(bsz)
    nf = 4 if weighted else 2

    fsum, cnt = pl.pallas_call(
        functools.partial(_fg_kernel_batched, n=n, block_rows=block_rows,
                          weighted=weighted),
        grid=(bsz, nblocks),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)]
        + [pl.BlockSpec((1, block_rows, LANES),
                        lambda r, b: (r, b, 0))] * len(data),
        out_specs=[
            pl.BlockSpec((1, 1, nf), lambda r, b: (r, b, 0)),
            pl.BlockSpec((1, 1, 2), lambda r, b: (r, b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nblocks, nf), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nblocks, 2), jnp.int32),
        ],
        interpret=interpret,
    )(y, *data)
    s = jnp.sum(fsum, axis=1)
    c = jnp.sum(cnt, axis=1, dtype=jnp.int32)  # int32 under global x64 too
    return tuple(s[..., i] for i in range(nf)) + (c[..., 0], c[..., 1])


def _slot_bounds(edges):
    """``(..., nbins+1)`` edges -> ``(..., nbins+2)`` (lower, upper) slot
    bounds.  Pure concatenation — NO fp arithmetic (see the exactness
    contract below)."""
    ninf = jnp.full_like(edges[..., :1], -jnp.inf)
    pinf = jnp.full_like(edges[..., :1], jnp.inf)
    return (jnp.concatenate([ninf, edges], axis=-1),
            jnp.concatenate([edges, pinf], axis=-1))


def _hist_out(nout, lead, nslots):
    """Histogram out_shape list: cnt is int32, the mass/sum slots f32."""
    return [jax.ShapeDtypeStruct(lead + (nslots,),
                                 jnp.int32 if i == 0 else jnp.float32)
            for i in range(nout)]


def _hist_call_multi(x, w, edges, *, block_rows, interpret,
                     want_sums=True):
    """Shared-x multi-bracket histogram launch; per-bracket slot vectors.
    ``want_sums=False`` drops the trailing per-slot sum output (and its
    accumulator/HBM writeback) — the caller gets ``None`` in its place."""
    weighted = w is not None
    n = x.size
    npiv, nbins = edges.shape[0], edges.shape[-1] - 1
    x2, nblocks = _pad_to_tiles(x.reshape(-1), block_rows)
    data = [x2]
    if weighted:
        data.append(_pad_to_tiles(w.reshape(-1), block_rows)[0])
    lower, upper = _slot_bounds(jnp.asarray(edges, jnp.float32))
    y = jnp.stack([lower, upper])  # (2, K, nbins + 2)
    nout = (3 if weighted else 2) - (not want_sums)

    outs = pl.pallas_call(
        functools.partial(_hist_kernel_multi, n=n, npiv=npiv,
                          block_rows=block_rows, weighted=weighted,
                          want_sums=want_sums),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)]  # slot bounds: tiny
        + [pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))] * len(data),
        out_specs=[pl.BlockSpec((1, npiv, nbins + 2),
                                lambda i: (i, 0, 0))] * nout,
        out_shape=_hist_out(nout, (nblocks, npiv), nbins + 2),
        interpret=interpret,
    )(y, *data)
    outs = tuple(jnp.sum(o, axis=0, dtype=o.dtype) for o in outs)
    return outs if want_sums else outs + (None,)


def _hist_call_batched(x, w, edges, *, block_rows, interpret,
                       want_sums=True):
    """Row-wise histogram launch: per-row slot vectors ``(B, nbins + 2)``."""
    weighted = w is not None
    bsz, n = x.shape
    nbins = edges.shape[-1] - 1
    x3, nblocks = _pad_to_tiles(x, block_rows)
    data = [x3]
    if weighted:
        data.append(_pad_to_tiles(w, block_rows)[0])
    lower, upper = _slot_bounds(
        jnp.asarray(edges, jnp.float32).reshape(bsz, nbins + 1))
    y = jnp.stack([lower, upper])  # (2, B, nbins + 2)
    nout = (3 if weighted else 2) - (not want_sums)

    outs = pl.pallas_call(
        functools.partial(_hist_kernel_batched, n=n, block_rows=block_rows,
                          weighted=weighted, want_sums=want_sums),
        grid=(bsz, nblocks),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)]
        + [pl.BlockSpec((1, block_rows, LANES),
                        lambda r, b: (r, b, 0))] * len(data),
        out_specs=[pl.BlockSpec((1, 1, nbins + 2),
                                lambda r, b: (r, b, 0))] * nout,
        out_shape=_hist_out(nout, (bsz, nblocks), nbins + 2),
        interpret=interpret,
    )(y, *data)
    outs = tuple(jnp.sum(o, axis=1, dtype=o.dtype) for o in outs)
    return outs if want_sums else outs + (None,)


# ---------------------------------------------------------------------------
# Public entry points (stable names; thin views of the builders above)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def cp_partials(
    x: jax.Array,
    y: jax.Array,
    *,
    block_rows: int = DEF_BLOCK_ROWS,
    interpret: bool = False,
):
    """Per-pivot fused partials of the selection objective (K=1 view of the
    multi-pivot kernel).

    Returns ``(sum_pos, sum_neg, n_lt, n_le)`` scalars, bit-identical in
    count terms to the pure-jnp oracle ``kernels.ref.cp_partials_ref``.
    """
    parts = _fg_call_multi(x, None, jnp.asarray(y, jnp.float32).reshape(1),
                           block_rows=block_rows, interpret=interpret)
    return tuple(p[0] for p in parts)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def cp_partials_multi(
    x: jax.Array,
    y: jax.Array,
    *,
    block_rows: int = DEF_BLOCK_ROWS,
    interpret: bool = False,
):
    """Shared-x multi-pivot partials: ``x`` is (n,), ``y`` is (K,) pivots.

    Returns four (K,) vectors ``(sum_pos, sum_neg, n_lt, n_le)``; count
    terms bit-identical to ``kernels.ref.cp_partials_multi_ref``.  This is
    the data pass of shared-x batched selection (``multi_order_statistic`` /
    ``quantiles``): all K brackets iterate against one sweep of ``x``.
    """
    return _fg_call_multi(x, None, y, block_rows=block_rows,
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def cp_partials_batched(
    x: jax.Array,
    y: jax.Array,
    *,
    block_rows: int = DEF_BLOCK_ROWS,
    interpret: bool = False,
):
    """Row-wise partials: ``x`` is (B, n), ``y`` is (B,) pivots.

    Used by the vectorized selection solver (coordinate-wise medians for
    robust gradient aggregation solve millions of small problems at once).
    Returns four (B,) vectors.
    """
    return _fg_call_batched(x, None, y, block_rows=block_rows,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def wcp_partials(
    x: jax.Array,
    w: jax.Array,
    y: jax.Array,
    *,
    block_rows: int = DEF_BLOCK_ROWS,
    interpret: bool = False,
):
    """Weighted fused partials: ``x``/``w`` (n,), scalar pivot ``y`` (K=1
    view of the weighted multi-pivot kernel).

    Returns ``(wsum_pos, wsum_neg, w_lt, w_le, n_lt, n_le)`` scalars; count
    terms bit-identical to ``kernels.ref.wcp_partials_ref``.
    """
    parts = _fg_call_multi(x, w, jnp.asarray(y, jnp.float32).reshape(1),
                           block_rows=block_rows, interpret=interpret)
    return tuple(p[0] for p in parts)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def wcp_partials_multi(
    x: jax.Array,
    w: jax.Array,
    y: jax.Array,
    *,
    block_rows: int = DEF_BLOCK_ROWS,
    interpret: bool = False,
):
    """Shared-x weighted multi-pivot partials: ``x``/``w`` (n,), ``y`` (K,).

    Returns six (K,) vectors.
    """
    return _fg_call_multi(x, w, y, block_rows=block_rows,
                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def wcp_partials_batched(
    x: jax.Array,
    w: jax.Array,
    y: jax.Array,
    *,
    block_rows: int = DEF_BLOCK_ROWS,
    interpret: bool = False,
):
    """Row-wise weighted partials: ``x``/``w`` (B, n), ``y`` (B,) pivots.

    Returns six (B,) vectors ``(wsum_pos, wsum_neg, w_lt, w_le, n_lt,
    n_le)``.
    """
    return _fg_call_batched(x, w, y, block_rows=block_rows,
                            interpret=interpret)


# ---------------------------------------------------------------------------
# Binned bracket descent: multi-bin histogram kernels
# ---------------------------------------------------------------------------
#
# One sweep bins x against the current bracket's NBINS sub-intervals and
# emits additive per-slot partials — the measure vector localizes x_(k) to
# ONE bin (log2(NBINS) bisection steps of information per data pass) and
# the per-bin sums are the CP support-line ingredients (the in-bin polish:
# the support lines at every edge come free from prefix sums), all for the
# HBM cost of a single fused pass.  All outputs are additive over
# blocks/shards, so they psum across a mesh exactly like the FG partials.
#
# Slot layout (nbins + 2 slots for edges e_0 <= ... <= e_nbins):
#   slot 0          x <= e_0
#   slot j          e_{j-1} < x <= e_j          (j = 1..nbins)
#   slot nbins+1    x > e_nbins
# so prefix sums over slots 0..j give exact count(x <= e_j) / sum(x <= e_j)
# at every edge, and sum(cnt) == n is the per-row count invariant.
#
# EXACTNESS CONTRACT: the kernels take the REALIZED edge values — computed
# ONCE by the engine via ``kernels.ref.bin_edges`` (or
# ``core.selection.polish_edges``) — and only COMPARE against them.
# Recomputing edges here from (lo, hi) would be unsound: XLA may contract
# ``lo + w*j`` into an FMA in one fusion context and not another, yielding
# different fp edges (observed at full-f32-range brackets); comparisons
# against one shared array cannot diverge, so the histogram counts are
# exactly consistent with the engine's later ``x <= e_j`` narrowing and
# finalize comparisons.


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "want_sums"))
def cp_histogram(
    x: jax.Array,
    edges: jax.Array,
    *,
    block_rows: int = DEF_HIST_BLOCK_ROWS,
    interpret: bool = False,
    want_sums: bool = True,
):
    """Binned data pass: ``x`` (n,), realized bracket edges (nbins+1,)
    (monotone non-decreasing; build them with ``kernels.ref.bin_edges``).
    The K=1 view of :func:`cp_histogram_multi`.

    Returns ``(cnt, bsum)`` of shape ``(nbins + 2,)`` — counts int32
    (bit-identical to ``kernels.ref.cp_histogram_ref``), sums f32.
    ``want_sums=False`` (static) skips the sum accumulator and its HBM
    writeback — only the in-bin polish reads ``bsum`` — returning
    ``(cnt, None)``.
    """
    nbins = edges.shape[-1] - 1
    outs = _hist_call_multi(
        x, None, jnp.asarray(edges, jnp.float32).reshape(1, nbins + 1),
        block_rows=block_rows, interpret=interpret, want_sums=want_sums)
    return tuple(o[0] if o is not None else None for o in outs)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "want_sums"))
def cp_histogram_batched(
    x: jax.Array,
    edges: jax.Array,
    *,
    block_rows: int = DEF_HIST_BLOCK_ROWS,
    interpret: bool = False,
    want_sums: bool = True,
):
    """Row-wise binned pass: ``x`` (B, n), per-row realized edges
    ``(B, nbins+1)``.  Returns ``(cnt, bsum)`` of shape ``(B, nbins + 2)``
    (``bsum=None`` under ``want_sums=False``)."""
    return _hist_call_batched(x, None, edges, block_rows=block_rows,
                              interpret=interpret, want_sums=want_sums)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "want_sums"))
def cp_histogram_multi(
    x: jax.Array,
    edges: jax.Array,
    *,
    block_rows: int = DEF_HIST_BLOCK_ROWS,
    interpret: bool = False,
    want_sums: bool = True,
):
    """Shared-x multi-bracket binned pass: ``x`` (n,), per-pivot realized
    edges ``(K, nbins+1)``.  Returns ``(cnt, bsum)`` of shape
    ``(K, nbins + 2)`` (``bsum=None`` under ``want_sums=False``)."""
    return _hist_call_multi(x, None, edges, block_rows=block_rows,
                            interpret=interpret, want_sums=want_sums)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "want_sums"))
def wcp_histogram(
    x: jax.Array,
    w: jax.Array,
    edges: jax.Array,
    *,
    block_rows: int = DEF_HIST_BLOCK_ROWS,
    interpret: bool = False,
    want_sums: bool = True,
):
    """Weighted binned pass: ``x``/``w`` (n,), realized edges (nbins+1,).
    The K=1 view of :func:`wcp_histogram_multi`.

    Returns ``(cnt, wcnt, wsum)`` of shape ``(nbins + 2,)`` — counts int32
    (bit-identical to ``kernels.ref.wcp_histogram_ref``), masses/sums f32.
    ``want_sums=False`` skips ``wsum`` (returns ``None``); the mass vector
    ``wcnt`` always rides (it IS the weighted narrowing signal).
    """
    nbins = edges.shape[-1] - 1
    outs = _hist_call_multi(
        x, w, jnp.asarray(edges, jnp.float32).reshape(1, nbins + 1),
        block_rows=block_rows, interpret=interpret, want_sums=want_sums)
    return tuple(o[0] if o is not None else None for o in outs)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "want_sums"))
def wcp_histogram_batched(
    x: jax.Array,
    w: jax.Array,
    edges: jax.Array,
    *,
    block_rows: int = DEF_HIST_BLOCK_ROWS,
    interpret: bool = False,
    want_sums: bool = True,
):
    """Row-wise weighted binned pass: ``x``/``w`` (B, n), per-row edges
    ``(B, nbins+1)``.  Returns ``(cnt, wcnt, wsum)``, each
    ``(B, nbins + 2)`` (``wsum=None`` under ``want_sums=False``)."""
    return _hist_call_batched(x, w, edges, block_rows=block_rows,
                              interpret=interpret, want_sums=want_sums)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "want_sums"))
def wcp_histogram_multi(
    x: jax.Array,
    w: jax.Array,
    edges: jax.Array,
    *,
    block_rows: int = DEF_HIST_BLOCK_ROWS,
    interpret: bool = False,
    want_sums: bool = True,
):
    """Shared-x weighted multi-bracket binned pass: ``x``/``w`` (n,),
    per-pivot realized edges ``(K, nbins+1)``.  Returns ``(cnt, wcnt,
    wsum)``, each ``(K, nbins + 2)`` (``wsum=None`` under
    ``want_sums=False``)."""
    return _hist_call_multi(x, w, edges, block_rows=block_rows,
                            interpret=interpret, want_sums=want_sums)
