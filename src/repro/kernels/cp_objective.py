"""Pallas TPU kernel: fused selection-objective transform-reduce.

This is the compute hot-spot of the paper — the GPU code's
``thrust::transform_reduce`` (Fig. 1), executed ``maxit`` times per
selection.  On TPU we tile the array HBM -> VMEM in ``(block_rows, 128)``
blocks and emit *per-block partials*

    (sum_pos, sum_neg)  f32   and   (n_lt, n_le)  i32

for the pivot ``y``.  Partials are combined by a tiny tree-reduce outside the
kernel (parallel across MegaCore, no cross-grid accumulation races).  The
four partials are additive, which is exactly what makes the paper's method
shard-friendly: the same quadruple is psum'd across chips in
``core.distributed``.

Counts are carried as int32 (f32 mantissa overflows beyond 2^24 elements —
the paper's n reaches 1.34e8).

Layout notes (TPU-native, not a CUDA port):
  * last dim is the 128-lane VPU axis; ``block_rows`` a multiple of 8
    (f32 sublane tiling) — default (512, 128) = 256 KiB f32 per input tile,
    comfortably inside ~16 MiB VMEM with double buffering;
  * the pivot ``y`` is an SMEM scalar (prefetched, uniform across the tile);
  * masking by global element index handles the tail block, so any ``n``
    is supported without host-side padding corrections.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEF_BLOCK_ROWS = 512


def _partials_kernel(y_ref, x_ref, fsum_ref, cnt_ref, *, n, block_rows):
    b = pl.program_id(0)
    y = y_ref[0]
    x = x_ref[...].astype(jnp.float32)  # (block_rows, LANES)
    rows = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    pos = (b * block_rows + rows) * LANES + cols
    valid = pos < n

    d = x - y
    zero = jnp.zeros_like(x)
    sum_pos = jnp.sum(jnp.where(valid & (d > 0), d, zero))
    sum_neg = jnp.sum(jnp.where(valid & (d < 0), -d, zero))
    lt = jnp.sum(jnp.where(valid & (d < 0), 1, 0).astype(jnp.int32))
    le = jnp.sum(jnp.where(valid & (d <= 0), 1, 0).astype(jnp.int32))

    fsum_ref[0, 0] = sum_pos
    fsum_ref[0, 1] = sum_neg
    cnt_ref[0, 0] = lt
    cnt_ref[0, 1] = le


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret")
)
def cp_partials(
    x: jax.Array,
    y: jax.Array,
    *,
    block_rows: int = DEF_BLOCK_ROWS,
    interpret: bool = False,
):
    """Per-pivot fused partials of the selection objective.

    Returns ``(sum_pos, sum_neg, n_lt, n_le)`` scalars, bit-identical in
    count terms to the pure-jnp oracle ``kernels.ref.cp_partials_ref``.
    """
    n = x.size
    x = x.reshape(-1)
    block = block_rows * LANES
    nblocks = max(1, -(-n // block))
    padded = nblocks * block
    if padded != n:
        # padded tail is masked inside the kernel via the global index
        x = jnp.pad(x, (0, padded - n))
    x2 = x.reshape(nblocks * block_rows, LANES)
    y = jnp.asarray(y, jnp.float32).reshape(1)

    fsum, cnt = pl.pallas_call(
        functools.partial(_partials_kernel, n=n, block_rows=block_rows),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # y: tiny, whole-array
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 2), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, 2), jnp.float32),
            jax.ShapeDtypeStruct((nblocks, 2), jnp.int32),
        ],
        interpret=interpret,
    )(y, x2)
    sums = jnp.sum(fsum, axis=0)
    cnts = jnp.sum(cnt, axis=0)
    return sums[0], sums[1], cnts[0], cnts[1]


def _batched_kernel(y_ref, x_ref, fsum_ref, cnt_ref, *, n, block_rows):
    r = pl.program_id(0)  # problem row
    b = pl.program_id(1)  # block within the row
    y = y_ref[r]
    x = x_ref[0].astype(jnp.float32)  # (block_rows, LANES)
    rows = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    pos = (b * block_rows + rows) * LANES + cols
    valid = pos < n

    d = x - y
    zero = jnp.zeros_like(x)
    fsum_ref[0, 0, 0] = jnp.sum(jnp.where(valid & (d > 0), d, zero))
    fsum_ref[0, 0, 1] = jnp.sum(jnp.where(valid & (d < 0), -d, zero))
    cnt_ref[0, 0, 0] = jnp.sum(jnp.where(valid & (d < 0), 1, 0).astype(jnp.int32))
    cnt_ref[0, 0, 1] = jnp.sum(jnp.where(valid & (d <= 0), 1, 0).astype(jnp.int32))


def _multi_kernel(y_ref, x_ref, fsum_ref, cnt_ref, *, n, npiv, block_rows):
    """One x tile, ALL K pivots: the tile is read HBM -> VMEM once and the
    K per-pivot partial quadruples are computed from registers/VMEM — K× less
    HBM traffic than K independent passes (the win behind shared-x batched
    selection: a quantile set costs one sweep per iteration, not K).

    K is static (the pivot vector's shape), so the pivot loop is unrolled at
    trace time; all stores use static indices.
    """
    b = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)  # (block_rows, LANES)
    rows = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    pos = (b * block_rows + rows) * LANES + cols
    valid = pos < n

    zero = jnp.zeros_like(x)
    for j in range(npiv):  # static unroll: npiv is a trace-time constant
        d = x - y_ref[j]
        fsum_ref[0, j, 0] = jnp.sum(jnp.where(valid & (d > 0), d, zero))
        fsum_ref[0, j, 1] = jnp.sum(jnp.where(valid & (d < 0), -d, zero))
        cnt_ref[0, j, 0] = jnp.sum(
            jnp.where(valid & (d < 0), 1, 0).astype(jnp.int32))
        cnt_ref[0, j, 1] = jnp.sum(
            jnp.where(valid & (d <= 0), 1, 0).astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def cp_partials_multi(
    x: jax.Array,
    y: jax.Array,
    *,
    block_rows: int = DEF_BLOCK_ROWS,
    interpret: bool = False,
):
    """Shared-x multi-pivot partials: ``x`` is (n,), ``y`` is (K,) pivots.

    Returns four (K,) vectors ``(sum_pos, sum_neg, n_lt, n_le)``; count
    terms bit-identical to ``kernels.ref.cp_partials_multi_ref``.  This is
    the data pass of shared-x batched selection (``multi_order_statistic`` /
    ``quantiles``): all K brackets iterate against one sweep of ``x``.
    """
    n = x.size
    npiv = y.shape[0]
    x = x.reshape(-1)
    block = block_rows * LANES
    nblocks = max(1, -(-n // block))
    padded = nblocks * block
    if padded != n:
        # padded tail is masked inside the kernel via the global index
        x = jnp.pad(x, (0, padded - n))
    x2 = x.reshape(nblocks * block_rows, LANES)
    y = jnp.asarray(y, jnp.float32).reshape(npiv)

    fsum, cnt = pl.pallas_call(
        functools.partial(_multi_kernel, n=n, npiv=npiv,
                          block_rows=block_rows),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # y: tiny, whole-array
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, npiv, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, npiv, 2), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, npiv, 2), jnp.float32),
            jax.ShapeDtypeStruct((nblocks, npiv, 2), jnp.int32),
        ],
        interpret=interpret,
    )(y, x2)
    sums = jnp.sum(fsum, axis=0)
    cnts = jnp.sum(cnt, axis=0)
    return sums[:, 0], sums[:, 1], cnts[:, 0], cnts[:, 1]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def cp_partials_batched(
    x: jax.Array,
    y: jax.Array,
    *,
    block_rows: int = DEF_BLOCK_ROWS,
    interpret: bool = False,
):
    """Row-wise partials: ``x`` is (B, n), ``y`` is (B,) pivots.

    Used by the vectorized selection solver (coordinate-wise medians for
    robust gradient aggregation solve millions of small problems at once).
    Returns four (B,) vectors.
    """
    bsz, n = x.shape
    block = block_rows * LANES
    nblocks = max(1, -(-n // block))
    padded = nblocks * block
    if padded != n:
        x = jnp.pad(x, ((0, 0), (0, padded - n)))
    x3 = x.reshape(bsz, nblocks * block_rows, LANES)
    y = jnp.asarray(y, jnp.float32).reshape(bsz)

    fsum, cnt = pl.pallas_call(
        functools.partial(_batched_kernel, n=n, block_rows=block_rows),
        grid=(bsz, nblocks),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, block_rows, LANES), lambda r, b: (r, b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 2), lambda r, b: (r, b, 0)),
            pl.BlockSpec((1, 1, 2), lambda r, b: (r, b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nblocks, 2), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nblocks, 2), jnp.int32),
        ],
        interpret=interpret,
    )(y, x3)
    sums = jnp.sum(fsum, axis=1)
    cnts = jnp.sum(cnt, axis=1)
    return sums[..., 0], sums[..., 1], cnts[..., 0], cnts[..., 1]
