"""jit'd dispatch wrappers around the Pallas kernels.

On TPU the Pallas path is used; on CPU (this container) the pure-jnp oracle
is numerically identical and XLA fuses it into one pass, so it is the
default.  ``backend='pallas_interpret'`` forces the kernel body through the
Pallas interpreter (Python emulation) — used by the tests to validate the
TPU kernel logic on CPU.
"""
from __future__ import annotations

import jax

from repro.kernels import cp_objective, ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def fused_partials(x, y, *, backend: str | None = None):
    """(sum_pos, sum_neg, n_lt, n_le) for pivot y — kernel-accelerated."""
    if backend is None:
        backend = "pallas" if _on_tpu() else "jnp"
    if backend == "pallas":
        return cp_objective.cp_partials(x, y)
    if backend == "pallas_interpret":
        return cp_objective.cp_partials(x, y, interpret=True)
    if backend == "jnp":
        return ref.cp_partials_ref(x, y)
    raise ValueError(f"unknown backend {backend!r}")


def fused_partials_batched(x, y, *, backend: str | None = None):
    """Row-wise variant over (B, n) problems."""
    if backend is None:
        backend = "pallas" if _on_tpu() else "jnp"
    if backend == "pallas":
        return cp_objective.cp_partials_batched(x, y)
    if backend == "pallas_interpret":
        return cp_objective.cp_partials_batched(x, y, interpret=True)
    if backend == "jnp":
        return ref.cp_partials_batched_ref(x, y)
    raise ValueError(f"unknown backend {backend!r}")


def fused_partials_multi(x, y, *, backend: str | None = None):
    """Shared-x multi-pivot variant: ``x`` (n,), ``y`` (K,) pivots.

    On TPU the multi-pivot kernel reads each x tile into VMEM once and
    emits partials for every live pivot (K× less HBM traffic than K
    independent sweeps).
    """
    if backend is None:
        backend = "pallas" if _on_tpu() else "jnp"
    if backend == "pallas":
        return cp_objective.cp_partials_multi(x, y)
    if backend == "pallas_interpret":
        return cp_objective.cp_partials_multi(x, y, interpret=True)
    if backend == "jnp":
        return ref.cp_partials_multi_ref(x, y)
    raise ValueError(f"unknown backend {backend!r}")
