"""jit'd dispatch wrappers around the Pallas kernels.

On TPU the Pallas path is used; on CPU (this container) the pure-jnp oracle
is numerically identical and XLA fuses it into one pass, so it is the
default.  ``backend='pallas_interpret'`` forces the kernel body through the
Pallas interpreter (Python emulation) — used by the tests to validate the
TPU kernel logic on CPU.

f64 policy: the Pallas kernels accumulate in f32 (``x_ref[...].astype(
jnp.float32)`` — TPUs have no f64 VPU), so under x64 their counts would be
computed at f32 resolution: two f64 values straddling a pivot can collapse
onto it after the downcast, and the exactness certificates would lie.  Every
dispatcher therefore reroutes f64 inputs to the dtype-preserving jnp oracle,
even when ``backend='pallas'`` was requested.  ``pallas_interpret`` is NOT
rerouted — it exists precisely to emulate the TPU kernel (including its f32
accumulation) on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import cp_objective, ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def _resolve_backend(backend: str | None, x: jax.Array) -> str:
    if backend is None:
        backend = "pallas" if _on_tpu() else "jnp"
    if backend == "pallas" and x.dtype == jnp.float64:
        # dtype-preserving variant: the f32-accumulating kernel would lose
        # sub-f32 resolution (see module docstring)
        backend = "jnp"
    return backend


def _resolve_impl(impl: str | None) -> str:
    """jnp-path slotting implementation for the histogram dispatchers.

    ``None`` resolves to ``'arithmetic'`` — the verified multiply/floor/clip
    slotting (bit-identical to the searchsorted oracle by construction, see
    ``ref.bin_slots``) whose factored one-hot reduction is what makes the
    CPU histogram pass competitive with a fused FG pass.  ``'searchsorted'``
    stays selectable for differential testing.  The Pallas kernels bin
    in-register against the resident edges (neither slotting applies), so
    ``impl`` only routes the jnp-oracle path — including the f64 reroute.
    """
    if impl is None:
        return "arithmetic"
    from repro.kernels.ref import BIN_IMPLS

    if impl not in BIN_IMPLS:
        raise ValueError(f"unknown binning impl {impl!r}; one of "
                         f"{BIN_IMPLS}")
    return impl


def fused_partials(x, y, *, backend: str | None = None):
    """(sum_pos, sum_neg, n_lt, n_le) for pivot y — kernel-accelerated."""
    backend = _resolve_backend(backend, x)
    if backend == "pallas":
        return cp_objective.cp_partials(x, y)
    if backend == "pallas_interpret":
        return cp_objective.cp_partials(x, y, interpret=True)
    if backend == "jnp":
        return ref.cp_partials_ref(x, y)
    raise ValueError(f"unknown backend {backend!r}")


def fused_partials_batched(x, y, *, backend: str | None = None):
    """Row-wise variant over (B, n) problems."""
    backend = _resolve_backend(backend, x)
    if backend == "pallas":
        return cp_objective.cp_partials_batched(x, y)
    if backend == "pallas_interpret":
        return cp_objective.cp_partials_batched(x, y, interpret=True)
    if backend == "jnp":
        return ref.cp_partials_batched_ref(x, y)
    raise ValueError(f"unknown backend {backend!r}")


def fused_partials_multi(x, y, *, backend: str | None = None):
    """Shared-x multi-pivot variant: ``x`` (n,), ``y`` (K,) pivots.

    On TPU the multi-pivot kernel reads each x tile into VMEM once and
    emits partials for every live pivot (K× less HBM traffic than K
    independent sweeps).
    """
    backend = _resolve_backend(backend, x)
    if backend == "pallas":
        return cp_objective.cp_partials_multi(x, y)
    if backend == "pallas_interpret":
        return cp_objective.cp_partials_multi(x, y, interpret=True)
    if backend == "jnp":
        return ref.cp_partials_multi_ref(x, y)
    raise ValueError(f"unknown backend {backend!r}")


def _resolve_backend_weighted(backend: str | None, x: jax.Array,
                              w: jax.Array) -> str:
    """Weighted variant of :func:`_resolve_backend`: the f64 reroute fires
    when EITHER operand is f64 (f64 weights on f32 data must accumulate
    mass at full precision or the weighted certificates lie)."""
    if backend is None:
        backend = "pallas" if _on_tpu() else "jnp"
    if backend == "pallas" and (x.dtype == jnp.float64
                                or w.dtype == jnp.float64):
        backend = "jnp"
    return backend


def fused_weighted_partials(x, w, y, *, backend: str | None = None):
    """Six weighted partials ``(wsum_pos, wsum_neg, w_lt, w_le, n_lt,
    n_le)`` for pivot ``y`` — kernel-accelerated."""
    backend = _resolve_backend_weighted(backend, x, w)
    if backend == "pallas":
        return cp_objective.wcp_partials(x, w, y)
    if backend == "pallas_interpret":
        return cp_objective.wcp_partials(x, w, y, interpret=True)
    if backend == "jnp":
        return ref.wcp_partials_ref(x, w, y)
    raise ValueError(f"unknown backend {backend!r}")


def fused_weighted_partials_batched(x, w, y, *, backend: str | None = None):
    """Row-wise weighted variant over (B, n) problems."""
    backend = _resolve_backend_weighted(backend, x, w)
    if backend == "pallas":
        return cp_objective.wcp_partials_batched(x, w, y)
    if backend == "pallas_interpret":
        return cp_objective.wcp_partials_batched(x, w, y, interpret=True)
    if backend == "jnp":
        return ref.wcp_partials_batched_ref(x, w, y)
    raise ValueError(f"unknown backend {backend!r}")


def fused_weighted_partials_multi(x, w, y, *, backend: str | None = None):
    """Shared-x weighted multi-pivot variant: ``x``/``w`` (n,), ``y`` (K,)."""
    backend = _resolve_backend_weighted(backend, x, w)
    if backend == "pallas":
        return cp_objective.wcp_partials_multi(x, w, y)
    if backend == "pallas_interpret":
        return cp_objective.wcp_partials_multi(x, w, y, interpret=True)
    if backend == "jnp":
        return ref.wcp_partials_multi_ref(x, w, y)
    raise ValueError(f"unknown backend {backend!r}")


def fused_weighted_histogram(x, w, edges, *, backend: str | None = None,
                             impl: str | None = None,
                             want_sums: bool = True):
    """Weighted binned pass: ``(cnt, wcnt, wsum)`` per bracket sub-interval
    (slot weight mass next to the count — the weighted narrowing signal).

    ``impl`` selects the jnp-path slotting (see :func:`_resolve_impl`);
    ``want_sums=False`` skips the per-slot ``sum(w*x)`` on every backend
    (only the polish reads it) — the kernels drop the accumulator and its
    HBM writeback, the jnp arithmetic path the extra value row."""
    backend = _resolve_backend_weighted(backend, x, w)
    if backend == "pallas":
        return cp_objective.wcp_histogram(x, w, edges, want_sums=want_sums)
    if backend == "pallas_interpret":
        return cp_objective.wcp_histogram(x, w, edges, interpret=True,
                                          want_sums=want_sums)
    if backend == "jnp":
        return ref.wcp_histogram_ref(x, w, edges, impl=_resolve_impl(impl),
                                     want_sums=want_sums)
    raise ValueError(f"unknown backend {backend!r}")


def fused_weighted_histogram_batched(x, w, edges, *,
                                     backend: str | None = None,
                                     impl: str | None = None,
                                     want_sums: bool = True):
    """Row-wise weighted binned pass: ``x``/``w`` (B, n), per-row edges
    ``(B, nbins+1)``."""
    backend = _resolve_backend_weighted(backend, x, w)
    if backend == "pallas":
        return cp_objective.wcp_histogram_batched(x, w, edges,
                                                   want_sums=want_sums)
    if backend == "pallas_interpret":
        return cp_objective.wcp_histogram_batched(x, w, edges,
                                                   interpret=True,
                                                   want_sums=want_sums)
    if backend == "jnp":
        return ref.wcp_histogram_batched_ref(x, w, edges,
                                             impl=_resolve_impl(impl),
                                             want_sums=want_sums)
    raise ValueError(f"unknown backend {backend!r}")


def fused_weighted_histogram_multi(x, w, edges, *,
                                   backend: str | None = None,
                                   impl: str | None = None,
                                   want_sums: bool = True):
    """Shared-x weighted multi-bracket binned pass: ``x``/``w`` (n,),
    per-pivot edges ``(K, nbins+1)``."""
    backend = _resolve_backend_weighted(backend, x, w)
    if backend == "pallas":
        return cp_objective.wcp_histogram_multi(x, w, edges,
                                                 want_sums=want_sums)
    if backend == "pallas_interpret":
        return cp_objective.wcp_histogram_multi(x, w, edges, interpret=True,
                                                 want_sums=want_sums)
    if backend == "jnp":
        return ref.wcp_histogram_multi_ref(x, w, edges,
                                           impl=_resolve_impl(impl),
                                           want_sums=want_sums)
    raise ValueError(f"unknown backend {backend!r}")


def fused_histogram(x, edges, *, backend: str | None = None,
                    impl: str | None = None, want_sums: bool = True):
    """Binned data pass: (count, sum) per bracket sub-interval.

    ``x`` (n,), realized bracket edges ``(nbins+1,)`` built ONCE by the
    caller via ``kernels.ref.bin_edges`` (the exactness contract: every
    consumer compares against the same edge array, nobody recomputes edge
    arithmetic — the arithmetic slotting's candidate is verified against
    that same array, see ``ref.bin_slots``).  Returns ``(cnt, bsum)`` of
    shape ``(nbins + 2,)`` (slot layout in
    ``kernels.ref.searchsorted_slots``).  One sweep buys log2(nbins)
    bisection-equivalents of bracket narrowing.  ``want_sums=False`` skips
    ``bsum`` (returns ``None``) on every backend — plain binned
    sweeps never read it, only the polish does.
    """
    backend = _resolve_backend(backend, x)
    if backend == "pallas":
        return cp_objective.cp_histogram(x, edges, want_sums=want_sums)
    if backend == "pallas_interpret":
        return cp_objective.cp_histogram(x, edges, interpret=True,
                                         want_sums=want_sums)
    if backend == "jnp":
        return ref.cp_histogram_ref(x, edges, impl=_resolve_impl(impl),
                                    want_sums=want_sums)
    raise ValueError(f"unknown backend {backend!r}")


def fused_histogram_batched(x, edges, *, backend: str | None = None,
                            impl: str | None = None,
                            want_sums: bool = True):
    """Row-wise binned pass: ``x`` (B, n), per-row edges ``(B, nbins+1)``."""
    backend = _resolve_backend(backend, x)
    if backend == "pallas":
        return cp_objective.cp_histogram_batched(x, edges,
                                                  want_sums=want_sums)
    if backend == "pallas_interpret":
        return cp_objective.cp_histogram_batched(x, edges, interpret=True,
                                                  want_sums=want_sums)
    if backend == "jnp":
        return ref.cp_histogram_batched_ref(x, edges,
                                            impl=_resolve_impl(impl),
                                            want_sums=want_sums)
    raise ValueError(f"unknown backend {backend!r}")


def fused_histogram_multi(x, edges, *, backend: str | None = None,
                          impl: str | None = None, want_sums: bool = True):
    """Shared-x multi-bracket binned pass: ``x`` (n,), per-pivot edges
    ``(K, nbins+1)``.

    On TPU each x tile is read into VMEM once for all K live brackets,
    exactly like the multi-pivot FG kernel.
    """
    backend = _resolve_backend(backend, x)
    if backend == "pallas":
        return cp_objective.cp_histogram_multi(x, edges,
                                                want_sums=want_sums)
    if backend == "pallas_interpret":
        return cp_objective.cp_histogram_multi(x, edges, interpret=True,
                                                want_sums=want_sums)
    if backend == "jnp":
        return ref.cp_histogram_multi_ref(x, edges,
                                          impl=_resolve_impl(impl),
                                          want_sums=want_sums)
    raise ValueError(f"unknown backend {backend!r}")
