"""Pallas TPU kernels for the paper's compute hot-spot (the fused
selection-objective transform-reduce), with jit'd dispatch wrappers and
pure-jnp oracles.  Validated in interpret mode on CPU; see tests/test_kernels.py."""
from repro.kernels import cp_objective, ops, ref
