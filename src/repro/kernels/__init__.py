"""Pallas TPU kernels for the paper's compute hot-spots: the fused
selection-objective transform-reduce (scalar / rows / multi-pivot) and the
binned bracket-descent histogram pass (scalar / rows / multi-bracket), with
jit'd dispatch wrappers (f64 reroutes to the dtype-preserving oracles) and
pure-jnp oracles.  Validated in interpret mode on CPU; see
tests/test_kernels.py."""
from repro.kernels import cp_objective, ops, ref
