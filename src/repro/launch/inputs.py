"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

Nothing here allocates device memory: params/opt/cache structures come from
``jax.eval_shape`` and batches are ShapeDtypeStructs, so the 512-device
dry-run lowers and compiles without touching HBM (there is none).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, ShardingPlan
from repro.models import model
from repro.optim import AdamW, Adafactor
from repro.train.step import TrainState, train_state_specs

PARAM_DTYPE = jnp.bfloat16
CACHE_DTYPE = jnp.bfloat16


def pick_optimizer(cfg: ModelConfig):
    """Adafactor for the trillion-scale config, AdamW otherwise."""
    if cfg.moe is not None and cfg.moe.num_experts >= 256:
        return Adafactor()
    return AdamW()


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for one input batch (train/prefill)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return {
            "audio": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    if cfg.frontend == "patch_stub":
        n_img = min(cfg.n_frontend_tokens, S // 2)
        return {
            "patches": jax.ShapeDtypeStruct((B, n_img, cfg.d_model),
                                            jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((B, S - n_img), jnp.int32),
        }
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def batch_shardings(bspecs, plan: ShardingPlan):
    lead = plan.dp_axes if plan.dp_axes else None

    def shard_one(s):
        rest = [None] * (len(s.shape) - 1)
        return NamedSharding(plan.mesh, P(lead, *rest))

    return jax.tree.map(shard_one, bspecs)


def params_shapes(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: model.init(k, cfg, dtype=PARAM_DTYPE),
        jax.random.PRNGKey(0))


def state_shapes(cfg: ModelConfig, optimizer):
    pshapes = params_shapes(cfg)
    oshapes = jax.eval_shape(optimizer.init, pshapes)
    return TrainState(params=pshapes, opt=oshapes,
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def cache_shapes(cfg: ModelConfig, shape: ShapeConfig, plan: ShardingPlan):
    return jax.eval_shape(
        functools.partial(model.init_cache, cfg, shape.global_batch,
                          shape.seq_len, plan, CACHE_DTYPE,
                          enc_seq=shape.seq_len))


def to_named(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def train_cell(cfg: ModelConfig, shape: ShapeConfig, plan: ShardingPlan,
               clip: str = "quantile"):
    """(arg ShapeDtypeStructs, in_shardings, out_shardings) for train_step."""
    opt = pick_optimizer(cfg)
    state = state_shapes(cfg, opt)
    bspecs = batch_specs(cfg, shape)
    sspecs = train_state_specs(state, cfg, plan)
    in_sh = (to_named(sspecs, plan.mesh), batch_shardings(bspecs, plan))
    rep = NamedSharding(plan.mesh, P())
    keys = ["nll", "zloss", "loss"]
    if clip.startswith("quantile"):
        keys.append("clip_thr")
    elif clip == "global_norm":
        keys.append("grad_norm")
    if cfg.moe is not None:
        keys += ["moe_aux", "moe_z"]
    metrics_sh = {k: rep for k in keys}
    out_sh = (to_named(sspecs, plan.mesh), metrics_sh)
    return opt, (state, bspecs), in_sh, out_sh


def prefill_cell(cfg: ModelConfig, shape: ShapeConfig, plan: ShardingPlan):
    pshapes = params_shapes(cfg)
    pspec = model.param_specs(pshapes, cfg, plan)
    bspecs = batch_specs(cfg, shape)
    in_sh = (to_named(pspec, plan.mesh), batch_shardings(bspecs, plan))
    lead = plan.dp_axes if plan.dp_axes else None
    vtp = plan.tp_axis if cfg.vocab % max(plan.tp, 1) == 0 else None
    out_sh = NamedSharding(plan.mesh, P(lead, None, vtp))
    return (pshapes, bspecs), in_sh, out_sh


def decode_cell(cfg: ModelConfig, shape: ShapeConfig, plan: ShardingPlan):
    """serve_step(params, cache, token, index) specs/shardings."""
    B = shape.global_batch
    pshapes = params_shapes(cfg)
    pspec = model.param_specs(pshapes, cfg, plan)
    cshapes = cache_shapes(cfg, shape, plan)
    cspec = model.cache_specs(cshapes, cfg, plan)
    lead = plan.dp_axes if plan.dp_axes else None
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    idx = jax.ShapeDtypeStruct((), jnp.int32)
    mesh = plan.mesh
    in_sh = (to_named(pspec, mesh), to_named(cspec, mesh),
             NamedSharding(mesh, P(lead, None)), NamedSharding(mesh, P()))
    vtp = plan.tp_axis if cfg.vocab % max(plan.tp, 1) == 0 else None
    out_sh = (NamedSharding(mesh, P(lead, None)),
              NamedSharding(mesh, P(lead, None, vtp)),
              to_named(cspec, mesh))
    return (pshapes, cshapes, tok, idx), in_sh, out_sh
