import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This proves the distribution config is coherent without hardware: shardings
must be consistent, collectives legal, and the compiled memory analysis
reports per-device bytes (the "fits" evidence).  Results (cost analysis,
memory analysis, collective schedule) are cached as JSON per cell under
``experiments/dryrun`` so reruns skip completed cells.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import json
import time
import traceback

import jax

from repro.analysis import analyze_compiled, param_counts, roofline_terms
from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch import inputs as I
from repro.launch.mesh import make_plan, make_production_mesh
from repro.train.step import make_train_step, make_serve_step, make_prefill_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def cell_applicable(cfg, shape) -> bool:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False  # pure full-attention archs skip (noted in DESIGN.md)
    return True


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             clip: str = "quantile", rwkv_impl: str = "scan",
             donate: bool = True, accum: int = 0, strategy: str = "tp",
             rwkv_chunk: int = 0):
    import dataclasses
    cfg = get_config(arch)
    if rwkv_chunk:
        cfg = dataclasses.replace(cfg, rwkv_chunk=rwkv_chunk)
    shape = SHAPES[shape_name]
    if not cell_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "long_500k requires sub-quadratic attention"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = make_plan(cfg, shape, mesh, strategy=strategy)
    n_dev = mesh.devices.size
    t0 = time.time()

    if shape.kind == "train" and accum == 0:  # auto: ~4 seqs per microbatch
        b_loc = shape.global_batch // max(plan.dp, 1)
        accum = max(1, b_loc // 4)

    with mesh:
        if shape.kind == "train":
            opt, (state, bspecs), in_sh, out_sh = I.train_cell(
                cfg, shape, plan, clip=clip)
            step = make_train_step(cfg, plan, opt, clip=clip,
                                   rwkv_impl=rwkv_impl, accum_steps=accum)
            jf = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(0,) if donate else ())
            lowered = jf.lower(state, bspecs)
        elif shape.kind == "prefill":
            (pshapes, bspecs), in_sh, out_sh = I.prefill_cell(
                cfg, shape, plan)
            step = make_prefill_step(cfg, plan, rwkv_impl=rwkv_impl)
            jf = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jf.lower(pshapes, bspecs)
        else:  # decode
            args, in_sh, out_sh = I.decode_cell(cfg, shape, plan)
            step = make_serve_step(cfg, plan)
            jf = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(1,) if donate else ())
            lowered = jf.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    analysis = analyze_compiled(compiled, n_devices=n_dev)
    terms = roofline_terms(analysis)
    total, active = param_counts(I.params_shapes(cfg), cfg)

    # MODEL_FLOPS: 6*N_active*tokens (train) / 2*N_active*tokens (fwd-only)
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * active * tokens / n_dev
    useful = model_flops / max(analysis["flops_per_device"], 1.0)

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "kind": shape.kind,
        "plan": {
            "dp_axes": plan.dp_axes, "tp_axis": plan.tp_axis,
            "fsdp_axis": plan.fsdp_axis, "seq_axes": plan.seq_axes,
        },
        "params_total": total, "params_active": active,
        "model_flops_per_device": model_flops,
        "useful_flops_ratio": useful,
        "lower_s": t_lower, "compile_s": t_compile,
        **analysis,
        "roofline": terms,
        "skipped": False,
    }
    # memory_analysis + cost_analysis printed per the brief
    print(f"[{arch} x {shape_name} @ {result['mesh']}] "
          f"mem/device: args={analysis['argument_bytes']/2**30:.2f}GiB "
          f"temp={analysis['temp_bytes']/2**30:.2f}GiB | "
          f"flops/device={analysis['flops_per_device']:.3e} | "
          f"terms: c={terms['compute_s']*1e3:.2f}ms "
          f"m={terms['memory_s']*1e3:.2f}ms "
          f"coll={terms['collective_s']*1e3:.2f}ms "
          f"-> {terms['dominant']}-bound")
    return result


def cell_path(arch, shape_name, multi_pod, tag=""):
    mesh = "2x16x16" if multi_pod else "16x16"
    sfx = f"__{tag}" if tag else ""
    return os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh}{sfx}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--clip", default="quantile",
                    choices=("quantile", "quantile_hist", "global_norm",
                             "none"))
    ap.add_argument("--rwkv-impl", default="scan",
                    choices=("scan", "chunked"))
    ap.add_argument("--strategy", default="tp", choices=("tp", "fsdp"))
    ap.add_argument("--rwkv-chunk", type=int, default=0)
    ap.add_argument("--tag", default="", help="suffix for ablation runs")
    args = ap.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = (False, True) if args.both_meshes else (args.multi_pod,)
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    failures = []
    for arch, shape_name, mp in cells:
        path = cell_path(arch, shape_name, mp, args.tag)
        if os.path.exists(path) and not args.force:
            print(f"[skip cached] {os.path.basename(path)}")
            continue
        try:
            res = run_cell(arch, shape_name, multi_pod=mp, clip=args.clip,
                           rwkv_impl=args.rwkv_impl, strategy=args.strategy,
                           rwkv_chunk=args.rwkv_chunk)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape_name, mp, str(e)))
            continue
        with open(path, "w") as f:
            json.dump(res, f, indent=1, default=str)

    if failures:
        print("\nFAILURES:")
        for f_ in failures:
            print(" ", f_)
        raise SystemExit(1)
    print("\nAll requested cells compiled OK.")


if __name__ == "__main__":
    main()
