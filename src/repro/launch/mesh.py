"""Production mesh + per-(arch,shape) sharding plans.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: (data=16, model=16) = 256 chips (TPU v5e
pod slice); multi-pod: (pod=2, data=16, model=16) = 512 chips, with the pod
axis acting as an outer data-parallel dimension (cross-pod traffic is
gradient all-reduce only).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.configs.base import ModelConfig, ShapeConfig, ShardingPlan
from repro.core import _compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat.make_mesh(shape, axes)


def make_plan(cfg: ModelConfig, shape: ShapeConfig,
              mesh: Optional[jax.sharding.Mesh],
              strategy: str = "tp") -> ShardingPlan:
    """Map (arch x shape) onto the mesh.

    strategy='tp' (default): batch over ('pod','data'), tensor parallelism
    over 'model', fsdp per arch flag.  Decode with batch smaller than the
    dp degree (long_500k: batch=1) re-purposes the data (and model) axes as
    KV-sequence shards — distributed flash-decode.

    strategy='fsdp': pure data parallelism over EVERY mesh axis with fully
    sharded params (ZeRO-3-style): no activation all-reduces at all; the
    collective load becomes per-layer param all-gathers — the right trade
    when tokens/device is high and TP would replicate attention (e.g.
    gemma2's 8 heads on a 16-way model axis).  Non-MoE archs only.
    """
    if mesh is None:
        return ShardingPlan()
    names = mesh.axis_names

    if strategy == "fsdp":
        assert cfg.moe is None, "fsdp strategy: MoE needs the model axis"
        all_axes = tuple(a for a in ("pod", "data", "model") if a in names)
        total = 1
        for a in all_axes:
            total *= mesh.shape[a]
        assert shape.kind == "train" and shape.global_batch % total == 0, (
            "fsdp strategy is a training-shape plan")
        return ShardingPlan(mesh=mesh, dp_axes=all_axes, tp_axis=None,
                            fsdp_axis=all_axes, seq_axes=())

    dp_axes = tuple(a for a in ("pod", "data") if a in names)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]

    seq_axes: tuple = ()
    if shape.kind == "decode" and shape.global_batch < dp:
        # batch cannot fill the dp axes: shard the KV sequence instead
        dp_axes = ()
        seq_axes = tuple(a for a in ("data", "model") if a in names)
    elif shape.global_batch % max(dp, 1) != 0:
        # drop the pod axis from batch sharding if needed
        dp_axes = ("data",) if "data" in names else ()

    return ShardingPlan(
        mesh=mesh,
        dp_axes=dp_axes,
        tp_axis="model" if "model" in names else None,
        fsdp_axis="data" if (cfg.fsdp and "data" in names) else None,
        seq_axes=seq_axes,
    )
