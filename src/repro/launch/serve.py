"""Serving launcher: batched prefill + decode loop with KV caches and
request batching; latency percentiles via the paper's selection primitive.

  PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b --gen 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, local_plan
from repro.core import selection
from repro.models import model
from repro.train import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="phi3-mini-3.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    plan = local_plan()
    B, P, G = args.batch, args.prompt_len, args.gen
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0), cfg)

    serve = jax.jit(make_serve_step(cfg, plan))
    cache = model.init_cache(cfg, B, max_seq=P + G, plan=plan,
                             dtype=jnp.float32)
    prompt = rng.integers(0, cfg.vocab, (B, P)).astype(np.int32)

    tok = jnp.asarray(prompt[:, :1])
    lat = []
    for t in range(P + G - 1):
        nxt = (jnp.asarray(prompt[:, t + 1:t + 2]) if t + 1 < P else None)
        t0 = time.perf_counter()
        tok_out, _, cache = serve(params, cache, tok,
                                  jnp.asarray(t, jnp.int32))
        jax.block_until_ready(tok_out)
        lat.append(time.perf_counter() - t0)
        tok = nxt if nxt is not None else tok_out

    ts = jnp.asarray(lat[2:], jnp.float32)
    print(f"arch={cfg.name} (reduced) B={B}: served {P + G} positions")
    print(f"latency p50={float(selection.median(ts).value)*1e3:.2f}ms "
          f"p99={float(selection.quantile(ts, .99).value)*1e3:.2f}ms")


if __name__ == "__main__":
    main()
