"""Training launcher.

Two modes:

* ``--reduced`` (default): run REAL optimizer steps on this host with a
  reduced config — the end-to-end driver (data pipeline -> train_step ->
  checkpoints -> telemetry).
* ``--aot``: AOT lower+compile the full production config against the
  production mesh (equivalent to one dry-run cell) — what a cluster
  controller would ship to workers.

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --aot
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_IDS, SHAPES, get_config, local_plan
from repro.configs.base import ShapeConfig
from repro.data import SyntheticPipeline
from repro.models import model
from repro.optim import AdamW
from repro.train import TrainState, fit, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma2-2b")
    ap.add_argument("--shape", choices=tuple(SHAPES), default="train_4k")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--clip", default="quantile",
                    choices=("quantile", "global_norm", "none"))
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--aot", action="store_true",
                    help="AOT-compile the full config on the production mesh")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.aot:
        # defer to the dry-run machinery (shared code path)
        from repro.launch import dryrun
        res = dryrun.run_cell(args.arch, args.shape,
                              multi_pod=args.multi_pod, clip=args.clip)
        print("AOT compile OK:", res["arch"], res["shape"], res["mesh"])
        return

    cfg = get_config(args.arch).reduced()
    plan = local_plan()
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    params = model.init(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=3e-4)
    state = TrainState(params=params, opt=opt.init(params),
                       step=jnp.zeros((), jnp.int32))
    step_fn = make_train_step(cfg, plan, opt, clip=args.clip)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2) if args.ckpt_dir else None
    pipe = SyntheticPipeline(cfg, shape, seed=0)
    out = fit(train_step=step_fn, state=state, pipeline=pipe,
              steps=args.steps, ckpt=ckpt, ckpt_every=25, log_every=10)
    pipe.close()
    print(f"done: loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
