"""Adafactor (factored second moments) — the memory-frugal option for the
trillion-parameter configs (Kimi-K2): O(rows+cols) optimizer state for
matrices instead of O(rows*cols)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Adafactor:
    lr: float = 1e-3
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0

    def _factored(self, p):
        return p.ndim >= 2

    def init(self, params):
        def stats(p):
            if self._factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"stats": jax.tree.map(
            stats, params, is_leaf=lambda x: isinstance(x, jax.Array)),
            "count": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params, lr_scale=1.0):
        c = state["count"] + 1
        beta = 1.0 - c.astype(jnp.float32) ** -self.decay

        def upd(g, st, p):
            g = g.astype(jnp.float32)
            g2 = g * g + self.eps
            if self._factored(p):
                vr = beta * st["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * st["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    self.eps)
                vhat = (vr[..., None] / denom[..., None]) * vc[..., None, :]
                step = g / jnp.sqrt(vhat + self.eps)
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                step = g / jnp.sqrt(v + self.eps)
                new_st = {"v": v}
            # update clipping (Adafactor's RMS rule)
            rms = jnp.sqrt(jnp.mean(step * step) + self.eps)
            step = step / jnp.maximum(1.0, rms / self.clip_threshold)
            pf = p.astype(jnp.float32) - self.lr * lr_scale * step
            return pf.astype(p.dtype), new_st

        leaves = jax.tree.map(
            upd, grads, state["stats"], params,
            is_leaf=lambda x: isinstance(x, jax.Array) or (
                isinstance(x, dict) and ("v" in x or "vr" in x)))
        new_params = jax.tree.map(lambda o: o[0], leaves,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_stats = jax.tree.map(lambda o: o[1], leaves,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"stats": new_stats, "count": c}
