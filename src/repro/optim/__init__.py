from repro.optim.adamw import AdamW
from repro.optim.adafactor import Adafactor
from repro.optim.compress import int8_compress, int8_decompress

OPTIMIZERS = {"adamw": AdamW, "adafactor": Adafactor}
