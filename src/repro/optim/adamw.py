"""AdamW with bf16-param / f32-master support and pluggable clipping.

Pure-functional: ``state = opt.init(params)``, ``params, state =
opt.update(grads, state, params)``.  The f32 master copy lives in the
optimizer state when ``params`` are low-precision; m/v are always f32.
ZeRO-1 comes from sharding the state pytree (see train/step.py): the update
math is elementwise so any sharding of the state is legal.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    master_weights: bool = True

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        state = {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }
        if self.master_weights:
            # jnp.array copies: the master must not alias the params buffer
            # (donation would otherwise see the same buffer twice)
            state["master"] = jax.tree.map(
                lambda p: jnp.array(p, dtype=jnp.float32), params)
        return state

    def update(self, grads, state, params, lr_scale=1.0):
        c = state["count"] + 1
        b1c = 1.0 - self.b1 ** c.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** c.astype(jnp.float32)
        masters = state.get("master", params)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            step = (m / b1c) / (jnp.sqrt(v / b2c) + self.eps)
            pf = p.astype(jnp.float32)
            pf = pf - self.lr * lr_scale * (step + self.weight_decay * pf)
            return m, v, pf

        out = jax.tree.map(upd, grads, state["m"], state["v"], masters)
        m = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_master = jax.tree.map(lambda o: o[2], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree.map(
            lambda nm, p: nm.astype(p.dtype), new_master, params)
        new_state = {"m": m, "v": v, "count": c}
        if self.master_weights:
            new_state["master"] = new_master
        return new_params, new_state
