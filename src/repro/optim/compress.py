"""int8 gradient compression with stochastic rounding — an optional
distributed-optimization trick: gradients are quantized before the cross-
replica combine (4x ICI bytes saved) and dequantized after.  The scale is a
per-tensor max-abs (one cheap reduction)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress(key, tree):
    """Returns ({'q': int8, 'scale': f32} per leaf, new_key)."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves) + 1)

    def comp(k, g):
        g = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        x = g / scale
        noise = jax.random.uniform(k, g.shape, jnp.float32) - 0.5
        q = jnp.clip(jnp.round(x + noise), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale}

    out = [comp(keys[i], g) for i, g in enumerate(leaves)]
    return jax.tree.unflatten(treedef, out), keys[-1]


def int8_decompress(ctree):
    return jax.tree.map(
        lambda c: c["q"].astype(jnp.float32) * c["scale"],
        ctree, is_leaf=lambda x: isinstance(x, dict) and "q" in x)
