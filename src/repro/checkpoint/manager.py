"""Fault-tolerant checkpointing.

* Atomic: write to ``step_XXXX.tmp`` then ``os.replace`` -> a crash never
  leaves a half checkpoint visible.
* Async: saves run on a background thread (device->host transfer happens on
  the caller thread to get a consistent snapshot; serialization/IO overlap
  with the next training steps).
* Mesh-independent: tensors are saved *unsharded* as logical arrays keyed by
  their pytree path, so a checkpoint taken on a 16x16 mesh restores onto a
  2x16x16 (or single-device) mesh — the sharding is reapplied by the caller.
  (On a multi-host cluster each host would write its addressable shards with
  the same layout + an index; the format keeps that door open via the
  manifest's ``shards`` field.)
* Retention: keep the last ``keep`` checkpoints + every ``keep_every``-th.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Dict, Optional

import numpy as np

import jax


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 keep_every: int = 0, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.keep_every = keep_every
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save

    def save(self, step: int, tree, *, extra: Optional[Dict] = None):
        """Snapshot ``tree`` at ``step``; non-blocking when async."""
        self.wait()  # one in-flight save at a time
        arrays, _ = _flatten(tree)  # host transfer = consistent snapshot
        manifest = {
            "step": int(step),
            "time": time.time(),
            "keys": sorted(arrays.keys()),
            "shards": "full",  # single-host: full logical arrays
            "extra": extra or {},
        }

        def work():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)  # atomic publish
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------------------------------------------------- restore

    def steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                    out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, target):
        """Load into the structure of ``target`` (shape/dtype checked)."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        tkeys, treedef = _flatten(target)
        leaves = []
        for key in tkeys:
            if key not in data:
                raise KeyError(f"checkpoint missing tensor {key!r}")
            arr = data[key]
            want = tkeys[key]
            if tuple(arr.shape) != tuple(want.shape):
                raise ValueError(
                    f"{key}: shape {arr.shape} != target {want.shape}")
            leaves.append(arr.astype(want.dtype))
        tree = jax.tree_util.tree_unflatten(
            treedef, [leaves[i] for i, _ in enumerate(tkeys)])
        return tree, manifest

    # --------------------------------------------------------------- gc

    def _gc(self):
        steps = self.steps()
        protected = set(steps[-self.keep:]) if self.keep else set(steps)
        if self.keep_every:
            protected |= {s for s in steps if s % self.keep_every == 0}
        for s in steps:
            if s not in protected:
                shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                              ignore_errors=True)
