from repro.data.pipeline import SyntheticPipeline, batch_for_shape
