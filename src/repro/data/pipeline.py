"""Deterministic, resumable, shardable synthetic data pipeline.

Design for fault tolerance: ``batch = f(seed, step)`` — a pure function —
so recovery from a checkpoint replays the exact stream with no persisted
iterator state beyond the step counter.  A background prefetch thread keeps
``prefetch`` batches ahead; the thread is stateless and safe to kill.

Batches match ``launch.inputs`` specs per (arch x shape): tokens for LMs,
plus stub patch/frame embeddings for the [vlm]/[audio] frontends.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


from repro.configs.base import ModelConfig, ShapeConfig


def batch_for_shape(cfg: ModelConfig, shape: ShapeConfig, *, seed: int,
                    step: int, batch_override: Optional[int] = None):
    """Pure function (seed, step) -> batch dict (numpy, host-side)."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, 0xBEEF]))
    if cfg.family == "encdec":
        return {
            "audio": rng.standard_normal((B, S, cfg.d_model)).astype(
                np.float32) * 0.02,
            "tokens": rng.integers(0, cfg.vocab, (B, S), dtype=np.int32),
        }
    if cfg.frontend == "patch_stub":
        n_img = min(cfg.n_frontend_tokens, S - 1)
        return {
            "patches": rng.standard_normal((B, n_img, cfg.d_model)).astype(
                np.float32) * 0.02,
            "tokens": rng.integers(0, cfg.vocab, (B, S - n_img),
                                   dtype=np.int32),
        }
    return {"tokens": rng.integers(0, cfg.vocab, (B, S), dtype=np.int32)}


class SyntheticPipeline:
    """Resumable iterator with background prefetch.

    state() -> {'step': int}; restore by constructing with start_step.
    """

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0,
                 start_step: int = 0, prefetch: int = 2,
                 batch_override: Optional[int] = None):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.step = start_step
        self.batch_override = batch_override
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._next_produce = start_step
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            b = batch_for_shape(self.cfg, self.shape, seed=self.seed,
                                step=self._next_produce,
                                batch_override=self.batch_override)
            self._next_produce += 1
            while not self._stop.is_set():
                try:
                    self._q.put(b, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __next__(self):
        b = self._q.get()
        self.step += 1
        return b

    def __iter__(self) -> Iterator:
        return self

    def state(self):
        return {"step": self.step, "seed": self.seed}

    def close(self):
        self._stop.set()
