"""llava-next-mistral-7b — VLM, anyres tiling (stub frontend)
[hf:llava-hf/llava-v1.6-mistral-7b-hf].  The vision tower is a stub by
assignment: input_specs supplies precomputed patch embeddings (anyres:
base 576 + 4 tiles x 576 = 2880 patch tokens) prepended to the text."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="decoder",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    layer_pattern=(ATTN,),
    rope_theta=1e6,
    tie_embeddings=False,
    frontend="patch_stub",
    n_frontend_tokens=2880,
    sub_quadratic=False,
)
