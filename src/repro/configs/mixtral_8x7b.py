"""mixtral-8x7b — 8 experts top-2, sliding-window attention [arXiv:2401.04088]."""
from repro.configs.base import LOCAL, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="decoder",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    layer_pattern=(LOCAL,),   # SWA on every layer
    window=4096,
    rope_theta=1e6,
    moe=MoEConfig(num_experts=8, top_k=2, expert_ffn=14336),
    tie_embeddings=False,
    fsdp=True,                # 47B params
    sub_quadratic=True,       # SWA -> ring cache only
)
