"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8 (paper-table)."""
from repro.configs.base import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="decoder",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,               # per-expert ffn (MoE every layer per spec)
    vocab=163840,
    layer_pattern=(ATTN,),
    rope_theta=5e6,
    moe=MoEConfig(num_experts=384, top_k=8, expert_ffn=2048),
    tie_embeddings=False,
    fsdp=True,
    sub_quadratic=False,     # pure full attention -> long_500k skipped
)
