"""recurrentgemma-9b — RG-LRU + local attention, 2:1 [arXiv:2402.19427]."""
from repro.configs.base import LOCAL, RGLRU, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="decoder",
    n_layers=38,                    # 12 x (R,R,A) + 2 recurrent remainder
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,                   # MQA
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    layer_pattern=(RGLRU, RGLRU, LOCAL),
    window=2048,
    act="gelu",
    tie_embeddings=True,
    fsdp=True,
    sub_quadratic=True,   # recurrent state + ring caches only
)
