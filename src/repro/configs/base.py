"""Config system: model architecture, input shapes, sharding plan."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

# layer-pattern mixer kinds
ATTN = "attn"        # full (causal) attention
LOCAL = "local"      # sliding-window attention
RWKV = "rwkv"        # RWKV-6 (Finch) data-dependent-decay mixer
RGLRU = "rglru"      # RecurrentGemma RG-LRU recurrent block


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ffn: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # 'decoder' | 'encdec'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    layer_pattern: Tuple[str, ...] = (ATTN,)
    window: int = 4096           # sliding window for LOCAL layers
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0   # gemma3: different theta for global
    qk_norm: bool = False
    attn_softcap: float = 0.0    # 0 = off (gemma2: 50.0)
    final_softcap: float = 0.0   # gemma2: 30.0
    act: str = "silu"            # 'silu' | 'gelu'
    moe: Optional[MoEConfig] = None
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    frontend: Optional[str] = None   # None | 'patch_stub' | 'audio_stub'
    n_frontend_tokens: int = 0       # vlm: image patch token count
    enc_layers: int = 0              # encdec: encoder depth
    fsdp: bool = False               # shard params over data axis too
    sub_quadratic: bool = False      # eligible for long_500k
    # training-time defaults
    remat: str = "full"              # 'none' | 'full' (per-block jax.checkpoint)
    attn_chunk: int = 1024           # flash-attention KV chunk
    rwkv_chunk: int = 64
    head_dim_v: int = 0              # rwkv: value head dim (== head_dim)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def unit(self) -> Tuple[str, ...]:
        return self.layer_pattern

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.unit)

    @property
    def remainder(self) -> Tuple[str, ...]:
        """Layers beyond the scanned repeats (pattern prefix)."""
        r = self.n_layers - self.n_units * len(self.unit)
        return self.unit[:r]

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        unit = self.layer_pattern
        kw = dict(
            n_layers=len(unit) * 2 + len(self.remainder),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 2,
            head_dim=16,
            d_ff=128,
            vocab=512,
            window=8,
            attn_chunk=16,
            rwkv_chunk=8,
            n_frontend_tokens=min(self.n_frontend_tokens, 8),
            enc_layers=2 if self.enc_layers else 0,
            fsdp=False,
            remat="none",
        )
        if self.moe is not None:
            kw["moe"] = MoEConfig(
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                expert_ffn=32,
            )
        kw.update(overrides)
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ShardingPlan:
    """How the model maps onto the mesh.  mesh=None => single-device smoke."""
    mesh: Optional[jax.sharding.Mesh] = None
    dp_axes: Tuple[str, ...] = ()     # batch axes, e.g. ('pod', 'data')
    tp_axis: Optional[str] = None     # tensor-parallel axis name
    fsdp_axis: Optional[str] = None   # param shard axis (ZeRO-3 style)
    seq_axes: Tuple[str, ...] = ()    # KV-sequence shards for long decode

    @property
    def tp(self) -> int:
        if self.mesh is None or self.tp_axis is None:
            return 1
        return self.mesh.shape[self.tp_axis]

    @property
    def dp(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        return n

    def dspec(self, *rest) -> P:
        """Batch-sharded spec: P(dp_axes, *rest)."""
        lead = self.dp_axes if self.dp_axes else None
        return P(lead, *rest)

    def shard(self, x, spec: P):
        """with_sharding_constraint that no-ops without a mesh."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))


def local_plan() -> ShardingPlan:
    return ShardingPlan()
