"""phi3-mini-3.8b — dense RoPE SwiGLU GQA [arXiv:2404.14219]."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="decoder",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab=32064,
    layer_pattern=(ATTN,),
    rope_theta=10_000.0,
    tie_embeddings=False,
    sub_quadratic=False,
)
