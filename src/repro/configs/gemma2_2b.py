"""gemma2-2b — local/global alternating, logit softcaps [arXiv:2408.00118]."""
from repro.configs.base import ATTN, LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="decoder",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256000,
    layer_pattern=(LOCAL, ATTN),  # 1:1 alternating (13 repeats)
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
    sub_quadratic=True,   # half the layers are local; global cache seq-shards
)
