"""qwen3-32b — dense, qk-norm, GQA [hf:Qwen/Qwen3-8B family]."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="decoder",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab=151936,
    layer_pattern=(ATTN,),
    rope_theta=1e6,
    qk_norm=True,
    tie_embeddings=False,
    fsdp=True,
    sub_quadratic=False,
)
