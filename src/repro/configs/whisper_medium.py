"""whisper-medium — encoder-decoder, conv frontend stub [arXiv:2212.04356].

24 encoder + 24 decoder layers; the conv mel frontend is a STUB by
assignment (input_specs supplies precomputed frame embeddings)."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,          # decoder depth
    enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    layer_pattern=(ATTN,),
    rope_theta=0.0,       # sinusoidal positions, no rope
    act="gelu",
    tie_embeddings=True,
    frontend="audio_stub",
    sub_quadratic=False,
)
