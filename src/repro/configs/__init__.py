"""Config registry: ``get_config('<arch-id>')`` for every assigned arch."""
from repro.configs.base import (
    ATTN, LOCAL, RGLRU, RWKV, ModelConfig, MoEConfig, ShapeConfig, SHAPES,
    ShardingPlan, local_plan,
)

from repro.configs import (
    gemma2_2b,
    gemma3_27b,
    kimi_k2,
    llava_next_7b,
    mixtral_8x7b,
    phi3_mini,
    qwen3_32b,
    recurrentgemma_9b,
    rwkv6_1p6b,
    whisper_medium,
)

REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        rwkv6_1p6b, mixtral_8x7b, kimi_k2, gemma2_2b, qwen3_32b,
        gemma3_27b, phi3_mini, recurrentgemma_9b, llava_next_7b,
        whisper_medium,
    )
}

ARCH_IDS = tuple(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    return REGISTRY[name]


__all__ = [
    "ATTN", "LOCAL", "RGLRU", "RWKV", "ModelConfig", "MoEConfig",
    "ShapeConfig", "SHAPES", "ShardingPlan", "local_plan",
    "REGISTRY", "ARCH_IDS", "get_config",
]
