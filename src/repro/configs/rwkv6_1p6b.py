"""rwkv6-1.6b — Finch, attn-free, data-dependent decay [arXiv:2404.05892]."""
from repro.configs.base import RWKV, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="decoder",
    n_layers=24,
    d_model=2048,
    n_heads=32,          # head_dim 64 (RWKV convention d_model/64)
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab=65536,
    layer_pattern=(RWKV,),
    tie_embeddings=False,
    sub_quadratic=True,   # recurrent state -> O(1) decode cache
    rope_theta=0.0,       # no rope
)
