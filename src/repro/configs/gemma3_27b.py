"""gemma3-27b — 5:1 local:global, 128k context [hf:google/gemma-3 family]."""
from repro.configs.base import ATTN, LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="decoder",
    n_layers=62,                    # 10 x (5L+1G) + 2 local remainder
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab=262144,
    layer_pattern=(LOCAL, LOCAL, LOCAL, LOCAL, LOCAL, ATTN),
    window=1024,
    rope_theta=10_000.0,            # local layers
    rope_theta_global=1e6,          # global layers
    qk_norm=True,
    act="gelu",
    tie_embeddings=True,
    fsdp=True,
    sub_quadratic=True,   # 5/6 local; global cache seq-shards at 500k
)
