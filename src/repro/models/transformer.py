"""Decoder-only LM assembled from a repeating layer-pattern unit.

The layer stack is a ``lax.scan`` over ``cfg.n_units`` repeats of the
pattern unit (e.g. gemma3: (L,L,L,L,L,G)); the unit body is unrolled, so
every position has *static* layer kind / window / rope-theta.  Remainder
layers (n_layers % unit) are applied unrolled after the scan.  Scanning
keeps the HLO size O(unit) instead of O(n_layers) — essential for 512-way
SPMD compiles.

Caches are pytrees stacked along the scan dimension; decode steps scan over
(params, cache) pairs and emit the updated cache as the scan output.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL, RGLRU, RWKV, ModelConfig, ShardingPlan
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv as rwkv_mod


# ---------------------------------------------------------------------------
# per-layer init/apply
# ---------------------------------------------------------------------------


def _layer_init(key, kind: str, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), dtype),
                         "ln2": jnp.zeros((cfg.d_model,), dtype)}
    if kind in (ATTN, LOCAL):
        p["mixer"] = attn.attn_init(k1, cfg, dtype)
    elif kind == RWKV:
        p["mixer"] = rwkv_mod.rwkv_init(k1, cfg, dtype)
    elif kind == RGLRU:
        p["mixer"] = rglru_mod.rglru_init(k1, cfg, dtype)
    else:
        raise ValueError(kind)
    if kind == RWKV:
        p["ffn"] = rwkv_mod.chanmix_init(k2, cfg, dtype)
    elif cfg.moe is not None:
        p["ffn"] = moe_mod.moe_init(k2, cfg, dtype)
    else:
        p["ffn"] = L.mlp_init(k2, cfg, dtype=dtype)
    return p


def _theta(kind: str, cfg: ModelConfig) -> float:
    if kind == ATTN and cfg.rope_theta_global:
        return cfg.rope_theta_global
    return cfg.rope_theta


def _layer_apply(kind, p, x, positions, cfg, plan, cache, mode,
                 rwkv_impl="scan"):
    """One block: mixer + ffn with pre-norms. Returns (x, new_cache, aux)."""
    aux = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    new_cache = None

    if kind in (ATTN, LOCAL):
        theta = _theta(kind, cfg)
        q, k, v = attn.qkv_proj(p["mixer"], h, positions, cfg, plan, theta)
        if mode == "decode":
            idx = positions[0]
            if kind == LOCAL:
                new_cache, o = attn.decode_ring(cache, q, k, v, idx, cfg,
                                                plan, cfg.attn_softcap)
            else:
                new_cache, o = attn.decode_global(cache, q, k, v, idx, cfg,
                                                  plan, cfg.attn_softcap)
        else:
            window = cfg.window if kind == LOCAL else 0
            o = attn.flash_attention(
                q, k, v, causal=True, window=window, chunk=cfg.attn_chunk,
                cap=cfg.attn_softcap)
        mixed = attn.out_proj(p["mixer"], o, cfg, plan)
    elif kind == RWKV:
        mixed, new_cache = rwkv_mod.rwkv_apply(
            p["mixer"], h, cfg, plan,
            cache={"shift": cache["shift"], "state": cache["state"]}
            if cache else None, impl=rwkv_impl)
    elif kind == RGLRU:
        mixed, new_cache = rglru_mod.rglru_apply(
            p["mixer"], h, cfg, plan, cache=cache)
    else:
        raise ValueError(kind)

    x = x + mixed
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == RWKV:
        f, cshift = rwkv_mod.chanmix_apply(
            p["ffn"], h2, cfg, plan,
            cache={"shift": cache["cshift"]} if cache else None)
        if new_cache is not None:
            new_cache = dict(new_cache, cshift=cshift["shift"])
    elif cfg.moe is not None:
        f, a, z = moe_mod.moe_apply(p["ffn"], h2, cfg, plan)
        aux = (a, z)
    else:
        f = L.mlp_apply(p["ffn"], h2, cfg, plan)
    return x + f, new_cache, aux


def _layer_cache(kind, cfg, batch, max_seq, plan, dtype):
    if kind == ATTN:
        return attn.init_global_cache(cfg, batch, max_seq, plan, dtype)
    if kind == LOCAL:
        return attn.init_ring_cache(cfg, batch, plan, dtype)
    if kind == RWKV:
        return {
            "shift": jnp.zeros((batch, 1, cfg.d_model), dtype),
            "state": jnp.zeros((batch, cfg.n_heads, cfg.hd, cfg.hd),
                               jnp.float32),
            "cshift": jnp.zeros((batch, 1, cfg.d_model), dtype),
        }
    if kind == RGLRU:
        return {
            "h": jnp.zeros((batch, cfg.d_model), jnp.float32),
            "conv": jnp.zeros((batch, rglru_mod.CONV_W - 1, cfg.d_model),
                              dtype),
        }
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# full decoder
# ---------------------------------------------------------------------------


def init_decoder(key, cfg: ModelConfig, dtype=jnp.float32):
    k_embed, k_units, k_rem, k_final = jax.random.split(key, 4)
    params: Dict[str, Any] = {"embed": L.embed_init(k_embed, cfg, dtype)}

    def unit_init(k):
        ks = jax.random.split(k, len(cfg.unit))
        return [
            _layer_init(ks[i], kind, cfg, dtype)
            for i, kind in enumerate(cfg.unit)
        ]

    if cfg.n_units > 0:
        params["units"] = jax.vmap(unit_init)(
            jax.random.split(k_units, cfg.n_units))
    for i, kind in enumerate(cfg.remainder):
        params[f"rem_{i}"] = _layer_init(
            jax.random.fold_in(k_rem, i), kind, cfg, dtype)
    params["final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    return params


def _unit_caches(cfg, batch, max_seq, plan, dtype):
    def one_unit(_):
        return [
            _layer_cache(kind, cfg, batch, max_seq, plan, dtype)
            for kind in cfg.unit
        ]
    if cfg.n_units == 0:
        return None
    caches = [one_unit(None) for _ in range(cfg.n_units)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *caches)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               plan: ShardingPlan, dtype=jnp.bfloat16):
    cache = {"units": _unit_caches(cfg, batch, max_seq, plan, dtype)}
    for i, kind in enumerate(cfg.remainder):
        cache[f"rem_{i}"] = _layer_cache(kind, cfg, batch, max_seq, plan,
                                         dtype)
    return cache


def _embed_inputs(params, batch, cfg: ModelConfig, plan: ShardingPlan):
    """tokens (+ optional stub frontend embeddings) -> (B, S, D)."""
    x = L.embed_apply(params["embed"], batch["tokens"], cfg, plan)
    if cfg.frontend == "patch_stub" and "patches" in batch:
        # [vlm]: precomputed patch embeddings prepended to the text tokens
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    return x


def forward(params, batch, cfg: ModelConfig, plan: ShardingPlan, *,
            mode: str = "train", rwkv_impl: str = "scan",
            return_hidden: bool = False):
    """Full-sequence forward (train / prefill). Returns (logits, aux), or
    (normed hidden states, aux) when ``return_hidden`` (fused-loss path)."""
    x = _embed_inputs(params, batch, cfg, plan)
    B, S, D = x.shape
    positions = jnp.arange(S)
    aux_tot = jnp.zeros((2,), jnp.float32)

    def unit_body(x, unit_params):
        aux_u = jnp.zeros((2,), jnp.float32)
        for i, kind in enumerate(cfg.unit):
            x, _, aux = _layer_apply(kind, unit_params[i], x, positions,
                                     cfg, plan, None, mode, rwkv_impl)
            aux_u = aux_u + jnp.stack(aux)
        return x, aux_u

    if cfg.n_units > 0:
        body = unit_body
        if cfg.remat == "full" and mode == "train":
            body = jax.checkpoint(
                unit_body,
                policy=jax.checkpoint_policies.nothing_saveable)
        x, aux_units = jax.lax.scan(body, x, params["units"])
        aux_tot = aux_tot + jnp.sum(aux_units, axis=0)

    for i, kind in enumerate(cfg.remainder):
        x, _, aux = _layer_apply(kind, params[f"rem_{i}"], x, positions,
                                 cfg, plan, None, mode, rwkv_impl)
        aux_tot = aux_tot + jnp.stack(aux)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    aux = {"moe_aux": aux_tot[0], "moe_z": aux_tot[1]}
    if return_hidden:
        return x, aux
    logits = L.unembed_apply(params["embed"], x, cfg, plan,
                             apply_softcap=(mode != "train"))
    return logits, aux


def decode_step(params, cache, token, index, cfg: ModelConfig,
                plan: ShardingPlan):
    """One-token decode. token: (B, 1) int32; index: scalar position.
    Returns (logits (B,1,V), new_cache)."""
    x = L.embed_apply(params["embed"], token, cfg, plan)
    positions = jnp.full((1,), index, jnp.int32)

    def unit_body(x, inp):
        unit_params, unit_cache = inp
        new_caches = []
        for i, kind in enumerate(cfg.unit):
            x, nc, _ = _layer_apply(kind, unit_params[i], x, positions,
                                    cfg, plan, unit_cache[i], "decode")
            new_caches.append(nc)
        return x, new_caches

    if cfg.n_units > 0:
        x, new_unit_caches = jax.lax.scan(
            unit_body, x, (params["units"], cache["units"]))
    else:
        new_unit_caches = None

    new_cache = {"units": new_unit_caches}
    for i, kind in enumerate(cfg.remainder):
        x, nc, _ = _layer_apply(kind, params[f"rem_{i}"], x, positions,
                                cfg, plan, cache[f"rem_{i}"], "decode")
        new_cache[f"rem_{i}"] = nc

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], x, cfg, plan)
    return logits, new_cache
