"""Attention: chunked-flash train/prefill, cached decode (global + ring
buffer for sliding windows), and sequence-sharded distributed flash-decode.

TPU adaptation notes:
  * train/prefill use an online-softmax flash formulation as a ``lax.scan``
    over KV chunks — O(S * chunk) live memory instead of O(S^2) scores, so
    32k-prefill fits;
  * sliding-window (LOCAL) layers keep a RING-BUFFER cache of size
    ``window`` — a 500k-context decode stores only ``window`` KV entries for
    local layers (this is what makes long_500k cheap for gemma-style and
    SWA archs);
  * for global layers at 500k the KV cache is sharded over mesh axes along
    the *sequence* dim and partial flash statistics (m, l, o) are combined
    with psum — the paper's "scalar partial sums across devices" idea
    applied to attention (``seqshard_decode_attention``).
  * GQA: kv heads are repeated up to the TP degree only when needed
    (``eff_kv``), so TP sharding of the head dim stays even.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShardingPlan
from repro.core import _compat
from repro.models.layers import _init, rms_norm, rope, softcap

NEG = -1e30


def attn_tp(cfg: ModelConfig, plan: ShardingPlan) -> int:
    """TP degree usable for attention heads (1 => replicated attention)."""
    tp = plan.tp
    return tp if cfg.n_heads % tp == 0 else 1


def eff_kv(cfg: ModelConfig, plan: ShardingPlan) -> int:
    """KV head count after replication up to the attention TP degree."""
    tp = attn_tp(cfg, plan)
    kv = cfg.n_kv_heads
    if kv % tp == 0:
        return kv
    assert tp % kv == 0, (cfg.name, kv, tp)
    return tp


def head_spec(cfg: ModelConfig, plan: ShardingPlan):
    """Axis name for sharding head dims (None if attention is replicated)."""
    return plan.tp_axis if attn_tp(cfg, plan) > 1 else None


def attn_init(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    hd = cfg.hd
    p = {
        "wq": _init(ks[0], (cfg.d_model, cfg.n_heads, hd), dtype=dtype),
        "wk": _init(ks[1], (cfg.d_model, cfg.n_kv_heads, hd), dtype=dtype),
        "wv": _init(ks[2], (cfg.d_model, cfg.n_kv_heads, hd), dtype=dtype),
        "wo": _init(ks[3], (cfg.n_heads, hd, cfg.d_model), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _repeat_kv(k, v, cfg, plan):
    e = eff_kv(cfg, plan)
    rep = e // cfg.n_kv_heads
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    return k, v


def qkv_proj(p, x, positions, cfg: ModelConfig, plan: ShardingPlan,
             theta: float):
    """Project + qk-norm + rope. Returns q:(B,S,H,hd), k/v:(B,S,eff,hd)."""
    hspec = head_spec(cfg, plan)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if theta:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    k, v = _repeat_kv(k, v, cfg, plan)
    q = plan.shard(q, plan.dspec(None, hspec, None))
    k = plan.shard(k, plan.dspec(None, hspec, None))
    v = plan.shard(v, plan.dspec(None, hspec, None))
    return q, k, v


def out_proj(p, o, cfg: ModelConfig, plan: ShardingPlan):
    """o: (B, S, H, hd) -> (B, S, D)."""
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return plan.shard(out, plan.dspec(None, None))


# ---------------------------------------------------------------------------
# Flash attention (train / prefill): scan over KV chunks, online softmax
# ---------------------------------------------------------------------------


def banded_flash_attention(q, k, v, *, window: int, cap: float):
    """Sliding-window attention as a block-banded computation: query block i
    attends only to KV blocks {i-1, i} with block size == window.  Work is
    O(S * 2w) instead of the masked-full O(S^2) — 16x fewer attention flops
    for gemma3 (w=1024) at 32k prefill.  Requires Sq == Skv (self-attn)."""
    B, S, H, hd = q.shape
    E = k.shape[2]
    G = H // E
    c = window
    pad = (-S) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nb = Sp // c
    scale = hd ** -0.5
    qb = (q.reshape(B, nb, c, E, G, hd) * scale).astype(jnp.float32)
    kb = k.reshape(B, nb, c, E, hd)
    vb = v.reshape(B, nb, c, E, hd)
    # previous block (zeros before block 0, masked out by position)
    kp = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vp = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([kp, kb], axis=2)  # (B, nb, 2c, E, hd)
    v2 = jnp.concatenate([vp, vb], axis=2)
    s = jnp.einsum("bntegk,bnsek->bnegts", qb, k2.astype(jnp.float32))
    if cap:
        s = softcap(s, cap)
    # positions within the band: query t (block-local), key s in [-c, c)
    tq = jnp.arange(c)[:, None]
    tk = jnp.arange(2 * c)[None, :] - c
    mask = (tk <= tq) & (tk > tq - window)
    # block 0 has no previous block
    first = jnp.arange(nb)[:, None, None] > 0
    mask = mask[None] & (first | (tk >= 0)[None])
    # padded tail keys
    if pad:
        kpos = (jnp.arange(nb)[:, None, None] * c + tk[None])
        mask = mask & (kpos < S) if pad else mask
    s = jnp.where(mask[None, :, None, None], s, NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(mask[None, :, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bnegts,bnsek->bntegk", p / jnp.maximum(l, 1e-30),
                   v2.astype(jnp.float32))
    o = o.reshape(B, Sp, H, hd)[:, :S]
    return o.astype(q.dtype)


def flash_attention(q, k, v, *, causal: bool, window: int, chunk: int,
                    cap: float, q_offset=0):
    """q: (B,Sq,H,hd); k,v: (B,Skv,E,hd) with H % E == 0.  Online-softmax
    scan over KV chunks; O(Sq*chunk) live score memory.  Sliding-window
    self-attention takes the block-banded path (O(S*2w) work)."""
    if (window and q.shape[1] == k.shape[1] and causal
            and q.shape[1] > window and window <= 2048):
        # larger windows would materialize (c x 2c) band blocks beyond the
        # remat budget — they keep the masked online-softmax scan
        return banded_flash_attention(q, k, v, window=window, cap=cap)
    B, Sq, H, hd = q.shape
    Skv, E = k.shape[1], k.shape[2]
    G = H // E
    scale = hd ** -0.5
    qr = (q.reshape(B, Sq, E, G, hd) * scale).astype(jnp.float32)
    chunk = min(chunk, Skv)
    nchunks = -(-Skv // chunk)
    if nchunks * chunk != Skv:  # pad KV; padded keys masked by position
        pad = nchunks * chunk - Skv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    pos_q = q_offset + jnp.arange(Sq)

    def step(carry, idx):
        m, l, o = carry
        ks = jax.lax.dynamic_slice_in_dim(k, idx * chunk, chunk, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, idx * chunk, chunk, 1)
        s = jnp.einsum("bsegk,btek->bsegt", qr,
                       ks.astype(jnp.float32))
        if cap:
            s = softcap(s, cap)
        pos_k = idx * chunk + jnp.arange(chunk)
        mask = pos_k[None, :] < Skv  # (Sq, chunk) via broadcast below
        mask = jnp.broadcast_to(mask, (Sq, chunk))
        if causal:
            mask = mask & (pos_k[None, :] <= pos_q[:, None])
        if window:
            mask = mask & (pos_k[None, :] > pos_q[:, None] - window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p_ = jnp.exp(s - m_new[..., None])
        p_ = jnp.where(mask[None, :, None, None, :], p_, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p_, axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bsegt,btek->bsegk", p_, vs.astype(jnp.float32))
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, Sq, E, G), NEG, jnp.float32)
    l0 = jnp.zeros((B, Sq, E, G), jnp.float32)
    o0 = jnp.zeros((B, Sq, E, G, hd), jnp.float32)
    # remat the chunk step: the (B,Sq,E,G,chunk) probability tensor must be
    # recomputed in the backward pass, not saved per chunk (it dominates
    # training memory otherwise)
    step = jax.checkpoint(step,
                          policy=jax.checkpoint_policies.nothing_saveable)
    (m, l, o), _ = jax.lax.scan(step, (m0, l0, o0), jnp.arange(nchunks))
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode with caches
# ---------------------------------------------------------------------------


def init_global_cache(cfg, batch, max_seq, plan: ShardingPlan,
                      dtype=jnp.bfloat16):
    e = eff_kv(cfg, plan)
    shp = (batch, max_seq, e, cfg.hd)
    return {
        "k": jnp.zeros(shp, dtype),
        "v": jnp.zeros(shp, dtype),
    }


def init_ring_cache(cfg, batch, plan: ShardingPlan, dtype=jnp.bfloat16):
    e = eff_kv(cfg, plan)
    w = cfg.window
    return {
        "k": jnp.zeros((batch, w, e, cfg.hd), dtype),
        "v": jnp.zeros((batch, w, e, cfg.hd), dtype),
        "pos": jnp.full((w,), -1, jnp.int32),
    }


def _decode_scores(q, ks, vs, valid, cap):
    """q: (B,1,H,hd); ks/vs: (B,T,E,hd); valid: (T,) or (B,T)."""
    B, _, H, hd = q.shape
    E = ks.shape[2]
    G = H // E
    scale = hd ** -0.5
    qr = (q.reshape(B, E, G, hd) * scale).astype(jnp.float32)
    s = jnp.einsum("begk,btek->begt", qr, ks.astype(jnp.float32))
    if cap:
        s = softcap(s, cap)
    if valid.ndim == 1:
        valid = valid[None, :]
    s = jnp.where(valid[:, None, None, :], s, NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("begt,btek->begk", p / jnp.maximum(l, 1e-30),
                   vs.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def decode_global(cache, q, k_new, v_new, index, cfg, plan, cap=0.0):
    """One-token decode against a preallocated (B,S,E,hd) cache.

    When ``plan.seq_axes`` is set the cache sequence dim is sharded across
    those mesh axes and partials are psum-combined (distributed
    flash-decode).
    """
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), index, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), index, axis=1)
    new_cache = {"k": k_cache, "v": v_cache}

    if plan.seq_axes and plan.mesh is not None:
        out = _seqshard_decode(q, k_cache, v_cache, index, cfg, plan, cap)
        return new_cache, out

    valid = jnp.arange(cache["k"].shape[1]) <= index
    return new_cache, _decode_scores(q, k_cache, v_cache, valid, cap)


def decode_ring(cache, q, k_new, v_new, index, cfg, plan, cap=0.0):
    """One-token decode against a ring-buffer (window) cache."""
    w = cache["k"].shape[1]
    slot = index % w
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    pos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], index[None].astype(jnp.int32), slot, axis=0)
    valid = (pos >= 0) & (pos > index - w)
    out = _decode_scores(q, k_cache, v_cache, valid, cap)
    return {"k": k_cache, "v": v_cache, "pos": pos}, out


def _seqshard_decode(q, k_cache, v_cache, index, cfg, plan, cap):
    """Distributed flash-decode: KV sharded along sequence over
    plan.seq_axes; combine (m, l, o) partials with psum (paper-style scalar
    combine per head)."""
    axes = plan.seq_axes
    B, S, E, hd = k_cache.shape
    H = q.shape[2]
    G = H // E
    n_shards = 1
    for a in axes:
        n_shards *= plan.mesh.shape[a]
    s_local = S // n_shards

    def local(qv, kc, vc, idx):
        # global offset of this shard's KV slice
        off = jnp.asarray(0, jnp.int32)
        mult = jnp.asarray(s_local, jnp.int32)
        for a in reversed(axes):
            off = off + jax.lax.axis_index(a) * mult
            mult = mult * plan.mesh.shape[a]
        scale = hd ** -0.5
        qr = (qv.reshape(B, E, G, hd) * scale).astype(jnp.float32)
        s = jnp.einsum("begk,btek->begt", qr, kc.astype(jnp.float32))
        if cap:
            s = softcap(s, cap)
        valid = (off + jnp.arange(kc.shape[1])) <= idx
        s = jnp.where(valid[None, None, None, :], s, NEG)
        m = jnp.max(s, axis=-1)
        m_g = jax.lax.pmax(m, axes)
        p = jnp.exp(s - m_g[..., None])
        p = jnp.where(valid[None, None, None, :], p, 0.0)
        l = jax.lax.psum(jnp.sum(p, axis=-1), axes)
        o = jax.lax.psum(
            jnp.einsum("begt,btek->begk", p, vc.astype(jnp.float32)), axes)
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return o.reshape(B, 1, H, hd).astype(qv.dtype)

    from jax.sharding import PartitionSpec as P
    lead = plan.dp_axes if plan.dp_axes else None
    seq = axes if len(axes) > 1 else axes[0]
    return _compat.shard_map(
        local, mesh=plan.mesh,
        in_specs=(P(lead, None, None, None),
                  P(lead, seq, None, None),
                  P(lead, seq, None, None),
                  P()),
        out_specs=P(lead, None, None, None),
        check=False,
    )(q, k_cache, v_cache, jnp.asarray(index, jnp.int32))
