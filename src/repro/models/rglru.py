"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: input -> [linear -> causal depthwise conv(4) -> RG-LRU] * [linear ->
GeLU] -> output linear.  The RG-LRU recurrence

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)         (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

is an elementwise affine scan -> ``jax.lax.associative_scan`` for
train/prefill (log-depth, parallel), a single fused step for decode.
State per layer: h (B, R) + conv tail (B, 3, R).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShardingPlan
from repro.models.layers import _init

C_FACTOR = 8.0
CONV_W = 4


def rglru_init(key, cfg: ModelConfig, dtype=jnp.float32):
    D = cfg.d_model
    R = cfg.d_model  # rnn width == d_model
    ks = jax.random.split(key, 7)
    return {
        "w_branch": _init(ks[0], (D, R), dtype=dtype),
        "w_gate_branch": _init(ks[1], (D, R), dtype=dtype),
        "conv_w": _init(ks[2], (CONV_W, R), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((R,), dtype),
        "w_a": _init(ks[3], (R, R), dtype=dtype),
        "b_a": jnp.zeros((R,), dtype),
        "w_x": _init(ks[4], (R, R), dtype=dtype),
        "b_x": jnp.zeros((R,), dtype),
        "lam": jnp.full((R,), 0.65, dtype),  # sigmoid^-1-ish init
        "w_out": _init(ks[5], (R, D), dtype=dtype),
    }


def _conv_causal(x, w, b, tail):
    """Depthwise causal conv, width 4.  x: (B,S,R); tail: (B,3,R)."""
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(
        xp[:, CONV_W - 1 - i: xp.shape[1] - i] * w[CONV_W - 1 - i]
        for i in range(CONV_W)
    )
    return out + b, xp[:, -(CONV_W - 1):]


def _rg_lru(x, p, h0):
    """x: (B,S,R) conv output; h0: (B,R). Returns (h_seq, h_last)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_x"].astype(jnp.float32) + p["b_x"])
    log_a = -C_FACTOR * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)

    if x.shape[1] == 1:  # decode
        h = a[:, 0] * h0 + gated[:, 0]
        return h[:, None], h

    # prepend carry as an extra step: h_0 enters via (a=1 -> identity)
    a_all = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
    b_all = jnp.concatenate([h0[:, None].astype(jnp.float32), gated], axis=1)

    def comb(l, r_):
        al, bl = l
        ar, br = r_
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(comb, (a_all, b_all), axis=1)
    return h[:, 1:], h[:, -1]


def rglru_apply(p, x, cfg: ModelConfig, plan: ShardingPlan, cache=None):
    """x: (B,S,D). cache: {'h': (B,R), 'conv': (B,3,R)} or None."""
    B, S, D = x.shape
    R = cfg.d_model
    tp = plan.tp_axis
    h0 = cache["h"] if cache else jnp.zeros((B, R), jnp.float32)
    tail = (cache["conv"] if cache
            else jnp.zeros((B, CONV_W - 1, R), x.dtype))

    u = x @ p["w_branch"]
    u = plan.shard(u, plan.dspec(None, tp))
    g = jax.nn.gelu(x @ p["w_gate_branch"])
    g = plan.shard(g, plan.dspec(None, tp))
    u, new_tail = _conv_causal(u, p["conv_w"], p["conv_b"], tail)
    h, h_last = _rg_lru(u, p, h0)
    out = (h.astype(x.dtype) * g) @ p["w_out"]
    out = plan.shard(out, plan.dspec(None, None))
    return out, {"h": h_last, "conv": new_tail}
