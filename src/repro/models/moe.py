"""Mixture-of-Experts: top-k routing + sort-based grouped matmul
(``lax.ragged_dot``), with two sharding strategies:

* **EP** (expert parallelism) when ``num_experts % tp == 0`` (e.g. Kimi-K2:
  384 experts over 16 model shards = 24/shard): tokens are sorted by expert,
  each shard takes its experts' contiguous segment with a static *capacity*
  slice (``dynamic_slice`` at a traced offset — XLA-legal), computes the
  grouped matmul locally and scatter-adds back; partial outputs are psum'd
  over the model axis.  No all-to-all: activations are already replicated
  over the model axis in TP blocks, so the EP combine is one all-reduce.
* **TP-within-expert** when experts don't divide the mesh (Mixtral: 8
  experts over 16 shards): every shard computes all assignments against an
  ``F/tp`` slice of every expert and psums the partial outputs.

The local (mesh-free) path is the reference implementation used by the
smoke tests and the oracle for the sharded paths.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShardingPlan
from repro.core import _compat
from repro.models.layers import _init, act_fn


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    ks = jax.random.split(key, 4)
    return {
        "router": _init(ks[0], (cfg.d_model, m.num_experts), dtype=jnp.float32),
        "w_gate": _init(ks[1], (m.num_experts, cfg.d_model, m.expert_ffn),
                        dtype=dtype),
        "w_in": _init(ks[2], (m.num_experts, cfg.d_model, m.expert_ffn),
                      dtype=dtype),
        "w_out": _init(ks[3], (m.num_experts, m.expert_ffn, cfg.d_model),
                       dtype=dtype),
    }


def use_ep(cfg: ModelConfig, plan: ShardingPlan) -> bool:
    return (plan.tp > 1 and cfg.moe is not None
            and cfg.moe.num_experts % plan.tp == 0)


def _route(x, router, cfg: ModelConfig):
    """Top-k routing. x: (T, D). Returns ids/gates (T, K) + aux losses."""
    m = cfg.moe
    logits = (x.astype(jnp.float32) @ router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.sum(gates, -1, keepdims=True)
    # load-balance loss (Switch): E * sum_e f_e * p_e
    E = m.num_experts
    f = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f = f / jnp.maximum(jnp.sum(f), 1.0)
    p = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * p)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return ids, gates.astype(x.dtype), aux, z


def _sort_by_expert(ids, gates, E: int):
    """Flatten (T,K) assignments and sort by expert id (stable)."""
    T, K = ids.shape
    flat_e = ids.reshape(-1)
    flat_t = jnp.arange(T * K, dtype=jnp.int32) // K
    order = jnp.argsort(flat_e)  # stable
    se = flat_e[order]
    st = flat_t[order]
    sg = gates.reshape(-1)[order]
    group_sizes = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    return se, st, sg, group_sizes


def _capacity(tokens: int, top_k: int, E: int, cf: float) -> int:
    cap = int(tokens * top_k / E * cf)
    return max(8, -(-cap // 8) * 8)


def _grouped_moe(x_local, router, w_gate, w_in, w_out, cfg, *, first, El, Ce,
                 act):
    """Capacity-grouped MoE (sort -> (El, Ce, D) dispatch -> 3 einsums).

    Exact static FLOPs (= El*Ce rows through a dense grouped matmul),
    TPU-portable (no ragged_dot), tokens beyond an expert's capacity are
    dropped (standard practice; cf controls headroom).
    ``first``/``El`` select this shard's expert range (0/E when replicated).
    """
    E = cfg.moe.num_experts
    ids, gates, aux, z = _route(x_local, router, cfg)
    se, st, sg, gs = _sort_by_expert(ids, gates, E)
    # slot of each sorted assignment within its expert group
    gstart = jnp.cumsum(gs) - gs
    p = jnp.arange(se.shape[0], dtype=jnp.int32)
    slot = p - gstart[se]
    le = se - first
    valid = (le >= 0) & (le < El) & (slot < Ce)
    lec = jnp.where(valid, le, 0)
    slc = jnp.where(valid, slot, 0)
    xs = jnp.zeros((El, Ce, x_local.shape[1]), x_local.dtype)
    xs = xs.at[lec, slc].add(
        jnp.where(valid[:, None], x_local[st], 0))
    h = act(jnp.einsum("ecd,edf->ecf", xs, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", xs, w_in)
    y = jnp.einsum("ecf,efd->ecd", h, w_out)
    contrib = y[lec, slc] * jnp.where(valid, sg, 0.0)[:, None]
    out = jnp.zeros_like(x_local).at[st].add(contrib)
    return out, aux, z


def moe_apply(p, x, cfg: ModelConfig, plan: ShardingPlan):
    """x: (B, S, D) -> (out, aux_loss, z_loss)."""
    B, S, D = x.shape
    x2 = x.reshape(B * S, D)
    m = cfg.moe
    E = m.num_experts
    act = act_fn(cfg.act)

    if plan.mesh is None or plan.tp == 1:
        Ce = _capacity(B * S, m.top_k, E, m.capacity_factor)
        out, aux, z = _grouped_moe(x2, p["router"], p["w_gate"], p["w_in"],
                                   p["w_out"], cfg, first=0, El=E, Ce=Ce,
                                   act=act)
        return out.reshape(B, S, D), aux, z

    tp = plan.tp
    lead = plan.dp_axes if plan.dp_axes else None
    tpx = plan.tp_axis
    fsdp = plan.fsdp_axis if cfg.fsdp else None
    T_loc = (B // max(plan.dp, 1)) * S
    Ce = _capacity(T_loc, m.top_k, E, m.capacity_factor)
    ep = use_ep(cfg, plan)
    El = E // tp if ep else E

    def body(x_local, router, w_gate, w_in, w_out):
        if fsdp is not None:
            # FSDP: weights stored data-sharded; gather for compute
            w_gate = jax.lax.all_gather(w_gate, fsdp, axis=1, tiled=True)
            w_in = jax.lax.all_gather(w_in, fsdp, axis=1, tiled=True)
            w_out = jax.lax.all_gather(w_out, fsdp, axis=2, tiled=True)
        first = jax.lax.axis_index(tpx) * El if ep else 0
        out, aux, z = _grouped_moe(x_local, router, w_gate, w_in, w_out,
                                   cfg, first=first, El=El, Ce=Ce, act=act)
        # (1,)-shaped scalars: pre-0.5 shard_map cannot transpose rank-0
        # outputs that are not constant over the mesh
        return (jax.lax.psum(out, tpx), jnp.reshape(aux, (1,)),
                jnp.reshape(z, (1,)))

    if ep:  # expert weights sharded over the model axis
        wspecs = (P(tpx, fsdp, None), P(tpx, fsdp, None), P(tpx, None, fsdp))
    else:   # TP within each expert (ffn dim sharded)
        wspecs = (P(None, fsdp, tpx), P(None, fsdp, tpx), P(None, tpx, fsdp))

    out, aux, z = _compat.shard_map(
        body, mesh=plan.mesh,
        in_specs=(P(lead, None), P(None, None)) + wspecs,
        out_specs=(P(lead, None), P(None), P(None)),
        check=False,
    )(x2, p["router"], p["w_gate"], p["w_in"], p["w_out"])
    return out.reshape(B, S, D), aux[0], z[0]
