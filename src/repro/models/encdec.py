"""Whisper-style encoder-decoder backbone.

The conv audio frontend is a STUB by assignment: ``input_specs`` supplies
precomputed frame embeddings (B, S_enc, D).  Encoder: bidirectional
attention blocks; decoder: causal self-attention + cross-attention.
Positions are additive sinusoids (Whisper convention), no rope.
Decode caches: per-decoder-layer self KV cache + precomputed cross KV.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShardingPlan
from repro.models import attention as attn
from repro.models import layers as L


def sinusoid(seq: int, d: int, dtype=jnp.float32):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def sinusoid_at(pos, d: int, dtype=jnp.float32):
    """Single (traced) position -> (d,) sinusoid vector."""
    i = jnp.arange(d // 2, dtype=jnp.float32)
    ang = jnp.asarray(pos, jnp.float32) / (10_000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def _enc_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn.attn_init(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "ffn": L.mlp_init(k2, cfg, dtype=dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "self": attn.attn_init(k1, cfg, dtype),
        "ln_x": jnp.zeros((cfg.d_model,), dtype),
        "cross": attn.attn_init(k2, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "ffn": L.mlp_init(k3, cfg, dtype=dtype),
    }


def init_encdec(key, cfg: ModelConfig, dtype=jnp.float32):
    ke, kd, kv = jax.random.split(key, 3)
    enc_keys = jax.random.split(ke, cfg.enc_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": L.embed_init(kv, cfg, dtype),
        "enc": jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype))(enc_keys),
        "dec": jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype))(dec_keys),
        "enc_norm": jnp.zeros((cfg.d_model,), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }


def _self_attn(p, x, positions, cfg, plan, *, causal, cache=None, cap=0.0):
    q, k, v = attn.qkv_proj(p, x, positions, cfg, plan, theta=0.0)
    if cache is not None:
        idx = positions[0]
        new_cache, o = attn.decode_global(cache, q, k, v, idx, cfg, plan, cap)
        return attn.out_proj(p, o, cfg, plan), new_cache
    o = attn.flash_attention(q, k, v, causal=causal, window=0,
                             chunk=cfg.attn_chunk, cap=cap)
    return attn.out_proj(p, o, cfg, plan), None


def _cross_kv(p, enc_out, cfg, plan):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    k, v = attn._repeat_kv(k, v, cfg, plan)
    return k, v


def _cross_attn(p, x, k, v, cfg, plan):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    hspec = attn.head_spec(cfg, plan)
    q = plan.shard(q, plan.dspec(None, hspec, None))
    o = attn.flash_attention(q, k, v, causal=False, window=0,
                             chunk=cfg.attn_chunk, cap=0.0)
    return attn.out_proj(p, o, cfg, plan)


def encode(params, audio_embeds, cfg: ModelConfig, plan: ShardingPlan):
    """audio_embeds: (B, S_enc, D) stub frontend output."""
    B, S, D = audio_embeds.shape
    x = audio_embeds + sinusoid(S, D, audio_embeds.dtype)[None]
    x = plan.shard(x, plan.dspec(None, None))
    positions = jnp.arange(S)

    def body(x, p):
        h, _ = _self_attn(p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                          positions, cfg, plan, causal=False)
        x = x + h
        x = x + L.mlp_apply(p["ffn"], L.rms_norm(x, p["ln2"], cfg.norm_eps),
                            cfg, plan)
        return x, None

    if cfg.remat == "full":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(params, batch, cfg: ModelConfig, plan: ShardingPlan, *,
            mode: str = "train", rwkv_impl: str = "scan",
            return_hidden: bool = False):
    """Teacher-forced encoder-decoder forward.

    batch: {'audio': (B,S_enc,D), 'tokens': (B,S_dec)}.
    """
    enc_out = encode(params, batch["audio"], cfg, plan)
    tok = batch["tokens"]
    B, S = tok.shape
    x = L.embed_apply(params["embed"], tok, cfg, plan)
    x = x + sinusoid(S, cfg.d_model, x.dtype)[None]
    positions = jnp.arange(S)

    def body(x, p):
        h, _ = _self_attn(p["self"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                          positions, cfg, plan, causal=True)
        x = x + h
        kx, vx = _cross_kv(p["cross"], enc_out, cfg, plan)
        x = x + _cross_attn(p["cross"],
                            L.rms_norm(x, p["ln_x"], cfg.norm_eps),
                            kx, vx, cfg, plan)
        x = x + L.mlp_apply(p["ffn"], L.rms_norm(x, p["ln2"], cfg.norm_eps),
                            cfg, plan)
        return x, None

    body_fn = body
    if cfg.remat == "full" and mode == "train":
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body_fn, x, params["dec"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    aux = {"moe_aux": jnp.zeros(()), "moe_z": jnp.zeros(())}
    if return_hidden:
        return x, aux
    logits = L.unembed_apply(params["embed"], x, cfg, plan,
                             apply_softcap=(mode != "train"))
    return logits, aux


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, enc_seq: int,
               plan: ShardingPlan, dtype=jnp.bfloat16):
    """Self KV caches + cross KV (filled by ``prefill_cross``)."""
    e = attn.eff_kv(cfg, plan)

    def one(_):
        return {
            "self": attn.init_global_cache(cfg, batch, max_seq, plan, dtype),
            "xk": jnp.zeros((batch, enc_seq, e, cfg.hd), dtype),
            "xv": jnp.zeros((batch, enc_seq, e, cfg.hd), dtype),
        }

    caches = [one(i) for i in range(cfg.n_layers)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *caches)


def decode_step(params, cache, token, index, cfg: ModelConfig,
                plan: ShardingPlan):
    """One decoder token against self-cache + precomputed cross KV."""
    x = L.embed_apply(params["embed"], token, cfg, plan)
    x = x + sinusoid_at(index, cfg.d_model, x.dtype)[None, None]
    positions = jnp.full((1,), index, jnp.int32)

    def body(x, inp):
        p, c = inp
        h, new_self = _self_attn(
            p["self"], L.rms_norm(x, p["ln1"], cfg.norm_eps), positions,
            cfg, plan, causal=True, cache=c["self"])
        x = x + h
        xq = jnp.einsum("bsd,dhk->bshk",
                        L.rms_norm(x, p["ln_x"], cfg.norm_eps), p["cross"]["wq"])
        valid = jnp.ones((c["xk"].shape[1],), bool)
        o = attn._decode_scores(xq, c["xk"], c["xv"], valid, 0.0)
        x = x + attn.out_proj(p["cross"], o, cfg, plan)
        x = x + L.mlp_apply(p["ffn"], L.rms_norm(x, p["ln2"], cfg.norm_eps),
                            cfg, plan)
        return x, dict(c, self=new_self)

    x, new_cache = jax.lax.scan(body, x, (params["dec"], cache))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], x, cfg, plan)
    return logits, new_cache
