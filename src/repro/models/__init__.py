from repro.models import model
from repro.models.model import (
    decode_step, forward, init, init_cache, lm_loss, param_specs, cache_specs,
)
