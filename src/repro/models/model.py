"""Model dispatch (decoder / encdec), sharding rules and the LM loss."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShardingPlan
from repro.core import _compat
from repro.models import attention as attn_mod
from repro.models import encdec, transformer
from repro.models import moe as moe_mod


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def init(key, cfg: ModelConfig, dtype=jnp.float32):
    if cfg.family == "encdec":
        return encdec.init_encdec(key, cfg, dtype)
    return transformer.init_decoder(key, cfg, dtype)


def forward(params, batch, cfg: ModelConfig, plan: ShardingPlan,
            mode="train", rwkv_impl="scan", return_hidden=False):
    if cfg.family == "encdec":
        return encdec.forward(params, batch, cfg, plan, mode=mode,
                              return_hidden=return_hidden)
    return transformer.forward(params, batch, cfg, plan, mode=mode,
                               rwkv_impl=rwkv_impl,
                               return_hidden=return_hidden)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               plan: ShardingPlan, dtype=jnp.bfloat16, enc_seq: int = 0):
    if cfg.family == "encdec":
        return encdec.init_cache(cfg, batch, max_seq, enc_seq or max_seq,
                                 plan, dtype)
    return transformer.init_cache(cfg, batch, max_seq, plan, dtype)


def decode_step(params, cache, token, index, cfg: ModelConfig,
                plan: ShardingPlan):
    if cfg.family == "encdec":
        return encdec.decode_step(params, cache, token, index, cfg, plan)
    return transformer.decode_step(params, cache, token, index, cfg, plan)


# ---------------------------------------------------------------------------
# sharding rules (params + caches)
# ---------------------------------------------------------------------------


def _leaf_rule(name: str, cfg: ModelConfig, plan: ShardingPlan):
    """Base PartitionSpec per parameter leaf name (unstacked ndim)."""
    tp = plan.tp_axis if plan.tp > 1 else None
    # without a TP axis params MUST be fully sharded (pure-FSDP strategy)
    fsdp = plan.fsdp_axis if (cfg.fsdp or tp is None) else None
    hs = attn_mod.head_spec(cfg, plan)
    kv_ok = (hs is not None and cfg.n_kv_heads % plan.tp == 0)
    kvs = hs if kv_ok else None

    rules = {
        "embedding": P(tp, fsdp),
        "unembed": P(fsdp, tp),
        "wq": P(fsdp, hs, None),
        "wk": P(fsdp, kvs, None),
        "wv": P(fsdp, kvs, None),
        "wo": P(hs, None, fsdp),
        "w_gate": P(fsdp, tp),
        "w_in": P(fsdp, tp),
        "w_out": P(tp, fsdp),
        "router": P(None, None),
        # rwkv time-mix
        "w_r": P(fsdp, tp),
        "w_k": P(fsdp, tp),
        "w_v": P(fsdp, tp),
        "w_g": P(fsdp, tp),
        "w_o": P(tp, fsdp),
        "wa": P(fsdp, None),
        "wb": P(None, tp),
        # rglru
        "w_branch": P(fsdp, tp),
        "w_gate_branch": P(fsdp, tp),
        "w_a": P(fsdp, tp),
        "w_x": P(fsdp, tp),
        "conv_w": P(None, tp),
    }
    return rules.get(name)


def _moe_rule(name: str, cfg, plan):
    tp = plan.tp_axis if plan.tp > 1 else None
    fsdp = plan.fsdp_axis if cfg.fsdp else None
    if moe_mod.use_ep(cfg, plan):
        return {
            "w_gate": P(tp, fsdp, None),
            "w_in": P(tp, fsdp, None),
            "w_out": P(tp, None, fsdp),
        }[name]
    return {
        "w_gate": P(None, fsdp, tp),
        "w_in": P(None, fsdp, tp),
        "w_out": P(None, tp, fsdp),
    }[name]


def param_specs(params, cfg: ModelConfig, plan: ShardingPlan):
    """PartitionSpec pytree matching ``params`` (works on shapes too)."""

    def rule(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        name = names[-1]
        # rwkv channel-mix wk/wv/wr are (D,F)/(F,D)/(D,D) under 'ffn'
        in_ffn = "ffn" in names
        in_moe = cfg.moe is not None and in_ffn and name in (
            "w_gate", "w_in", "w_out")
        if in_moe:
            spec = _moe_rule(name, cfg, plan)
        elif in_ffn and name in ("wk", "wv", "w_r"):  # rwkv channel mix
            tp = plan.tp_axis if plan.tp > 1 else None
            fsdp = plan.fsdp_axis if (cfg.fsdp or tp is None) else None
            spec = {"wk": P(fsdp, tp), "wv": P(tp, fsdp),
                    "w_r": P(fsdp, None)}[name]
        else:
            spec = _leaf_rule(name, cfg, plan)
        if spec is None:
            spec = P()
        ndim = len(leaf.shape)
        pad = ndim - len(spec)
        if pad > 0:  # stacked (scan) leading dims -> replicated
            spec = P(*([None] * pad), *spec)
        elif pad < 0:
            spec = P()
        return _divisibility_guard(spec, leaf.shape, plan)

    return jax.tree_util.tree_map_with_path(rule, params)


def _divisibility_guard(spec: P, shape, plan: ShardingPlan) -> P:
    """Drop mesh axes from dims they don't divide (e.g. whisper's vocab
    51865 on a 16-way model axis -> replicated)."""
    if plan.mesh is None:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for d, p_ in zip(shape, parts):
        if p_ is None:
            out.append(None)
            continue
        axes = (p_,) if isinstance(p_, str) else tuple(p_)
        size = 1
        for a in axes:
            size *= plan.mesh.shape[a]
        out.append(p_ if d % size == 0 else None)
    return P(*out)


def cache_specs(cache, cfg: ModelConfig, plan: ShardingPlan):
    """Cache sharding: batch over dp axes, kv-heads over tp (when even),
    sequence over plan.seq_axes for global caches (long-context decode)."""
    hs = attn_mod.head_spec(cfg, plan)
    e = attn_mod.eff_kv(cfg, plan)
    ehs = hs if (hs is not None and e % plan.tp == 0) else None
    lead = plan.dp_axes if plan.dp_axes else None
    seq = None
    n_seq_shards = 1
    if plan.seq_axes:
        seq = plan.seq_axes if len(plan.seq_axes) > 1 else plan.seq_axes[0]
        for a in plan.seq_axes:
            n_seq_shards *= plan.mesh.shape[a]
        if plan.tp_axis in plan.seq_axes:
            ehs = None  # a mesh axis can appear only once per spec

    def rule(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        name = names[-1]
        ndim = len(leaf.shape)
        if name in ("k", "v", "xk", "xv"):
            # stacked caches have a leading scan dim: the seq dim is ndim-3
            seq_len = leaf.shape[-3]
            s_ok = seq is not None and seq_len % n_seq_shards == 0
            base = P(lead, seq, ehs, None) if s_ok else P(
                lead, None, ehs, None)
        elif name == "pos":
            base = P()
        elif name in ("shift", "cshift", "conv"):
            base = P(lead, None, None)
        elif name == "state":
            base = P(lead, ehs, None, None)
        elif name == "h":
            base = P(lead, None)
        else:
            base = P()
        pad = ndim - len(base)
        if pad > 0:
            base = P(*([None] * pad), *base)
        return _divisibility_guard(base, leaf.shape, plan)

    return jax.tree_util.tree_map_with_path(rule, cache)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def lm_loss(logits, labels, mask, *, z_weight: float = 1e-4,
            plan: Optional[ShardingPlan] = None, final_softcap: float = 0.0,
            chunk: int = 512):
    """Stable CE + z-loss. logits (B,S,V); labels/mask (B,S).

    Memory discipline (these dominate training HBM otherwise):
      * with a TP mesh the vocab dim stays sharded: the label-logit gather
        runs *per vocab shard* under shard_map (out-of-range labels
        contribute zero, psum-combined).  A take_along_axis over the sharded
        dim would make GSPMD all-gather full-vocab f32 logits.
      * the sequence is processed in rematerialized chunks, so only one
        (B, chunk, V/tp) f32 block is ever live (forward and backward);
      * the final logit softcap (gemma2) is applied inside the chunk in f32
        — ``forward(mode='train')`` emits raw logits.
    """
    if plan is not None and plan.mesh is not None and plan.tp_axis:
        return _lm_loss_sharded(logits, labels, mask, plan, z_weight,
                                final_softcap, chunk)
    from repro.models.layers import softcap as _softcap
    lf = _softcap(logits.astype(jnp.float32), final_softcap)
    logz = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    m = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(m), 1.0)
    nll = jnp.sum((logz - ll) * m) / denom
    zloss = jnp.sum(logz * logz * m) / denom
    return nll + z_weight * zloss, {"nll": nll, "zloss": zloss}


def lm_loss_fused(hidden, embed_params, labels, mask, cfg: ModelConfig,
                  plan: ShardingPlan, *, z_weight: float = 1e-4,
                  chunk: int = 512):
    """Fused chunked unembed + CE: full logits are NEVER materialized.

    ``hidden``: final normed hidden states (B, S, D).  Per rematerialized
    sequence chunk we compute the (B, c, V/tp) logits block in f32
    (``preferred_element_type``), reduce it to three scalars and discard it;
    the backward recomputes each block.  This removes the dominant training
    buffers (multiple full-vocab f32 logits tensors survive even a chunked
    post-hoc loss, because XLA hoists the f32 convert out of the loop).
    """
    from repro.models.layers import softcap as _softcap
    tied = cfg.tie_embeddings
    W = embed_params["embedding"] if tied else embed_params["unembed"]
    cap = cfg.final_softcap
    V = cfg.vocab

    def chunk_logits(xc, Wl):
        if tied:  # Wl: (V_loc, D)
            lg = jnp.einsum("bcd,vd->bcv", xc, Wl,
                            preferred_element_type=jnp.float32)
        else:     # Wl: (D, V_loc)
            lg = jnp.einsum("bcd,dv->bcv", xc, Wl,
                            preferred_element_type=jnp.float32)
        return _softcap(lg, cap)

    def run(x, Wl, lb, mk, tpx):
        b, s, _ = x.shape
        c = min(chunk, s)
        n_chunks = -(-s // c)
        pad = n_chunks * c - s
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            lb = jnp.pad(lb, ((0, 0), (0, pad)))
            mk = jnp.pad(mk, ((0, 0), (0, pad)))
        v_loc = Wl.shape[0] if tied else Wl.shape[1]
        off = (jax.lax.axis_index(tpx) * v_loc) if tpx else 0

        def cstep(carry, i):
            nll_a, zl_a, den_a = carry
            xc = jax.lax.dynamic_slice_in_dim(x, i * c, c, 1)
            lbc = jax.lax.dynamic_slice_in_dim(lb, i * c, c, 1)
            mkc = jax.lax.dynamic_slice_in_dim(mk, i * c, c, 1)
            lf = chunk_logits(xc, Wl)
            mx = jnp.max(jax.lax.stop_gradient(lf), -1)
            if tpx:
                mx = jax.lax.pmax(mx, tpx)
            mx = jax.lax.stop_gradient(mx)
            sumexp = jnp.sum(jnp.exp(lf - mx[..., None]), -1)
            if tpx:
                sumexp = jax.lax.psum(sumexp, tpx)
            logz = mx + jnp.log(sumexp)
            loc = lbc.astype(jnp.int32) - off
            inrange = (loc >= 0) & (loc < v_loc)
            ll = jnp.take_along_axis(
                lf, jnp.clip(loc, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
            ll = jnp.where(inrange, ll, 0.0)
            if tpx:
                ll = jax.lax.psum(ll, tpx)
            m = mkc.astype(jnp.float32)
            return (nll_a + jnp.sum((logz - ll) * m),
                    zl_a + jnp.sum(logz * logz * m),
                    den_a + jnp.sum(m)), None

        cstep = jax.checkpoint(
            cstep, policy=jax.checkpoint_policies.nothing_saveable)
        zero = jnp.zeros((), jnp.float32)
        nll, zl, den = _compat.scan_in_shard_map(
            cstep, (zero, zero, zero), n_chunks)
        return nll, zl, den

    if plan.mesh is None:
        nll, zl, den = run(hidden, W, labels, mask, None)
        den = jnp.maximum(den, 1.0)
        nll, zl = nll / den, zl / den
        return nll + z_weight * zl, {"nll": nll, "zloss": zl}

    # vocab not divisible by tp (whisper: 51865) -> replicate the unembed
    tpx = plan.tp_axis if V % plan.tp == 0 else None
    lead = plan.dp_axes if plan.dp_axes else None
    fsdp = plan.fsdp_axis if (cfg.fsdp or plan.tp_axis is None) else None
    if plan.tp_axis is None and plan.mesh is not None:
        # pure-FSDP strategy: without vocab sharding the loss would gather
        # the full unembed AND all-reduce a full f32 embedding gradient
        # (observed 6 x 5.25 GiB on gemma3).  Re-purpose the 'model' axis as
        # vocab parallelism inside the loss region only: batch reshards to
        # the remaining dp axes, W keeps vocab/model + D/data sharding.
        names = plan.mesh.axis_names
        if "model" in names and V % plan.mesh.shape["model"] == 0:
            tpx = "model"
            lead = tuple(a for a in (plan.dp_axes or ()) if a != "model") \
                or None
            fs = plan.fsdp_axis
            if fs is not None:
                fs_t = (fs,) if isinstance(fs, str) else tuple(fs)
                fs2 = tuple(a for a in fs_t if a != "model")
                fsdp = (fs2[0] if len(fs2) == 1 else fs2) if fs2 else None
    wspec = P(tpx, fsdp) if tied else P(fsdp, tpx)

    lead_axes = lead if lead is not None else ()
    lead_axes = (lead_axes,) if isinstance(lead_axes, str) else tuple(
        lead_axes)

    def body(x, Wl, lb, mk):
        if fsdp is not None:
            Wl = jax.lax.all_gather(Wl, fsdp, axis=(1 if tied else 0),
                                    tiled=True)
        nll, zl, den = run(x, Wl, lb, mk, tpx)
        if lead_axes:
            nll = jax.lax.psum(nll, lead_axes)
            zl = jax.lax.psum(zl, lead_axes)
            den = jax.lax.psum(den, lead_axes)
        den = jnp.maximum(den, 1.0)
        # (1,)-shaped outputs: pre-0.5 shard_map cannot transpose rank-0
        # outputs that are not constant over the mesh
        return (nll / den).reshape(1), (zl / den).reshape(1)

    nll, zl = _compat.shard_map(
        body, mesh=plan.mesh,
        in_specs=(P(lead, None, None), wspec, P(lead, None), P(lead, None)),
        out_specs=(P(None), P(None)),
        check=False,
        # f32 labels/mask: pre-0.5 shard_map transposes produce rank-0 zero
        # cotangents for integer operands, tripping the out-spec rank check
    )(hidden, W, labels.astype(jnp.float32), mask.astype(jnp.float32))
    nll, zl = nll[0], zl[0]
    return nll + z_weight * zl, {"nll": nll, "zloss": zl}


def _lm_loss_sharded(logits, labels, mask, plan: ShardingPlan,
                     z_weight: float, final_softcap: float, chunk: int):
    from repro.models.layers import softcap as _softcap
    tpx = plan.tp_axis
    lead = plan.dp_axes if plan.dp_axes else None
    V = logits.shape[-1]
    vshard = V // plan.tp

    def body(lg, lb, mk):
        b, s, _ = lg.shape
        c = min(chunk, s)
        n_chunks = -(-s // c)
        pad = n_chunks * c - s
        if pad:
            lg = jnp.pad(lg, ((0, 0), (0, pad), (0, 0)))
            lb = jnp.pad(lb, ((0, 0), (0, pad)))
            mk = jnp.pad(mk, ((0, 0), (0, pad)))  # pad mask = 0
        off = jax.lax.axis_index(tpx) * vshard

        def cstep(carry, i):
            nll_a, zl_a, den_a = carry
            sl = jax.lax.dynamic_slice_in_dim(lg, i * c, c, 1)
            lbc = jax.lax.dynamic_slice_in_dim(lb, i * c, c, 1)
            mkc = jax.lax.dynamic_slice_in_dim(mk, i * c, c, 1)
            lf = _softcap(sl.astype(jnp.float32), final_softcap)
            # max shift is gradient-neutral; pmax has no VJP
            lmax = jax.lax.stop_gradient(
                jax.lax.pmax(jnp.max(jax.lax.stop_gradient(lf), -1), tpx))
            sumexp = jax.lax.psum(
                jnp.sum(jnp.exp(lf - lmax[..., None]), -1), tpx)
            logz = lmax + jnp.log(sumexp)
            loc = lbc.astype(jnp.int32) - off
            inrange = (loc >= 0) & (loc < vshard)
            ll_loc = jnp.take_along_axis(
                lf, jnp.clip(loc, 0, vshard - 1)[..., None], axis=-1)[..., 0]
            ll = jax.lax.psum(jnp.where(inrange, ll_loc, 0.0), tpx)
            m = mkc.astype(jnp.float32)
            return (nll_a + jnp.sum((logz - ll) * m),
                    zl_a + jnp.sum(logz * logz * m),
                    den_a + jnp.sum(m)), None

        cstep = jax.checkpoint(
            cstep, policy=jax.checkpoint_policies.nothing_saveable)
        zero = jnp.zeros((), jnp.float32)
        nll, zl, den = _compat.scan_in_shard_map(
            cstep, (zero, zero, zero), n_chunks)
        if plan.dp_axes:
            nll = jax.lax.psum(nll, plan.dp_axes)
            zl = jax.lax.psum(zl, plan.dp_axes)
            den = jax.lax.psum(den, plan.dp_axes)
        den = jnp.maximum(den, 1.0)
        # (1,)-shaped outputs: see lm_loss_fused
        return (nll / den).reshape(1), (zl / den).reshape(1)

    nll, zl = _compat.shard_map(
        body, mesh=plan.mesh,
        in_specs=(P(lead, None, tpx), P(lead, None), P(lead, None)),
        out_specs=(P(None), P(None)),
        check=False,
        # f32 labels/mask: see lm_loss_fused
    )(logits, labels.astype(jnp.float32), mask.astype(jnp.float32))
    nll, zl = nll[0], zl[0]
    return nll + z_weight * zl, {"nll": nll, "zloss": zl}
