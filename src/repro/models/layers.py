"""Shared neural building blocks (pure functions over param pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShardingPlan


def _init(key, shape, scale=None, dtype=jnp.float32):
    if scale is None:
        scale = 1.0 / (shape[0] ** 0.5 if len(shape) > 1 else 1.0)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff=None, dtype=jnp.float32):
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _init(k1, (cfg.d_model, d_ff), dtype=dtype),
        "w_in": _init(k2, (cfg.d_model, d_ff), dtype=dtype),
        "w_out": _init(k3, (d_ff, cfg.d_model), dtype=dtype),
    }


def mlp_apply(p, x, cfg: ModelConfig, plan: ShardingPlan):
    tp = plan.tp_axis
    h = act_fn(cfg.act)(x @ p["w_gate"]) * (x @ p["w_in"])
    h = plan.shard(h, plan.dspec(None, tp))
    out = h @ p["w_out"]
    return plan.shard(out, plan.dspec(None, None))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_init(key, cfg: ModelConfig, dtype=jnp.float32):
    p = {"embedding": _init(key, (cfg.vocab, cfg.d_model), scale=1.0,
                            dtype=dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = _init(jax.random.fold_in(key, 1),
                             (cfg.d_model, cfg.vocab), dtype=dtype)
    return p


def embed_apply(p, tokens, cfg: ModelConfig, plan: ShardingPlan):
    x = jnp.take(p["embedding"], tokens, axis=0)
    x = x * jnp.asarray(cfg.d_model, x.dtype) ** 0.5
    return plan.shard(x, plan.dspec(None, None))


def unembed_apply(p, x, cfg: ModelConfig, plan: ShardingPlan,
                  apply_softcap: bool = True):
    if cfg.tie_embeddings:
        logits = x @ p["embedding"].T
    else:
        logits = x @ p["unembed"]
    if apply_softcap:
        # in train mode the softcap is applied inside the (chunked, f32)
        # loss instead — avoids a full-logits tanh buffer
        logits = softcap(logits, cfg.final_softcap)
    return plan.shard(logits, plan.dspec(None, plan.tp_axis))
