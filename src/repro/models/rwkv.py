"""RWKV-6 (Finch) time-mix / channel-mix layers — attention-free mixer with
data-dependent per-channel decay (arXiv:2404.05892).

Two exact time-mix implementations:
  * ``impl='scan'``   — the recurrence as ``lax.scan`` over time (baseline;
    numerically exact for any decay, but latency-bound: O(S) tiny matmuls).
  * ``impl='chunked'``— GLA-style chunked form: within a chunk of C tokens
    the pairwise decay factorizes into bounded per-side exponentials
    (clamped at +/-CLAMP nats; exact whenever the within-chunk decay range
    is below the clamp, which holds for trained RWKV decays |log w| <~ 0.3
    with C=16..64); across chunks the state is carried exactly.  This turns
    the mixer into MXU-friendly (C x C) x (C x hd) matmuls — the §Perf
    hillclimb target for the rwkv cells.

State per layer: shift (B,1,D) token-shift buffer + wkv state (B,H,hd,hd).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShardingPlan
from repro.models.layers import _init, rms_norm

CLAMP = 25.0
LORA_R = 64


def rwkv_init(key, cfg: ModelConfig, dtype=jnp.float32):
    D = cfg.d_model
    H, hd = cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 12)
    return {
        "mu": 0.5 * jnp.ones((5, D), dtype),  # lerp weights: r,k,v,w,g
        "w_r": _init(ks[0], (D, H * hd), dtype=dtype),
        "w_k": _init(ks[1], (D, H * hd), dtype=dtype),
        "w_v": _init(ks[2], (D, H * hd), dtype=dtype),
        "w_g": _init(ks[3], (D, H * hd), dtype=dtype),
        # data-dependent decay: w = exp(-exp(w0 + tanh(x @ wa) @ wb))
        "w0": jnp.full((H * hd,), -1.0, dtype),
        "wa": _init(ks[4], (D, LORA_R), dtype=dtype),
        "wb": _init(ks[5], (LORA_R, H * hd), scale=0.01, dtype=dtype),
        "u": _init(ks[6], (H, hd), scale=0.5, dtype=dtype),
        "ln_scale": jnp.zeros((H * hd,), dtype),
        "w_o": _init(ks[7], (H * hd, D), dtype=dtype),
    }


def chanmix_init(key, cfg: ModelConfig, dtype=jnp.float32):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": 0.5 * jnp.ones((2, D), dtype),  # k, r
        "wk": _init(ks[0], (D, F), dtype=dtype),
        "wv": _init(ks[1], (F, D), dtype=dtype),
        "w_r": _init(ks[2], (D, D), dtype=dtype),
    }


def _shift(x, prev):
    """Token shift: x_{t-1} with ``prev`` filling t=0. x: (B,S,D)."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _lerp(x, xs, mu):
    return x + (xs - x) * mu


def _wkv_scan(r, k, v, logw, u, state):
    """Exact recurrence over time. r,k,v,logw: (B,S,H,hd); state (B,H,hd,hd).
    Returns (o, new_state) with o:(B,S,H,hd).

    o_t = r_t . (S_{t-1} + diag(u) k_t^T v_t);  S_t = diag(w_t) S_{t-1} + k_t^T v_t
    """
    def step(S, inp):
        rt, kt, vt, wt = inp  # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]       # (B,H,hd,hd)
        o = jnp.einsum("bhk,bhkv->bhv", rt, S + u[..., :, None] * kv)
        S = jnp.exp(wt)[..., :, None] * S + kv
        return S, o

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, logw))
    state, o = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(o, 0, 1), state


def _wkv_chunked(r, k, v, logw, u, state, chunk):
    """Chunked GLA form (see module docstring). Exact for moderate decay."""
    B, S, H, hd = r.shape
    C = min(chunk, S)
    pad = (-S) % C
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = (S + pad) // C
    shp = (B, n, C, H, hd)
    r_, k_, v_, lw = (t.reshape(shp) for t in (r, k, v, logw))
    cum = jnp.cumsum(lw, axis=2)          # logP_t (inclusive)
    cum_prev = cum - lw                   # logP_{t-1}
    logPC = cum[:, :, -1:]                # (B,n,1,H,hd)

    # bounded pairwise-decay factorization (clamped)
    r_in = r_ * jnp.exp(jnp.minimum(cum_prev, CLAMP))         # decays from t
    k_in = k_ * jnp.exp(jnp.maximum(-cum, -CLAMP))            # grows to 1/P_i
    att = jnp.einsum("bnthc,bnihc->bnhti", r_in, k_in)        # h=head,c=chan
    att = jnp.tril(jnp.ones((C, C), bool), -1)[None, None, None] * att
    o_intra = jnp.einsum("bnhti,bnihc->bnthc", att, v_)
    # u-bonus diagonal term
    s_diag = jnp.einsum("bnthc,bnthc->bnth", r_ * u[None, None, None], k_)
    o_intra = o_intra + s_diag[..., None] * v_

    # inter-chunk: carry state S across chunks (scan over n)
    r_st = r_ * jnp.exp(cum_prev)                              # for S_0 term
    k_st = k_ * jnp.exp(jnp.maximum(logPC - cum, -CLAMP))      # <= 1
    PC = jnp.exp(logPC[:, :, 0])                               # (B,n,H,hd)

    def step(S, inp):
        rs, ks_, vs, pc = inp  # (B,C,H,hd) x3, (B,H,hd)
        o = jnp.einsum("bthc,bhcv->bthv", rs, S)
        S = pc[..., :, None] * S + jnp.einsum("bthc,bthv->bhcv", ks_, vs)
        return S, o

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r_st, k_st, v_, PC))
    state, o_inter = jax.lax.scan(step, state, xs)
    o = o_intra + jnp.moveaxis(o_inter, 0, 1)
    o = o.reshape(B, n * C, H, hd)[:, :S]
    return o, state


def rwkv_apply(p, x, cfg: ModelConfig, plan: ShardingPlan, cache=None,
               impl: str = "scan"):
    """Time-mix. x: (B,S,D). cache: {'shift': (B,1,D), 'state': (B,H,hd,hd)}
    or None (training: zeros).  Returns (out, new_cache)."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.hd
    hspec = plan.tp_axis if (H % plan.tp == 0 and plan.tp > 1) else None
    prev = cache["shift"] if cache else jnp.zeros((B, 1, D), x.dtype)
    state = (cache["state"] if cache
             else jnp.zeros((B, H, hd, hd), jnp.float32))
    xs = _shift(x, prev)
    mu = p["mu"]
    xr, xk, xv, xw, xg = (_lerp(x, xs, mu[i]) for i in range(5))
    r = (xr @ p["w_r"]).reshape(B, S, H, hd)
    k = (xk @ p["w_k"]).reshape(B, S, H, hd)
    v = (xv @ p["w_v"]).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ p["w_g"])
    w_raw = p["w0"] + jnp.tanh(xw @ p["wa"]) @ p["wb"]
    logw = -jnp.exp(w_raw.astype(jnp.float32)).reshape(B, S, H, hd)
    r = plan.shard(r, plan.dspec(None, hspec, None))
    k = plan.shard(k, plan.dspec(None, hspec, None))
    v = plan.shard(v, plan.dspec(None, hspec, None))

    u = p["u"].astype(jnp.float32)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    if S == 1:  # decode fast path: one recurrence step
        o, state = _wkv_scan(rf, kf, vf, logw, u, state)
    elif impl == "chunked":
        o, state = _wkv_chunked(rf, kf, vf, logw, u, state, cfg.rwkv_chunk)
    else:
        o, state = _wkv_scan(rf, kf, vf, logw, u, state)

    o = o.reshape(B, S, H * hd)
    # per-head group norm
    o = rms_norm(o.reshape(B, S, H, hd),
                 p["ln_scale"].reshape(H, hd), cfg.norm_eps).reshape(
        B, S, H * hd)
    out = (o.astype(x.dtype) * g) @ p["w_o"]
    out = plan.shard(out, plan.dspec(None, None))
    new_cache = {"shift": x[:, -1:], "state": state}
    return out, new_cache


def chanmix_apply(p, x, cfg: ModelConfig, plan: ShardingPlan, cache=None):
    """Channel-mix (squared-relu FFN with token shift)."""
    B, S, D = x.shape
    prev = cache["shift"] if cache else jnp.zeros((B, 1, D), x.dtype)
    xs = _shift(x, prev)
    xk = _lerp(x, xs, p["mu"][0])
    xr = _lerp(x, xs, p["mu"][1])
    h = jnp.square(jax.nn.relu(xk @ p["wk"]))
    h = plan.shard(h, plan.dspec(None, plan.tp_axis))
    out = jax.nn.sigmoid(xr @ p["w_r"]) * (h @ p["wv"])
    out = plan.shard(out, plan.dspec(None, None))
    return out, {"shift": x[:, -1:]}
