"""Drifting-stream re-selection: carry a warm-start Prior across ticks.

A stream that re-selects the same order statistic on slowly drifting data
(sliding windows, sensor feeds, solver loops outside ``robust.py``) pays a
cold full-range bracket descent every tick if each call starts fresh.
:func:`reselect` and :class:`QuantileTracker` thread the warm-start carry
(:class:`repro.core.selection.Prior`) from each tick's result into the
next tick's call: when the answer moved little between ticks, the prior
edge ladder resolves the new selection in ONE binned sweep (the
``prev_float(value)``/``value`` collapse pair certifies an unchanged
answer immediately).  The prior only steers edge placement — a tick whose
data jumped arbitrarily, or a stale/garbage prior, costs extra sweeps,
never exactness (see the Prior docstring for the soundness contract).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import selection


def reselect(x, k, *, prior=None, weights=None, **kw):
    """One warm tick: select the k-th order statistic of ``x`` (or, with
    ``weights``, the smallest element whose cumulative weight reaches
    ``k``) seeded by ``prior``, and return ``(result, next_prior)``.

    ``prior`` accepts anything :func:`selection.as_prior` does — the
    previous tick's :class:`~repro.core.selection.SelectResult`, a
    :class:`~repro.core.selection.Prior`, or a bare scalar guess; ``None``
    is a cold start.  Feed the returned ``next_prior`` into the next tick::

        res, pr = reselect(x0, k)            # cold
        res, pr = reselect(x1, k, prior=pr)  # warm: 1 sweep if drift small

    Extra keyword arguments (``method=``, ``nbins=``, ...) pass through to
    the underlying selection call.
    """
    if weights is None:
        res = selection.order_statistic(x, k, prior=prior, **kw)
    else:
        res = selection.weighted_order_statistic(x, weights, k,
                                                 prior=prior, **kw)
    return res, selection.as_prior(res)


class QuantileTracker:
    """Stateful quantile tracker over a drifting stream.

    Each :meth:`update` re-selects the q-quantile of the new batch, warm-
    started from the previous tick's realized bracket; the carry lives on
    the tracker, so callers just feed batches::

        t = QuantileTracker(0.5, method="binned")
        for batch in stream:
            med = t.update(batch).value

    ``sweeps`` records the per-tick bracket-sweep counts (host ints) —
    the steady-state value on a slow-drifting stream is 1.  The tracker
    never affects exactness: every tick's value is bit-identical to a
    cold ``selection.quantile`` call on the same batch.
    """

    def __init__(self, q: float = 0.5, *, weighted: bool = False, **kw):
        self.q = q
        self.weighted = weighted
        self.kw = kw
        self.prior: Optional[selection.Prior] = None
        self.sweeps: list = []

    def update(self, x, weights=None) -> selection.SelectResult:
        """Re-select on a new batch; returns the exact SelectResult."""
        x = jnp.asarray(x).reshape(-1)
        if self.weighted or weights is not None:
            w = (jnp.ones_like(x) if weights is None
                 else jnp.asarray(weights).reshape(-1))
            res = selection.weighted_quantile(x, w, self.q,
                                              prior=self.prior, **self.kw)
        else:
            res = selection.quantile(x, self.q, prior=self.prior, **self.kw)
        self.prior = selection.as_prior(res)
        self.sweeps.append(int(res.iters))
        return res

    def reset(self) -> None:
        """Drop the carry (next update is a cold start)."""
        self.prior = None
        self.sweeps.clear()


__all__ = ["reselect", "QuantileTracker"]
