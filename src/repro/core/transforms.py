"""Monotone transform guard for extreme-valued data (paper Sec. V-D).

Order statistics are invariant under strictly increasing maps.  For data with
components of order 1e20, summation in (1) loses the small terms; the paper
applies ``F(t) = log(1 + t - x_(1))`` and selects in the transformed domain.
We run the *iterations* on ``F(x)`` and the exact finalize on the original
values (bracket mapped back and widened by one ulp on each side).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def next_float(y):
    return jnp.nextafter(y, jnp.asarray(jnp.inf, y.dtype))


def prev_float(y):
    return jnp.nextafter(y, jnp.asarray(-jnp.inf, y.dtype))


def log1p_transform(x: jax.Array):
    """Returns (F(x), F_inverse). F(t) = log1p(t - min(x)) — strictly
    increasing on [min(x), inf), maps the data into a well-conditioned range.
    """
    x0 = jnp.min(x)

    def inverse(y):
        return jnp.expm1(y) + x0

    return jnp.log1p(x - x0), inverse


def log1p_transform_rows(x: jax.Array) -> jax.Array:
    """Row-wise monotone guard for the batched engine: ``x`` is (B, n) and
    each row gets its own anchor ``F_i(t) = log1p(t - min(x_i))``.  Only the
    forward image is needed — the batched finalize maps brackets back by
    count-preserving preimage reductions, never by the float inverse (see
    ``selection._map_bracket_back_rows``)."""
    return jnp.log1p(x - jnp.min(x, axis=1, keepdims=True))
