"""Convex selection objective of Beliakov (2011), Eqs. (1)-(2).

The k-th smallest element of ``x`` (1-indexed) is the minimizer of the
piecewise-linear convex function

    f(y) = (1/n) * sum_i u(x_i - y),
    u(t) = beta * t        if t >= 0          (x_i above y)
         = -alpha * t      if t <  0          (x_i below y)

with ``alpha = (n - k + 1/2)/n`` and ``beta = (k - 1/2)/n``.  The kink of the
one-sided derivatives crosses zero at ``count(x < y) = k - 1/2``, i.e. exactly
at ``x_(k)``.

NOTE (paper erratum): the paper's Eq. (2) swaps alpha/beta relative to its
stated "k-th smallest" convention; as printed it selects the k-th *largest*.
We use the corrected weights above and validate against ``np.partition``.

The Clarke subdifferential at ``y`` is the interval ``[g_lo, g_hi]`` with

    g_lo(y) = alpha * n_lt - beta * (n - n_lt)      # left  derivative
    g_hi(y) = alpha * n_le - beta * (n - n_le)      # right derivative

where ``n_lt = count(x < y)`` and ``n_le = count(x <= y)``.  Crucially

    0 in [g_lo, g_hi]  <=>  n_lt < k <= n_le  <=>  y == x_(k) (exact hit),

so the counts both drive the optimizer *and* certify exactness.  Everything
in this module is a single fused read-only pass over ``x`` (the paper's
``transform_reduce``), which is what makes the method shard-friendly: partial
``(sum_pos, sum_neg, n_lt, n_le)`` quadruples combine additively across
devices (psum of four scalars).

Evaluator contract (the batched-first engine's only data interface)
-------------------------------------------------------------------
The selection engine in :mod:`repro.core.selection` never touches the data
directly; it talks to an :class:`Evaluator`, which owns the data layout and
answers one question per iteration:

    evaluator(y: (B,) pivots) -> FG with (B,) fields

plus the initial statistics ``init_stats() -> (xmin, xmax, xmean)`` (each
``(B,)``) and the static attributes ``n`` (elements per problem, ``(B,)`` or
scalar) and ``k`` (target ranks, ``(B,)``).  Anything that can produce the
four additive partials per pivot is a valid evaluator:

* :class:`RowsEvaluator`    — ``(B, n)`` rows, per-row pivot (independent
  problems: coordinate-wise medians, per-start LMS/LTS criteria, kNN rows);
* :class:`SharedEvaluator`  — ONE array, ``(K,)`` pivots (quantile sets /
  ``multi_order_statistic``); backed by the multi-pivot Pallas kernel that
  reads each ``x`` tile into VMEM once and emits partials for all K pivots;
* :class:`ShardedEvaluator` — the data lives sharded across a mesh axis; the
  local fused pass is combined by a ``psum`` of the four partials (the
  paper's multi-GPU combine, see :mod:`repro.core.distributed`).

Scalar selection is just the ``B=1`` view of the rows regime.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Protocol

import jax
import jax.numpy as jnp


class FG(NamedTuple):
    """Objective value, subdifferential interval and counts at a pivot."""

    f: jax.Array      # objective value (normalized by n)
    g_lo: jax.Array   # left one-sided derivative
    g_hi: jax.Array   # right one-sided derivative
    n_lt: jax.Array   # count(x <  y), int32
    n_le: jax.Array   # count(x <= y), int32


def os_weights(n, k, dtype=jnp.float32):
    """Normalized slope weights (alpha: below-pivot, beta: above-pivot)."""
    n = jnp.asarray(n, dtype)
    k = jnp.asarray(k, dtype)
    alpha = (n - k + 0.5) / n
    beta = (k - 0.5) / n
    return alpha, beta


def eval_partials(x: jax.Array, y: jax.Array):
    """One fused pass: (sum of (x-y)+, sum of (y-x)+, n_lt, n_le).

    These four partials are additive over shards/blocks; every selection
    method in :mod:`repro.core.selection` is built from them.
    """
    x = x.reshape(-1)
    d = x - y
    sum_pos = jnp.sum(jnp.maximum(d, 0), dtype=x.dtype)
    sum_neg = jnp.sum(jnp.maximum(-d, 0), dtype=x.dtype)
    n_lt = jnp.sum(d < 0, dtype=jnp.int32)
    n_le = jnp.sum(d <= 0, dtype=jnp.int32)
    return sum_pos, sum_neg, n_lt, n_le


def fg_from_partials(partials, n, k) -> FG:
    """Combine additive partials into the FG quintuple."""
    sum_pos, sum_neg, n_lt, n_le = partials
    alpha, beta = os_weights(n, k, sum_pos.dtype)
    nf = jnp.asarray(n, sum_pos.dtype)
    f = (beta * sum_pos + alpha * sum_neg) / nf
    n_ltf = n_lt.astype(sum_pos.dtype)
    n_lef = n_le.astype(sum_pos.dtype)
    # one-sided derivatives: at x==y the term switches branch, so the left
    # derivative counts ties as "above" and the right derivative as "below".
    g_lo = alpha * n_ltf / nf - beta * (nf - n_ltf) / nf
    g_hi = alpha * n_lef / nf - beta * (nf - n_lef) / nf
    return FG(f=f, g_lo=g_lo, g_hi=g_hi, n_lt=n_lt, n_le=n_le)


def eval_fg(x: jax.Array, y: jax.Array, k) -> FG:
    """Objective + subdifferential + counts at pivot ``y`` (single pass)."""
    return fg_from_partials(eval_partials(x, y), x.size, k)


def eval_fg_batched(x: jax.Array, y: jax.Array, k) -> FG:
    """Row-wise variant: ``x`` is (B, n), ``y``/``k`` are (B,)."""
    b_eval = jax.vmap(lambda xi, yi, ki: eval_fg(xi, yi, ki))
    return b_eval(x, y, jnp.broadcast_to(jnp.asarray(k), (x.shape[0],)))


# ---------------------------------------------------------------------------
# Evaluator abstraction — the batched-first engine's data interface
# ---------------------------------------------------------------------------


class Evaluator(Protocol):
    """Batched pivot evaluation: pivots ``(B,)`` -> :class:`FG` with ``(B,)``
    fields.  ``n`` is the per-problem element count (``(B,)`` or scalar),
    ``k`` the 1-indexed target ranks ``(B,)``.  ``init_stats`` returns
    per-problem ``(min, max, mean)`` — one extra fused pass, used to seat the
    initial bracket and cutting planes analytically.

    ``histogram`` is the binned data pass behind ``method='binned'``: per
    problem, bin the data against the caller-supplied REALIZED bracket
    edges ``(B, nbins + 1)`` (built once per sweep by the engine via
    ``kernels.ref.bin_edges`` — implementations must only COMPARE against
    them, never recompute edge arithmetic) and return additive
    ``(count, sum)`` slot vectors of shape ``(B, nbins + 2)`` (slot layout
    documented in ``kernels.ref.cp_histogram_ref``).  One sweep narrows
    every live bracket by a factor of ``nbins`` — log2(nbins)
    bisection-equivalents per data pass — and, like the FG quadruple, the
    slot vectors combine additively across blocks/shards (a psum of
    ``nbins + 2`` ints per problem is the whole multi-device story).  The
    engine only reads the counts; implementations whose transport makes
    the sums costly (the distributed evaluators) may return ``None`` in
    their place."""

    n: jax.Array
    k: jax.Array

    def __call__(self, y: jax.Array) -> FG: ...

    def init_stats(self) -> tuple[jax.Array, jax.Array, jax.Array]: ...

    def histogram(self, edges: jax.Array) -> tuple[jax.Array, jax.Array]: ...


class RowsEvaluator:
    """Independent rows: ``x`` is (B, n), one pivot and one ``k`` per row.

    The data pass is ``kernels.ops.fused_partials_batched`` (Pallas on TPU,
    fused jnp elsewhere, Pallas-interpret for kernel validation on CPU).
    """

    def __init__(self, x: jax.Array, k, *, backend: str | None = None):
        from repro.kernels import ops as kops  # deferred: core <-> kernels

        self._kops = kops
        self._backend = backend
        self._partials = lambda y: kops.fused_partials_batched(
            x, y, backend=backend)
        self.x = x
        self.n = jnp.asarray(x.shape[1], jnp.int32)
        self.k = jnp.broadcast_to(
            jnp.clip(jnp.asarray(k, jnp.int32), 1, x.shape[1]), (x.shape[0],))

    def __call__(self, y: jax.Array) -> FG:
        return fg_from_partials(self._partials(y), self.n, self.k)

    def histogram(self, edges):
        return self._kops.fused_histogram_batched(
            self.x, edges, backend=self._backend)

    def init_stats(self):
        x = self.x
        return (jnp.min(x, axis=1), jnp.max(x, axis=1),
                jnp.mean(x, axis=1, dtype=x.dtype))


class SharedEvaluator:
    """One shared array, K live pivots (``multi_order_statistic``).

    The data pass is ``kernels.ops.fused_partials_multi``: the multi-pivot
    Pallas kernel reads each ``x`` tile into VMEM once and emits partials
    for all K pivots — K× less HBM traffic than K independent passes.
    """

    def __init__(self, x: jax.Array, ks, *, backend: str | None = None):
        from repro.kernels import ops as kops  # deferred: core <-> kernels

        self._kops = kops
        self._backend = backend
        self.x = x = x.reshape(-1)
        self._partials = lambda y: kops.fused_partials_multi(
            x, y, backend=backend)
        self.n = jnp.asarray(x.size, jnp.int32)
        self.k = jnp.clip(jnp.asarray(ks, jnp.int32).reshape(-1), 1, x.size)

    def __call__(self, y: jax.Array) -> FG:
        return fg_from_partials(self._partials(y), self.n, self.k)

    def histogram(self, edges):
        return self._kops.fused_histogram_multi(
            self.x, edges, backend=self._backend)

    def init_stats(self):
        x, b = self.x, self.k.shape[0]
        bc = lambda v: jnp.broadcast_to(v, (b,))
        return (bc(jnp.min(x)), bc(jnp.max(x)),
                bc(jnp.mean(x, dtype=x.dtype)))


class ShardedEvaluator:
    """Data sharded over mesh axis/axes: local fused pass + psum combine.

    ``B = 1`` view (scalar pivot broadcast from the engine's (1,) state) —
    the psum of the four additive partials IS the cross-device combine; no
    data moves.  Must be constructed inside ``shard_map``.
    """

    def __init__(self, x_local: jax.Array, k, axes, *,
                 backend: str | None = None):
        from repro.kernels import ops as kops  # deferred: core <-> kernels

        self.x_local = x_local = x_local.reshape(-1)
        self.axes = axes = (axes,) if isinstance(axes, str) else tuple(axes)
        self._kops = kops
        self._backend = backend
        self._partials1 = lambda y: kops.fused_partials(
            x_local, y, backend=backend)
        self.n = jax.lax.psum(jnp.asarray(x_local.size, jnp.int32), axes)
        self.k = jnp.clip(jnp.asarray(k, jnp.int32), 1, self.n)

    def __call__(self, y: jax.Array) -> FG:
        return self.combine(self._partials1(y))

    def local_histogram(self, edges):
        """This shard's un-psum'd slot vectors (shape ``(nbins + 2,)``) —
        the binned analogue of :meth:`local_partials`; the distributed
        binned loop bounds the PER-SHARD in-bracket count from these."""
        return self._kops.fused_histogram(
            self.x_local, edges, backend=self._backend)

    def histogram(self, edges):
        """Binned pass over the GLOBAL array: local histogram + one psum of
        the ``(nbins + 2,)`` count vector — additive across shards exactly
        like the FG quadruple (B = 1 view: ``(nbins + 1,)`` edges).  The
        per-bin sums are returned un-psum'd as ``None``: the binned engine
        never reads them, and psumming them would double the wire bytes."""
        cnt, _bsum = self.local_histogram(edges)
        return jax.lax.psum(cnt, self.axes), None

    def local_partials(self, y: jax.Array):
        """This shard's un-psum'd quadruple (for shard-local bookkeeping —
        the distributed hybrid finalize bounds the PER-SHARD in-bracket
        count, see ``distributed.local_order_statistic``)."""
        return self._partials1(y)

    def combine(self, partials) -> FG:
        """The cross-device combine IS a psum of the four additive partials
        (the paper's "partial sums from several GPUs are added")."""
        sp, sn, lt, le = partials
        fsum = jax.lax.psum(jnp.stack([sp, sn]), self.axes)
        csum = jax.lax.psum(jnp.stack([lt, le]), self.axes)
        return fg_from_partials((fsum[0], fsum[1], csum[0], csum[1]),
                                self.n, self.k)

    def init_stats(self):
        x, axes = self.x_local, self.axes
        xsum = jax.lax.psum(jnp.sum(x, dtype=x.dtype), axes)
        return (jax.lax.pmin(jnp.min(x), axes),
                jax.lax.pmax(jnp.max(x), axes),
                xsum / self.n.astype(x.dtype))


class FnEvaluator:
    """Adapter: wrap a raw ``partials(y) -> (sp, sn, lt, le)`` closure (all
    fields ``(B,)``-shaped) as an :class:`Evaluator`.  Used by the
    distributed across-axis solver, where the combine is a per-coordinate
    psum, and by tests that drive the engine through a custom backend.

    ``histogram(edges) -> (cnt, bsum)`` (edges ``(B, nbins + 1)``, outputs
    ``(B, nbins + 2)``) is optional; without it the evaluator only drives
    the FG methods."""

    def __init__(self, partials: Callable, n, k, init_stats: Callable,
                 histogram: Optional[Callable] = None):
        self._partials = partials
        self.n = n
        self.k = k
        self._init_stats = init_stats
        self._histogram = histogram

    def __call__(self, y: jax.Array) -> FG:
        return fg_from_partials(self._partials(y), self.n, self.k)

    def histogram(self, edges):
        if self._histogram is None:
            raise NotImplementedError(
                "this FnEvaluator was built without a histogram closure; "
                "method='binned' needs one")
        return self._histogram(edges)

    def init_stats(self):
        return self._init_stats()
