"""Convex selection objective of Beliakov (2011), Eqs. (1)-(2).

The k-th smallest element of ``x`` (1-indexed) is the minimizer of the
piecewise-linear convex function

    f(y) = (1/n) * sum_i u(x_i - y),
    u(t) = beta * t        if t >= 0          (x_i above y)
         = -alpha * t      if t <  0          (x_i below y)

with ``alpha = (n - k + 1/2)/n`` and ``beta = (k - 1/2)/n``.  The kink of the
one-sided derivatives crosses zero at ``count(x < y) = k - 1/2``, i.e. exactly
at ``x_(k)``.

NOTE (paper erratum): the paper's Eq. (2) swaps alpha/beta relative to its
stated "k-th smallest" convention; as printed it selects the k-th *largest*.
We use the corrected weights above and validate against ``np.partition``.

The Clarke subdifferential at ``y`` is the interval ``[g_lo, g_hi]`` with

    g_lo(y) = alpha * n_lt - beta * (n - n_lt)      # left  derivative
    g_hi(y) = alpha * n_le - beta * (n - n_le)      # right derivative

where ``n_lt = count(x < y)`` and ``n_le = count(x <= y)``.  Crucially

    0 in [g_lo, g_hi]  <=>  n_lt < k <= n_le  <=>  y == x_(k) (exact hit),

so the counts both drive the optimizer *and* certify exactness.  Everything
in this module is a single fused read-only pass over ``x`` (the paper's
``transform_reduce``), which is what makes the method shard-friendly: the
partial sums combine additively across devices (one psum per iteration).

The Measure abstraction (one engine for counts and weights)
-----------------------------------------------------------
Selection and *weighted* selection are the same convex program under two
measures on the data:

* the **counting measure** — every element has mass 1, the target is the
  integer rank ``k``, and every mass comparison is an exact int32
  comparison;
* a **weight measure** ``w_i >= 0`` — the target is a cumulative mass
  ``wk`` (the minimizer of ``F_w(y) = sum_i w_i * rho(x_i - y)``), and
  masses accumulate in floating point.

Every pivot evaluation therefore returns ONE partials type, :class:`FG`,
carrying the measure below / at-or-below the pivot (``m_lt`` / ``m_le`` —
int32 counts on the counting path, fp masses on the weighted path) next to
the integer element counts ``n_lt`` / ``n_le`` that always ride along
(buffer capacity is an element count, so the engine's cap-based stopping
rule is measure-independent).  The engine's move / exact-hit decisions
compare ``m_*`` against the target measure ``k``:

    m_lt(y) < k <= m_le(y)   <=>   y is the (weighted) order statistic

(on the weighted path ``m_lt < m_le`` forces positive mass AT ``y``, so a
certified pivot is a data element).  Uniform weights with ``wk = k`` make
every mass comparison an exact integer-valued comparison, reproducing the
counting path bit for bit — counts are the exact-measure specialization,
not a separate engine.  The counting path stays on the four-partial kernels
(``m_*`` aliases ``n_*``; no weights array is read from HBM).

Evaluator contract (the batched-first engine's only data interface)
-------------------------------------------------------------------
The selection engine in :mod:`repro.core.selection` never touches the data
directly; it talks to an :class:`Evaluator`, which owns the data layout and
answers one question per iteration:

    evaluator(y: (B,) pivots) -> FG with (B,) fields

plus the initial statistics ``init_stats() -> (xmin, xmax, mean)`` (each
``(B,)``; the mean is mass-weighted on the weighted path) and the static
attributes ``n`` (elements per problem), ``k`` (target measure: int32 ranks
or fp masses, ``(B,)``) and ``weighted`` (which leg the evaluator runs).
Anything that can produce the additive partials per pivot is a valid
evaluator:

* :class:`RowsEvaluator`    — ``(B, n)`` rows, per-row pivot (independent
  problems: coordinate-wise medians, per-start LMS/LTS criteria, kNN rows);
* :class:`SharedEvaluator`  — ONE array, ``(K,)`` pivots (quantile sets /
  ``multi_order_statistic``); backed by the multi-pivot Pallas kernel that
  reads each ``x`` tile into VMEM once and emits partials for all K pivots;
* :class:`ShardedEvaluator` — the data lives sharded across a mesh axis; the
  local fused pass is combined by a ``psum`` of the additive partials (the
  paper's multi-GPU combine, see :mod:`repro.core.distributed`).

Scalar selection is just the ``B=1`` view of the rows regime.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Protocol

import jax
import jax.numpy as jnp


class FG(NamedTuple):
    """Objective value, subdifferential interval and measure at a pivot.

    The single partials type of the unified engine: ``m_lt`` / ``m_le``
    carry the MEASURE below / at-or-below the pivot — int32 counts on the
    counting path (where they alias ``n_lt`` / ``n_le``), fp weight masses
    on the weighted path — and drive every move / exact-hit decision.  The
    int32 element counts ``n_lt`` / ``n_le`` always ride along: buffer
    capacity is an element count, so the cap-based stopping rule reads them
    on both legs.
    """

    f: jax.Array      # objective value (normalized by the total measure)
    g_lo: jax.Array   # left one-sided derivative
    g_hi: jax.Array   # right one-sided derivative
    m_lt: jax.Array   # measure(x <  y) — drives narrowing + certificates
    m_le: jax.Array   # measure(x <= y)
    n_lt: jax.Array   # count(x <  y), int32 — drives the cap stopping rule
    n_le: jax.Array   # count(x <= y), int32


# Backwards-compatible alias: the weighted septuple IS the unified type.
WFG = FG


def os_weights(n, k, dtype=jnp.float32):
    """Normalized slope weights (alpha: below-pivot, beta: above-pivot)."""
    n = jnp.asarray(n, dtype)
    k = jnp.asarray(k, dtype)
    alpha = (n - k + 0.5) / n
    beta = (k - 0.5) / n
    return alpha, beta


def eval_partials(x: jax.Array, y: jax.Array):
    """One fused pass: (sum of (x-y)+, sum of (y-x)+, n_lt, n_le).

    These four partials are additive over shards/blocks; every selection
    method in :mod:`repro.core.selection` is built from them.
    """
    x = x.reshape(-1)
    d = x - y
    sum_pos = jnp.sum(jnp.maximum(d, 0), dtype=x.dtype)
    sum_neg = jnp.sum(jnp.maximum(-d, 0), dtype=x.dtype)
    n_lt = jnp.sum(d < 0, dtype=jnp.int32)
    n_le = jnp.sum(d <= 0, dtype=jnp.int32)
    return sum_pos, sum_neg, n_lt, n_le


def fg_from_partials(partials, n, k) -> FG:
    """Combine the four counting-measure partials into the unified FG.

    The measure fields alias the integer counts (counts ARE the measure on
    this leg), so every downstream mass comparison is an exact int32
    comparison.
    """
    sum_pos, sum_neg, n_lt, n_le = partials
    alpha, beta = os_weights(n, k, sum_pos.dtype)
    nf = jnp.asarray(n, sum_pos.dtype)
    f = (beta * sum_pos + alpha * sum_neg) / nf
    n_ltf = n_lt.astype(sum_pos.dtype)
    n_lef = n_le.astype(sum_pos.dtype)
    # one-sided derivatives: at x==y the term switches branch, so the left
    # derivative counts ties as "above" and the right derivative as "below".
    g_lo = alpha * n_ltf / nf - beta * (nf - n_ltf) / nf
    g_hi = alpha * n_lef / nf - beta * (nf - n_lef) / nf
    return FG(f=f, g_lo=g_lo, g_hi=g_hi, m_lt=n_lt, m_le=n_le,
              n_lt=n_lt, n_le=n_le)


def eval_fg(x: jax.Array, y: jax.Array, k) -> FG:
    """Objective + subdifferential + counts at pivot ``y`` (single pass)."""
    return fg_from_partials(eval_partials(x, y), x.size, k)


def eval_fg_batched(x: jax.Array, y: jax.Array, k) -> FG:
    """Row-wise variant: ``x`` is (B, n), ``y``/``k`` are (B,)."""
    b_eval = jax.vmap(lambda xi, yi, ki: eval_fg(xi, yi, ki))
    return b_eval(x, y, jnp.broadcast_to(jnp.asarray(k), (x.shape[0],)))


def wfg_from_partials(partials, W, wk) -> FG:
    """Combine the six weight-measure partials into the unified FG.

    Choosing the slopes ``alpha = (W - wk)/W`` and ``beta = wk/W`` puts the
    subdifferential zero-crossing of ``F_w(y) = sum_i w_i * rho(x_i - y)``
    exactly at mass ``wk``, and the normalized one-sided derivatives
    collapse to ``g_lo = (W_lt - wk)/W`` and ``g_hi = (W_le - wk)/W``.
    """
    wsum_pos, wsum_neg, w_lt, w_le, n_lt, n_le = partials
    dt = wsum_pos.dtype
    Wf = jnp.asarray(W, dt)
    wkf = jnp.asarray(wk, dt)
    alpha = (Wf - wkf) / Wf
    beta = wkf / Wf
    f = (beta * wsum_pos + alpha * wsum_neg) / Wf
    g_lo = (w_lt - wkf) / Wf
    g_hi = (w_le - wkf) / Wf
    return FG(f=f, g_lo=g_lo, g_hi=g_hi, m_lt=w_lt, m_le=w_le,
              n_lt=n_lt, n_le=n_le)


# ---------------------------------------------------------------------------
# Evaluator abstraction — the batched-first engine's data interface
# ---------------------------------------------------------------------------


class Evaluator(Protocol):
    """Batched pivot evaluation: pivots ``(B,)`` -> :class:`FG` with ``(B,)``
    fields.  ``n`` is the per-problem element count (``(B,)`` or scalar),
    ``k`` the target measure ``(B,)`` — 1-indexed int32 ranks on the
    counting leg, fp cumulative masses on the weighted leg (``weighted``
    says which).  ``init_stats`` returns per-problem ``(min, max, mean)`` —
    one extra fused pass, used to seat the initial bracket and cutting
    planes analytically (the mean is mass-weighted on the weighted leg).

    ``histogram`` is the binned data pass behind ``method='binned'``: per
    problem, bin the data against the caller-supplied REALIZED bracket
    edges ``(B, nbins + 1)`` (built once per sweep by the engine via
    ``kernels.ref.bin_edges`` — implementations must only COMPARE against
    them, never recompute edge arithmetic; the verified arithmetic slotting
    honors this by checking its candidate against that same array) and
    return additive ``(cnt, mass, msum)`` slot vectors of shape
    ``(B, nbins + 2)`` (slot layout documented in
    ``kernels.ref.searchsorted_slots``):

    * ``cnt``  — int32 element counts (feed the cap-based stopping rule);
    * ``mass`` — the per-slot measure (the narrowing signal; on the
      counting leg this IS ``cnt``, returned aliased — no extra compute);
    * ``msum`` — per-slot ``sum(w_i * x_i)`` (``sum(x_i)`` on the counting
      leg) — the in-bin CP-polish ingredient, DEMAND-DRIVEN: the engine
      passes ``need_msum=True`` only on polish sweeps, and implementations
      may return ``None`` whenever it is False (the jnp arithmetic pass
      skips the sums entirely; the distributed evaluators skip their wire
      bytes).  An implementation that cannot produce sums at all simply
      always returns ``None`` — such evaluators cannot drive the polish.

    One sweep narrows every live bracket by a factor of ``nbins`` —
    log2(nbins) bisection-equivalents per data pass — and, like the FG
    partials, the slot vectors combine additively across blocks/shards (a
    psum of ``nbins + 2`` scalars per problem is the whole multi-device
    story).

    Edge-geometry contract: the engine owns edge PLACEMENT, and it is not
    always uniform — polish sweeps splice a CP cut into the ladder, and
    warm-started sweeps (``prior=``, see ``selection.prior_edges``) lay a
    sharp collapse pair + geometric ladder around the carried answer.
    Implementations must therefore accept ANY sorted, endpoint-pinned,
    possibly-duplicated edge array (duplicates yield legitimate empty
    slots) and bin by comparison against it — never by assuming equal
    widths.  The verified-arithmetic fast path already honors this: its
    width-based slot guess is checked against the realized edges and
    rescued by ``searchsorted`` wherever the geometry disagrees."""

    n: jax.Array
    k: jax.Array
    weighted: bool

    def __call__(self, y: jax.Array) -> FG: ...

    def init_stats(self) -> tuple[jax.Array, jax.Array, jax.Array]: ...

    def histogram(
        self, edges: jax.Array, need_msum: bool = False
    ) -> tuple[jax.Array, jax.Array, Optional[jax.Array]]: ...


def _weight_accum_dtype(x, w):
    """Mass accumulation dtype: the kernels' f32 floor, full precision for
    either-f64 operands.  SINGLE source of truth lives with the oracles —
    the engine's wk/W dtype must never desynchronize from the kernels'
    accumulation dtype or the weighted certificates lie."""
    from repro.kernels.ref import _waccum_dtype  # deferred: core <-> kernels

    return _waccum_dtype(x, w)


class RowsEvaluator:
    """Independent rows: ``x`` is (B, n), one pivot and one ``k`` per row.

    The data pass is ``kernels.ops.fused_partials_batched`` (Pallas on TPU,
    fused jnp elsewhere, Pallas-interpret for kernel validation on CPU).

    The optional weights leg: with ``weights`` (B, n), ``k`` is reinterpreted
    as the per-row TARGET CUMULATIVE MASS ``wk`` (float, clipped to the
    row's total weight ``W``), the partials carry weight masses in the
    measure fields, and ``histogram`` binning emits the weighted
    ``(cnt, mass, msum)`` slot triple.

    ``binned_impl`` routes the jnp histogram pass's slot assignment
    ('searchsorted' | 'arithmetic'; None lets ``kernels.ops`` pick — see
    ``_resolve_impl`` there); both are bit-identical, the knob exists for
    differential testing and perf bisection.
    """

    def __init__(self, x: jax.Array, k, *, backend: str | None = None,
                 weights: jax.Array | None = None,
                 binned_impl: str | None = None):
        from repro.kernels import ops as kops  # deferred: core <-> kernels

        self._kops = kops
        self._backend = backend
        self._binned_impl = binned_impl
        self.x = x
        self.n = jnp.asarray(x.shape[1], jnp.int32)
        self.weighted = weights is not None
        if self.weighted:
            self.w = w = jnp.broadcast_to(jnp.asarray(weights), x.shape)
            dt = _weight_accum_dtype(x, w)
            self.W = jnp.sum(w, axis=1, dtype=dt)
            self.k = jnp.broadcast_to(
                jnp.minimum(jnp.asarray(k, dt), self.W), (x.shape[0],))
            self._partials = lambda y: kops.fused_weighted_partials_batched(
                x, w, y, backend=backend)
        else:
            self.k = jnp.broadcast_to(
                jnp.clip(jnp.asarray(k, jnp.int32), 1, x.shape[1]),
                (x.shape[0],))
            self._partials = lambda y: kops.fused_partials_batched(
                x, y, backend=backend)

    def __call__(self, y: jax.Array) -> FG:
        if self.weighted:
            return wfg_from_partials(self._partials(y), self.W, self.k)
        return fg_from_partials(self._partials(y), self.n, self.k)

    def histogram(self, edges, need_msum=False):
        if self.weighted:
            return self._kops.fused_weighted_histogram_batched(
                self.x, self.w, edges, backend=self._backend,
                impl=self._binned_impl, want_sums=need_msum)
        cnt, bsum = self._kops.fused_histogram_batched(
            self.x, edges, backend=self._backend, impl=self._binned_impl,
            want_sums=need_msum)
        return cnt, cnt, bsum  # counting measure: the counts ARE the mass

    def init_stats(self):
        x = self.x
        if self.weighted:
            # weighted mean: the analytic seed f-values are mass-weighted
            wmean = jnp.sum(self.w * x, axis=1, dtype=self.W.dtype) \
                / jnp.maximum(self.W, jnp.ones_like(self.W) * 1e-30)
            return (jnp.min(x, axis=1), jnp.max(x, axis=1),
                    wmean.astype(x.dtype))
        return (jnp.min(x, axis=1), jnp.max(x, axis=1),
                jnp.mean(x, axis=1, dtype=x.dtype))


class SharedEvaluator:
    """One shared array, K live pivots (``multi_order_statistic``).

    The data pass is ``kernels.ops.fused_partials_multi``: the multi-pivot
    Pallas kernel reads each ``x`` tile into VMEM once and emits partials
    for all K pivots — K× less HBM traffic than K independent passes.
    """

    def __init__(self, x: jax.Array, ks, *, backend: str | None = None,
                 weights: jax.Array | None = None,
                 binned_impl: str | None = None):
        from repro.kernels import ops as kops  # deferred: core <-> kernels

        self._kops = kops
        self._backend = backend
        self._binned_impl = binned_impl
        self.x = x = x.reshape(-1)
        self.n = jnp.asarray(x.size, jnp.int32)
        self.weighted = weights is not None
        if self.weighted:
            self.w = w = jnp.asarray(weights).reshape(-1)
            dt = _weight_accum_dtype(x, w)
            self.W = jnp.sum(w, dtype=dt)
            self.k = jnp.minimum(jnp.asarray(ks, dt).reshape(-1), self.W)
            self._partials = lambda y: kops.fused_weighted_partials_multi(
                x, w, y, backend=backend)
        else:
            self.k = jnp.clip(jnp.asarray(ks, jnp.int32).reshape(-1), 1,
                              x.size)
            self._partials = lambda y: kops.fused_partials_multi(
                x, y, backend=backend)

    def __call__(self, y: jax.Array) -> FG:
        if self.weighted:
            return wfg_from_partials(self._partials(y), self.W, self.k)
        return fg_from_partials(self._partials(y), self.n, self.k)

    def histogram(self, edges, need_msum=False):
        if self.weighted:
            return self._kops.fused_weighted_histogram_multi(
                self.x, self.w, edges, backend=self._backend,
                impl=self._binned_impl, want_sums=need_msum)
        cnt, bsum = self._kops.fused_histogram_multi(
            self.x, edges, backend=self._backend, impl=self._binned_impl,
            want_sums=need_msum)
        return cnt, cnt, bsum  # counting measure: the counts ARE the mass

    def init_stats(self):
        x, b = self.x, self.k.shape[0]
        bc = lambda v: jnp.broadcast_to(v, (b,))
        if self.weighted:
            wmean = jnp.sum(self.w * x, dtype=self.W.dtype) \
                / jnp.maximum(self.W, 1e-30)
            return (bc(jnp.min(x)), bc(jnp.max(x)),
                    bc(wmean.astype(x.dtype)))
        return (bc(jnp.min(x)), bc(jnp.max(x)),
                bc(jnp.mean(x, dtype=x.dtype)))


class ShardedEvaluator:
    """Data sharded over mesh axis/axes: local fused pass + psum combine.

    ``B = 1`` view (scalar pivot broadcast from the engine's (1,) state) —
    the psum of the additive partials IS the cross-device combine; no
    data moves.  Must be constructed inside ``shard_map``.
    """

    def __init__(self, x_local: jax.Array, k, axes, *,
                 backend: str | None = None,
                 weights: jax.Array | None = None,
                 binned_impl: str | None = None):
        from repro.kernels import ops as kops  # deferred: core <-> kernels

        self.x_local = x_local = x_local.reshape(-1)
        self.axes = axes = (axes,) if isinstance(axes, str) else tuple(axes)
        self._kops = kops
        self._backend = backend
        self._binned_impl = binned_impl
        self.n = jax.lax.psum(jnp.asarray(x_local.size, jnp.int32), axes)
        self.weighted = weights is not None
        if self.weighted:
            self.w_local = w = jnp.asarray(weights).reshape(-1)
            dt = _weight_accum_dtype(x_local, w)
            # total mass is a psum, exactly like the element count
            self.W = jax.lax.psum(jnp.sum(w, dtype=dt), axes)
            self.k = jnp.minimum(jnp.asarray(k, dt), self.W)
            self._partials1 = lambda y: kops.fused_weighted_partials(
                x_local, w, y, backend=backend)
        else:
            self.k = jnp.clip(jnp.asarray(k, jnp.int32), 1, self.n)
            self._partials1 = lambda y: kops.fused_partials(
                x_local, y, backend=backend)

    def __call__(self, y: jax.Array) -> FG:
        return self.combine(self._partials1(y))

    def local_histogram(self, edges, need_msum=False):
        """This shard's un-psum'd ``(cnt, mass, msum)`` slot triple (shape
        ``(nbins + 2,)`` each) — the binned analogue of
        :meth:`local_partials`; the distributed binned loop bounds the
        PER-SHARD in-bracket count from the local counts while the psum of
        the mass vector drives the narrowing.  ``need_msum`` requests the
        per-slot sums (the polish ingredient); without it the jnp
        arithmetic pass skips them."""
        if self.weighted:
            return self._kops.fused_weighted_histogram(
                self.x_local, self.w_local, edges, backend=self._backend,
                impl=self._binned_impl, want_sums=need_msum)
        cnt, bsum = self._kops.fused_histogram(
            self.x_local, edges, backend=self._backend,
            impl=self._binned_impl, want_sums=need_msum)
        return cnt, cnt, bsum  # counting measure: the counts ARE the mass

    def histogram(self, edges, need_msum=False):
        """Binned pass over the GLOBAL array: local histogram + one psum of
        the ``(nbins + 2,)`` mass vector — additive across shards exactly
        like the FG partials (B = 1 view: ``(nbins + 1,)`` edges).  On the
        counting leg the psum'd counts serve as both ``cnt`` and ``mass``
        (one vector on the wire); the weighted leg psums the mass vector
        next to the counts (``2 * (nbins + 2)`` scalars, still no data
        movement).  The per-bin sums ride the wire ONLY on demand
        (``need_msum=True``, the polish rounds): one extra ``(nbins + 2,)``
        psum buys the globally-reconstructed straddling-bin centroid; plain
        binned rounds keep the old wire cost and return ``None``."""
        if self.weighted:
            cnt, wcnt, wsum = self.local_histogram(edges,
                                                   need_msum=need_msum)
            return (jax.lax.psum(cnt, self.axes),
                    jax.lax.psum(wcnt, self.axes),
                    jax.lax.psum(wsum, self.axes) if need_msum else None)
        cnt, _, bsum = self.local_histogram(edges, need_msum=need_msum)
        c = jax.lax.psum(cnt, self.axes)
        return c, c, (jax.lax.psum(bsum, self.axes) if need_msum else None)

    def local_partials(self, y: jax.Array):
        """This shard's un-psum'd additive partials (for shard-local
        bookkeeping — the distributed hybrid finalize bounds the PER-SHARD
        in-bracket count, see ``distributed.local_order_statistic``)."""
        return self._partials1(y)

    def combine(self, partials):
        """The cross-device combine IS a psum of the additive partials
        (the paper's "partial sums from several GPUs are added") — four
        for counts, six for the weighted leg."""
        if self.weighted:
            wsp, wsn, wlt, wle, lt, le = partials
            fsum = jax.lax.psum(jnp.stack([wsp, wsn, wlt, wle]), self.axes)
            csum = jax.lax.psum(jnp.stack([lt, le]), self.axes)
            return wfg_from_partials(
                (fsum[0], fsum[1], fsum[2], fsum[3], csum[0], csum[1]),
                self.W, self.k)
        sp, sn, lt, le = partials
        fsum = jax.lax.psum(jnp.stack([sp, sn]), self.axes)
        csum = jax.lax.psum(jnp.stack([lt, le]), self.axes)
        return fg_from_partials((fsum[0], fsum[1], csum[0], csum[1]),
                                self.n, self.k)

    def init_stats(self):
        x, axes = self.x_local, self.axes
        if self.weighted:
            wxsum = jax.lax.psum(
                jnp.sum(self.w_local * x, dtype=self.W.dtype), axes)
            wmean = wxsum / jnp.maximum(self.W, 1e-30)
            return (jax.lax.pmin(jnp.min(x), axes),
                    jax.lax.pmax(jnp.max(x), axes),
                    wmean.astype(x.dtype))
        xsum = jax.lax.psum(jnp.sum(x, dtype=x.dtype), axes)
        return (jax.lax.pmin(jnp.min(x), axes),
                jax.lax.pmax(jnp.max(x), axes),
                xsum / self.n.astype(x.dtype))


class FnEvaluator:
    """Adapter: wrap a raw ``partials(y) -> (sp, sn, lt, le)`` closure (all
    fields ``(B,)``-shaped) as an :class:`Evaluator`.  Used by the
    distributed across-axis solver, where the combine is a per-coordinate
    psum, and by tests that drive the engine through a custom backend.

    ``histogram(edges) -> (cnt, mass, msum)`` (edges ``(B, nbins + 1)``,
    outputs ``(B, nbins + 2)``; ``msum`` may be ``None``) is optional;
    without it the evaluator only drives the FG methods.  A closure may
    accept a ``need_msum`` keyword to see the engine's demand hint (skip
    sum transport on plain rounds, ship it on polish rounds); a
    single-argument closure absorbs the hint here (always returning
    ``None`` for ``msum`` forgoes the polish).

    Weighted leg: with ``weights_total=W`` the ``partials`` closure must
    return the six weighted partials, ``k`` is the target mass ``wk``, and
    the histogram triple carries the weighted slot masses — the closure
    owns whatever transport (psum, multi-leaf reduction) produces them."""

    def __init__(self, partials: Callable, n, k, init_stats: Callable,
                 histogram: Optional[Callable] = None,
                 weights_total=None):
        import inspect

        self._partials = partials
        self.n = n
        self.k = k
        self._init_stats = init_stats
        self._histogram = histogram
        self._hist_takes_msum = False
        if histogram is not None:
            try:
                params = inspect.signature(histogram).parameters
                self._hist_takes_msum = "need_msum" in params
            except (TypeError, ValueError):  # builtins / odd callables
                self._hist_takes_msum = False
        self.weighted = weights_total is not None
        self.W = weights_total

    def __call__(self, y: jax.Array) -> FG:
        if self.weighted:
            return wfg_from_partials(self._partials(y), self.W, self.k)
        return fg_from_partials(self._partials(y), self.n, self.k)

    def histogram(self, edges, need_msum=False):
        if self._histogram is None:
            raise NotImplementedError(
                "this FnEvaluator was built without a histogram closure; "
                "method='binned' needs one")
        if self._hist_takes_msum:
            return self._histogram(edges, need_msum=need_msum)
        return self._histogram(edges)

    def init_stats(self):
        return self._init_stats()
