"""Convex selection objective of Beliakov (2011), Eqs. (1)-(2).

The k-th smallest element of ``x`` (1-indexed) is the minimizer of the
piecewise-linear convex function

    f(y) = (1/n) * sum_i u(x_i - y),
    u(t) = beta * t        if t >= 0          (x_i above y)
         = -alpha * t      if t <  0          (x_i below y)

with ``alpha = (n - k + 1/2)/n`` and ``beta = (k - 1/2)/n``.  The kink of the
one-sided derivatives crosses zero at ``count(x < y) = k - 1/2``, i.e. exactly
at ``x_(k)``.

NOTE (paper erratum): the paper's Eq. (2) swaps alpha/beta relative to its
stated "k-th smallest" convention; as printed it selects the k-th *largest*.
We use the corrected weights above and validate against ``np.partition``.

The Clarke subdifferential at ``y`` is the interval ``[g_lo, g_hi]`` with

    g_lo(y) = alpha * n_lt - beta * (n - n_lt)      # left  derivative
    g_hi(y) = alpha * n_le - beta * (n - n_le)      # right derivative

where ``n_lt = count(x < y)`` and ``n_le = count(x <= y)``.  Crucially

    0 in [g_lo, g_hi]  <=>  n_lt < k <= n_le  <=>  y == x_(k) (exact hit),

so the counts both drive the optimizer *and* certify exactness.  Everything
in this module is a single fused read-only pass over ``x`` (the paper's
``transform_reduce``), which is what makes the method shard-friendly: partial
``(sum_pos, sum_neg, n_lt, n_le)`` quadruples combine additively across
devices (psum of four scalars).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class FG(NamedTuple):
    """Objective value, subdifferential interval and counts at a pivot."""

    f: jax.Array      # objective value (normalized by n)
    g_lo: jax.Array   # left one-sided derivative
    g_hi: jax.Array   # right one-sided derivative
    n_lt: jax.Array   # count(x <  y), int32
    n_le: jax.Array   # count(x <= y), int32


def os_weights(n, k, dtype=jnp.float32):
    """Normalized slope weights (alpha: below-pivot, beta: above-pivot)."""
    n = jnp.asarray(n, dtype)
    k = jnp.asarray(k, dtype)
    alpha = (n - k + 0.5) / n
    beta = (k - 0.5) / n
    return alpha, beta


def eval_partials(x: jax.Array, y: jax.Array):
    """One fused pass: (sum of (x-y)+, sum of (y-x)+, n_lt, n_le).

    These four partials are additive over shards/blocks; every selection
    method in :mod:`repro.core.selection` is built from them.
    """
    x = x.reshape(-1)
    d = x - y
    sum_pos = jnp.sum(jnp.maximum(d, 0), dtype=x.dtype)
    sum_neg = jnp.sum(jnp.maximum(-d, 0), dtype=x.dtype)
    n_lt = jnp.sum(d < 0, dtype=jnp.int32)
    n_le = jnp.sum(d <= 0, dtype=jnp.int32)
    return sum_pos, sum_neg, n_lt, n_le


def fg_from_partials(partials, n, k) -> FG:
    """Combine additive partials into the FG quintuple."""
    sum_pos, sum_neg, n_lt, n_le = partials
    alpha, beta = os_weights(n, k, sum_pos.dtype)
    nf = jnp.asarray(n, sum_pos.dtype)
    f = (beta * sum_pos + alpha * sum_neg) / nf
    n_ltf = n_lt.astype(sum_pos.dtype)
    n_lef = n_le.astype(sum_pos.dtype)
    # one-sided derivatives: at x==y the term switches branch, so the left
    # derivative counts ties as "above" and the right derivative as "below".
    g_lo = alpha * n_ltf / nf - beta * (nf - n_ltf) / nf
    g_hi = alpha * n_lef / nf - beta * (nf - n_lef) / nf
    return FG(f=f, g_lo=g_lo, g_hi=g_hi, n_lt=n_lt, n_le=n_le)


def eval_fg(x: jax.Array, y: jax.Array, k) -> FG:
    """Objective + subdifferential + counts at pivot ``y`` (single pass)."""
    return fg_from_partials(eval_partials(x, y), x.size, k)


def eval_fg_batched(x: jax.Array, y: jax.Array, k) -> FG:
    """Row-wise variant: ``x`` is (B, n), ``y``/``k`` are (B,)."""
    b_eval = jax.vmap(lambda xi, yi, ki: eval_fg(xi, yi, ki))
    return b_eval(x, y, jnp.broadcast_to(jnp.asarray(k), (x.shape[0],)))
