"""Robust statistics built on selection — the paper's Sec. VI applications,
plus the training-framework integrations (robust aggregation, quantile clip).

* LMS  (Least Median of Squares, Rousseeuw 1984): minimize Med(r_i^2).
* LTS  (Least Trimmed Squares): minimize the sum of the h smallest squared
  residuals — evaluated WITHOUT sorting via the paper's rho/(a,b)
  median-multiplicity trick (Eq. 4): with m = |r|_(h), b_L = count(|r| < m),
  b = count(|r| = m), a = h - b_L:

      F(theta) = sum_{|r|<m} r^2 + a * m^2

  which equals the sum of exactly h smallest squared residuals.
* FAST-LTS style fitting: random elemental starts + concentration steps
  (Rousseeuw & Van Driessen, ref [28] of the paper); the h-th order
  statistic threshold comes from the CP selector, the trimmed LS refit is a
  weighted least squares with fractional tie weights a/b (so ties do not
  break exactness).
* kNN by order statistic (no sort): indicator weights from d_(k).
* Robust gradient aggregation + quantile clipping for distributed training.

Batched-first wiring: every multi-problem selection here rides the rows-mode
engine (``selection.select_rows`` over a ``(B, n)`` residual/distance
matrix) — one batched bracket loop for ALL elemental starts / queries per
step, instead of lock-stepping B scalar solvers under ``jax.vmap``.  The
concentration scan is therefore structured *starts-inside, steps-outside*:
``lax.scan`` over C-steps carries the whole (n_starts, p) theta block, and
each step does one batched selection + one batched weighted refit.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import distributed, selection
from repro.core.objective import fg_from_partials


# ---------------------------------------------------------------------------
# LMS / LTS objectives
# ---------------------------------------------------------------------------


def residuals(theta, X, y):
    return X @ theta - y


def lms_objective(theta, X, y, **kw):
    """Med(r^2) (Rousseeuw's LMS criterion)."""
    r2 = residuals(theta, X, y) ** 2
    return selection.median(r2, **kw).value


def lts_objective_from_residuals(r, h, **kw):
    """Sum of the h smallest squared residuals via the rho/(a,b) trick.

    One selection + one fused masked reduction; no sort, no partial sort.
    The B=1 view of :func:`lts_objective_rows`.
    """
    return lts_objective_rows(r.reshape(1, -1), h, **kw)[0]


def lts_objective_rows(R, h, **kw):
    """Row-wise LTS criterion: ``R`` is (B, n) residuals, one scalar per
    row — the rho/(a,b) trick on top of one rows-mode batched selection."""
    a2 = R * R
    m = selection.select_rows(a2, h, **kw).value[:, None]
    below = jnp.sum(jnp.where(a2 < m, a2, 0.0), axis=1, dtype=a2.dtype)
    b_lo = jnp.sum(a2 < m, axis=1, dtype=jnp.int32)
    a = (jnp.asarray(h, jnp.int32) - b_lo).astype(a2.dtype)
    return below + a * m[:, 0]


def lts_objective(theta, X, y, h=None, **kw):
    n, p = X.shape
    if h is None:
        h = (n + p + 1) // 2  # [(n+p)/2] + parity-safe default
    return lts_objective_from_residuals(residuals(theta, X, y), h, **kw)


# ---------------------------------------------------------------------------
# Fitting: random elemental starts + concentration steps
# ---------------------------------------------------------------------------


class RobustFit(NamedTuple):
    theta: jax.Array
    objective: jax.Array
    inlier_weights: jax.Array  # LTS: 1 below cutoff, a/b at cutoff, 0 above
    # per-concentration-step selection sweep counts, (c_steps, n_starts)
    # int32 (None where the fit has no iterative selection): the
    # warm-start instrumentation — steady state is 1 sweep per step
    sweeps: Optional[jax.Array] = None


def _elemental_thetas(key, X, y, n_starts):
    """Solve p x p systems on random p-subsets (PROGRESS-style starts)."""
    n, p = X.shape
    keys = jax.random.split(key, n_starts)

    def solve_one(kk):
        idx = jax.random.choice(kk, n, shape=(p,), replace=False)
        A = X[idx]
        b = y[idx]
        # ridge-regularized solve for degenerate subsets
        G = A.T @ A + 1e-8 * jnp.eye(p, dtype=X.dtype)
        return jnp.linalg.solve(G, A.T @ b)

    return jax.vmap(solve_one)(keys)


def _lts_weights(r, h):
    """Fractional trimming weights: 1 / (a/b) / 0 per the paper's rho."""
    return _lts_weights_rows(r[None, :], h)[0][0]


def _lts_weights_rows(R, h, method=None, prior=None):
    """Row-wise fractional trimming weights for (B, n) residual blocks.

    One rows-mode batched selection yields every row's cutoff m = |r|^2_(h)
    at once; ties at the cutoff get weight a/b so each row keeps EXACTLY h
    points in total weight.  ``prior`` warm-starts the cutoff selection
    from the previous concentration step's result.  Returns
    ``(weights, SelectResult)`` — the result feeds the next step's prior
    and the sweep-count instrumentation.
    """
    a2 = R * R
    res = selection.select_rows(a2, h, method=method, prior=prior)
    m = res.value[:, None]
    b_lo = jnp.sum(a2 < m, axis=1, keepdims=True, dtype=jnp.int32)
    b_eq = jnp.sum(a2 == m, axis=1, keepdims=True, dtype=jnp.int32)
    a = jnp.asarray(h, jnp.int32) - b_lo
    frac = a.astype(a2.dtype) / jnp.maximum(b_eq, 1).astype(a2.dtype)
    return jnp.where(a2 < m, 1.0, jnp.where(a2 == m, frac, 0.0)), res


def _carry_prior(res, shape, pdt) -> selection.Prior:
    """SelectResult -> fixed-structure scan carry (shape/dtype pinned so a
    cp-leg result and a binned-leg result produce the same carry pytree)."""
    pr = selection.as_prior(res)
    return selection.Prior(
        *(jnp.broadcast_to(jnp.asarray(f, pdt), shape) for f in pr))


def _nan_prior(shape, pdt) -> selection.Prior:
    """Cold-start carry seed: all-NaN fields are sanitized away inside the
    engine (a NaN prior degrades to the analytic/uniform layout), so step 1
    of a warm scan behaves like a cold solve — exactly, on the counting
    leg."""
    nanv = jnp.full(shape, jnp.nan, pdt)
    return selection.Prior(nanv, nanv, nanv, nanv)


def _weighted_ls(X, y, w):
    Xw = X * w[:, None]
    G = X.T @ Xw + 1e-8 * jnp.eye(X.shape[1], dtype=X.dtype)
    return jnp.linalg.solve(G, Xw.T @ y)


def _weighted_ls_rows(X, y, W):
    """Batched weighted LS: ``W`` is (B, n) weights, one solve per row."""
    return jax.vmap(lambda w: _weighted_ls(X, y, w))(W)


@functools.partial(jax.jit, static_argnames=("n_starts", "c_steps", "h",
                                             "method", "warm"))
def lts_fit(key, X, y, *, h: Optional[int] = None, n_starts: int = 64,
            c_steps: int = 10, method: Optional[str] = None,
            warm: bool = True) -> RobustFit:
    """FAST-LTS: elemental starts -> concentration steps -> best fit.

    Concentration runs starts-inside, steps-outside: each ``lax.scan`` step
    thresholds ALL starts' squared residuals at their h-th order statistic
    in ONE rows-mode batched selection (no sort), then refits every start by
    weighted LS.  The objective is monotone non-increasing along C-steps
    (Rousseeuw & Van Driessen), so the final best-of-starts is a
    high-breakdown estimate.

    ``method`` threads through to the batched selections (None = auto:
    'binned' for large n — every C-step then costs ~3 data passes over the
    (n_starts, n) residual block instead of ~15).

    ``warm`` (default on): the scan carries each start's selection result
    as a ``prior`` into the next step's cutoff selection — residuals
    barely move between concentration steps, so steady-state steps take 1
    binned sweep instead of a cold ~2-3 (the warm-started repeated
    selection the engine's ``prior=`` leg exists for).  Results are
    bit-identical to ``warm=False`` (the prior steers edge placement
    only); ``RobustFit.sweeps`` records the per-step counts.
    """
    n, p = X.shape
    hh = (n + p + 1) // 2 if h is None else h
    pdt = jnp.promote_types(X.dtype, jnp.float32)

    thetas0 = _elemental_thetas(key, X, y, n_starts)

    def c_step(carry, _):
        thetas, pr = carry
        R = thetas @ X.T - y[None, :]          # (n_starts, n) residuals
        W, res = _lts_weights_rows(R, hh, method,
                                   prior=pr if warm else None)
        pr_n = _carry_prior(res, (n_starts,), pdt)
        return (_weighted_ls_rows(X, y, W), pr_n), res.iters

    (thetas, prf), sweeps = jax.lax.scan(
        c_step, (thetas0, _nan_prior((n_starts,), pdt)), None,
        length=c_steps)
    objs = lts_objective_rows(thetas @ X.T - y[None, :], hh, method=method,
                              prior=prf if warm else None)
    best = jnp.argmin(objs)
    theta = thetas[best]
    return RobustFit(
        theta=theta,
        objective=objs[best],
        inlier_weights=_lts_weights(residuals(theta, X, y), hh),
        sweeps=sweeps,
    )


@functools.partial(jax.jit, static_argnames=("n_starts", "method"))
def lms_fit(key, X, y, *, n_starts: int = 256,
            method: Optional[str] = None) -> RobustFit:
    """LMS by best-of-elemental-starts (the classical PROGRESS approach).

    Every start's criterion Med(r^2) is one row of a single rows-mode
    batched selection — thousands of concurrent selection problems in one
    bracket loop, the workload the paper's GPU method targets.  ``method``
    threads through to the selections (None = auto: 'binned' for large n).
    """
    n = X.shape[0]
    thetas = _elemental_thetas(key, X, y, n_starts)
    R2 = (thetas @ X.T - y[None, :]) ** 2      # (n_starts, n)
    objs = selection.select_rows(R2, (n + 1) // 2, method=method).value
    best = jnp.argmin(objs)
    theta = thetas[best]
    r2 = residuals(theta, X, y) ** 2
    med = selection.median(r2, method=method).value
    return RobustFit(
        theta=theta, objective=objs[best],
        inlier_weights=(r2 <= med).astype(X.dtype),
    )


# ---------------------------------------------------------------------------
# Weighted-median regression: Theil-Sen and IRLS M-estimation
# ---------------------------------------------------------------------------
#
# Both estimators are consumers of the WEIGHTED selection engine (PR 3): the
# weighted median is the exact primitive behind Theil-Sen slopes (Sen's
# |dx|-weighted median of pairwise slopes) and behind the IRLS scale step
# (weighted MAD under the current robustness weights) — the regime where
# GPU-side convex minimization replaces sort-based weighted quantiles
# (Zhou, Lange & Suchard 2010 make the same argument for LAD).


class TheilSenFit(NamedTuple):
    intercept: jax.Array
    slope: jax.Array
    theta: jax.Array        # (2,) = [intercept, slope]
    # (slope Prior, intercept Prior) carry for warm refits on drifted data;
    # pass the whole fit back as ``prior=`` to the next call
    prior: object = None


@functools.partial(jax.jit, static_argnames=("weighting", "method",
                                             "max_pairs"))
def theil_sen_fit(x, y, *, weighting: str = "sen",
                  method: Optional[str] = None,
                  max_pairs: Optional[int] = None,
                  prior=None) -> TheilSenFit:
    """Theil-Sen simple regression via the weighted median of pairwise
    slopes.

    All pairwise slopes ride ONE weighted selection (degenerate pairs
    ``x_i == x_j`` get weight 0, so they never influence the mass target);
    ``weighting='sen'`` weights each slope by ``|x_j - x_i|`` (Sen 1968's
    variance-reducing choice — a long-baseline pair estimates the slope
    better than a short one), ``'uniform'`` recovers the classical median
    of slopes.  The intercept is the (unweighted) median of the residuals
    at the fitted slope.  Breakdown ~29%: the acceptance bar is exact slope
    recovery at 30% random contamination, where OLS is destroyed.

    ``max_pairs=None`` materializes the full (n, n) slope matrix — fine for
    the paper-scale regression workloads (n up to a few thousand).  For
    larger n pass ``max_pairs``: slopes are generated in a BLOCKED
    offset-strided layout — ``p = max_pairs // n`` cyclic offsets ``d``
    spread over ``1..n-1``, pairing every ``x_i`` with ``x_{(i+d) mod n}``
    into a ``(p, n)`` block — O(max_pairs) memory, no (n, n) anywhere.
    Each offset contributes every index once, so the subsample is balanced
    (every observation appears in exactly ``2p`` pairs); with
    ``max_pairs >= n*(n-1)`` the offsets enumerate EVERY ordered pair
    exactly once, which has the same (slope, weight) multiset as the full
    matrix (whose diagonal carries weight 0) — the two modes then agree
    exactly, which is the property the tests pin on small n.
    """
    x = jnp.asarray(x).reshape(-1)
    y = jnp.asarray(y).reshape(-1)
    n = x.shape[0]
    # blocked whenever it is no larger than the full (n, n) materialization
    # — max_pairs == n*(n-1) then yields offsets 1..n-1 (every ordered
    # pair), the exact-equality regime the parity tests pin
    if max_pairs is not None and n > 2 and max_pairs < n * n:
        import numpy as np  # static offset schedule (n, max_pairs static)

        p = int(max(1, min(n - 1, max_pairs // n)))
        offsets = np.unique(
            np.round(np.linspace(1, n - 1, p)).astype(np.int64))
        idx = (jnp.arange(n)[None, :]
               + jnp.asarray(offsets)[:, None]) % n     # (p, n)
        dx = x[idx] - x[None, :]
        dy = y[idx] - y[None, :]
    else:
        dx = x[None, :] - x[:, None]
        dy = y[None, :] - y[:, None]
    valid = dx != 0
    slopes = jnp.where(valid, dy / jnp.where(valid, dx, 1.0), 0.0)
    if weighting == "sen":
        w = jnp.where(valid, jnp.abs(dx), 0.0)
    elif weighting == "uniform":
        w = valid.astype(x.dtype)
    else:
        raise ValueError(f"unknown weighting {weighting!r}")
    # warm start: accept a previous TheilSenFit (its ``prior`` carry, or —
    # if that is absent — its point estimates) or an explicit
    # (slope_prior, intercept_prior) pair; each leg is normalized through
    # ``selection.as_prior`` so results, SelectResults, Priors and bare
    # scalars all work.  Exactness never depends on the prior.
    spr = ipr = None
    if prior is not None:
        if isinstance(prior, TheilSenFit):
            if prior.prior is not None:
                spr, ipr = prior.prior
            else:
                spr, ipr = prior.slope, prior.intercept
        else:
            spr, ipr = prior
        spr = selection.as_prior(spr)
        ipr = selection.as_prior(ipr)
    sres = selection.weighted_median(
        slopes.reshape(-1), w.reshape(-1), method=method, prior=spr)
    slope = sres.value
    ires = selection.median(y - slope * x, method=method, prior=ipr)
    intercept = ires.value
    return TheilSenFit(intercept=intercept, slope=slope,
                       theta=jnp.stack([intercept, slope]),
                       prior=(selection.as_prior(sres),
                              selection.as_prior(ires)))


class IRLSFit(NamedTuple):
    theta: jax.Array
    scale: jax.Array        # final robust scale (weighted MAD estimate)
    weights: jax.Array      # final robustness weights (n,)
    objective: jax.Array    # sum of rho(r / scale) at the final iterate
    # per-iteration weighted-median sweep counts, (iters,) int32 — the
    # warm-start instrumentation — steady state is 1 sweep per iteration
    sweeps: Optional[jax.Array] = None


def _rho_weights(u, loss: str, c):
    """IRLS weight function w(u) = psi(u)/u for the supported losses."""
    au = jnp.abs(u)
    if loss == "huber":
        return jnp.minimum(1.0, c / jnp.maximum(au, 1e-20))
    if loss == "tukey":
        t = jnp.clip(1.0 - (u / c) ** 2, 0.0, None)
        return t * t
    raise ValueError(f"unknown loss {loss!r}")


def _rho(u, loss: str, c):
    au = jnp.abs(u)
    if loss == "huber":
        quad = 0.5 * u * u
        return jnp.where(au <= c, quad, c * au - 0.5 * c * c)
    # tukey bisquare
    t = jnp.clip(1.0 - (u / c) ** 2, 0.0, None)
    return (c * c / 6.0) * (1.0 - t ** 3)


@functools.partial(jax.jit, static_argnames=("loss", "iters", "method",
                                             "warm"))
def irls_fit(X, y, *, loss: str = "huber", c: Optional[float] = None,
             iters: int = 30, method: Optional[str] = None,
             min_scale: float = 1e-12, warm: bool = True) -> IRLSFit:
    """IRLS M-estimator (Huber / Tukey bisquare) with a weighted-engine
    scale step.

    Each reweighting iteration calls the WEIGHTED selection engine for its
    scale: a weighted MAD-about-zero (1.4826 x the weighted median of
    |residuals| under the current robustness weights) — down-weighted
    outliers stop corrupting their own rejection threshold, and centering
    at zero (the regression convention: location is the intercept's job)
    keeps a biased start from shrinking the scale below the residual
    offset, which would zero every redescending-psi weight.  Then the
    standard w(u) = psi(u)/u reweighting and a weighted LS refit.

    ``c`` defaults to the 95%-efficiency constants (Huber 1.345, Tukey
    4.685).  ``method`` threads to the weighted selections.

    ``warm`` (default on): the scan carries each iteration's weighted
    median result as the next iteration's ``prior`` — residuals and
    robustness weights move little between reweighting steps, so
    steady-state scale steps take 1 binned sweep (bit-identical results,
    see ``selection.Prior``).  ``IRLSFit.sweeps`` records the per-
    iteration counts.
    """
    if c is None:
        c = 1.345 if loss == "huber" else 4.685
    n, p = X.shape
    dt = X.dtype
    pdt = jnp.promote_types(dt, jnp.float32)
    theta0 = _weighted_ls(X, y, jnp.ones((n,), dt))

    def step(carry, _):
        theta, w, pr = carry
        r = y - X @ theta
        res = selection.weighted_median(jnp.abs(r), w, method=method,
                                        prior=pr if warm else None)
        mad = res.value
        sigma = jnp.maximum(1.4826 * mad, min_scale)
        u = r / sigma
        w_new = _rho_weights(u, loss, c)
        theta_new = _weighted_ls(X, y, w_new)
        return (theta_new, w_new, _carry_prior(res, (), pdt)), \
            (sigma, res.iters)

    (theta, w, prf), (_sigmas, sweeps) = jax.lax.scan(
        step, (theta0, jnp.ones((n,), dt), _nan_prior((), pdt)), None,
        length=iters)
    # re-evaluate scale/weights/objective AT the returned theta (the scan
    # carries them one iterate stale: sigma was measured on the pre-refit
    # residuals, which would make objectives incomparable across iters)
    r = y - X @ theta
    mad = selection.weighted_median(jnp.abs(r), w, method=method,
                                    prior=prf if warm else None).value
    scale = jnp.maximum(1.4826 * mad, min_scale)
    u = r / scale
    return IRLSFit(theta=theta, scale=scale, weights=_rho_weights(u, loss, c),
                   objective=jnp.sum(_rho(u, loss, c)), sweeps=sweeps)


def knn_predict(train_x, train_y, query_x, k: int, *, classify: bool = False,
                n_classes: int = 0, method: Optional[str] = None):
    """kNN regression/classification without sorting the distances.

    Distances by one MXU-friendly matmul; the k-NN cutoffs for ALL queries
    come from one rows-mode batched selection over the (Q, n) distance
    matrix; ties at the cutoff get fractional weight so exactly k neighbors
    are counted.
    """
    # squared euclidean distances via ||a-b||^2 expansion (one matmul)
    d2 = (
        jnp.sum(query_x**2, -1, keepdims=True)
        - 2.0 * query_x @ train_x.T
        + jnp.sum(train_x**2, -1)[None, :]
    )

    dk = selection.select_rows(d2, k, method=method).value[:, None]
    lt = (d2 < dk).astype(d2.dtype)
    eq = (d2 == dk).astype(d2.dtype)
    n_lt = jnp.sum(lt, -1, keepdims=True)
    n_eq = jnp.sum(eq, -1, keepdims=True)
    frac = (k - n_lt) / jnp.maximum(n_eq, 1.0)
    w = lt + eq * frac  # sums to exactly k per query
    if classify:
        onehot = jax.nn.one_hot(train_y, n_classes, dtype=d2.dtype)
        votes = w @ onehot
        return jnp.argmax(votes, -1)
    return (w @ train_y) / k


# ---------------------------------------------------------------------------
# Distributed-training integrations
# ---------------------------------------------------------------------------


def robust_aggregate(tree, axes, *, method: str = "median",
                     trim: float = 0.25, agg_impl: str = "gather"):
    """Byzantine/straggler-robust combine of per-replica gradient pytrees.

    Call inside shard_map where each device along ``axes`` holds one
    replica's gradients.  method: 'mean' | 'median' | 'trimmed'.
    'median'/'trimmed' use coordinate-wise order statistics across the mesh
    axis (impl 'gather' or 'cp', see ``distributed.order_statistic_across_axis``).
    """
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    n_rep = jax.lax.psum(jnp.asarray(1, jnp.int32), axes_t)

    if method == "mean":
        return jax.tree.map(
            lambda g: jax.lax.pmean(g, axes_t), tree)

    if method == "median":
        return jax.tree.map(
            lambda g: distributed.median_across_axis(g, axes_t,
                                                     method=agg_impl), tree)

    if method == "trimmed":
        def tmean(g):
            k_lo = jnp.maximum((trim * n_rep).astype(jnp.int32), 1)
            k_hi = n_rep - k_lo + 1
            lo = distributed.order_statistic_across_axis(
                g, k_lo, axes_t, method=agg_impl)
            hi = distributed.order_statistic_across_axis(
                g, k_hi, axes_t, method=agg_impl)
            keep = (g >= lo) & (g <= hi)
            num = jax.lax.psum(jnp.where(keep, g, 0.0), axes_t)
            den = jax.lax.psum(keep.astype(g.dtype), axes_t)
            return num / jnp.maximum(den, 1.0)

        return jax.tree.map(tmean, tree)

    raise ValueError(f"unknown method {method!r}")


def pytree_quantile(tree, q, *, maxit: int = 16, abs_values: bool = True):
    """Approximate global q-quantile over all entries of a pytree.

    The CP loop runs with the pytree as one logical array: each iteration is
    one fused pass over every leaf (additive partials summed across leaves).
    Under pjit/GSPMD the leaf reductions lower to local reductions plus
    all-reduces of four scalars per iteration — communication-free in data
    volume, exactly the paper's multi-device argument.

    Returns the bracket midpoint on non-exact exit (tight after ~16 its for
    clipping purposes); exact on exact-hit / extreme shortcuts.
    """
    # Keep every leaf in its native shape AND sharding: a reshape(-1) here
    # would force GSPMD to all-gather each (sharded) gradient tensor.  The
    # abs+f32 conversion happens inside the reduction pass so XLA fuses it
    # (no materialized |g| copies); reductions over sharded dims lower to
    # local reductions + all-reduces of four scalars.
    leaves = list(jax.tree.leaves(tree))
    n = sum(l.size for l in leaves)

    def absf(l):
        l = l.astype(jnp.float32)
        return jnp.abs(l) if abs_values else l

    # counts in f32: gradient pytrees exceed int32 range (n > 2^31 for
    # multi-B-param models); the ~1e-7 relative count error is irrelevant
    # for a clipping threshold (and TPUs have no int64/f64).
    k = jnp.clip(jnp.ceil(jnp.float32(q) * jnp.float32(n)),
                 jnp.float32(1.0), jnp.float32(n))

    def partials(y):
        sp = sn = jnp.float32(0.0)
        lt = le = jnp.float32(0.0)
        for l in leaves:
            d = absf(l) - y
            sp = sp + jnp.sum(jnp.maximum(d, 0))
            sn = sn + jnp.sum(jnp.maximum(-d, 0))
            lt = lt + jnp.sum(d < 0, dtype=jnp.float32)
            le = le + jnp.sum(d <= 0, dtype=jnp.float32)
        return sp, sn, lt, le

    xmin = functools.reduce(jnp.minimum, [jnp.min(absf(l)) for l in leaves])
    xmax = functools.reduce(jnp.maximum, [jnp.max(absf(l)) for l in leaves])
    xsum = functools.reduce(jnp.add, [jnp.sum(absf(l)) for l in leaves])
    nf = jnp.asarray(n, jnp.float32)
    alpha = (nf - k + 0.5) / nf
    beta = (k - 0.5) / nf

    state = dict(
        yL=xmin, fL=beta * (xsum / nf - xmin),
        gL=alpha / nf - beta * (nf - 1.0) / nf,
        yR=xmax, fR=alpha * (xmax - xsum / nf),
        gR=alpha * (nf - 1.0) / nf - beta / nf,
        t=0.5 * (xmin + xmax), exact=jnp.asarray(False), it=jnp.asarray(0),
    )

    def cond(s):
        return (~s["exact"]) & (s["it"] < maxit) & (s["yR"] > s["yL"])

    def body(s):
        t = (s["fR"] - s["fL"] + s["yL"] * s["gL"] - s["yR"] * s["gR"]) / (
            s["gL"] - s["gR"])
        bad = ~jnp.isfinite(t) | (t <= s["yL"]) | (t >= s["yR"])
        t = jnp.where(bad, 0.5 * (s["yL"] + s["yR"]), t)
        fg = fg_from_partials(partials(t), n, k)
        exact = (fg.n_lt < k) & (k <= fg.n_le)
        move_left = fg.g_hi < 0
        return dict(
            yL=jnp.where(move_left, t, s["yL"]),
            fL=jnp.where(move_left, fg.f, s["fL"]),
            gL=jnp.where(move_left, fg.g_hi, s["gL"]),
            yR=jnp.where(move_left | exact, s["yR"], t),
            fR=jnp.where(move_left | exact, s["fR"], fg.f),
            gR=jnp.where(move_left | exact, s["gR"], fg.g_lo),
            t=t, exact=s["exact"] | exact, it=s["it"] + 1,
        )

    s = jax.lax.while_loop(cond, body, state)
    return jnp.where(s["exact"], s["t"], 0.5 * (s["yL"] + s["yR"]))


def pytree_quantile_per_leaf(tree, q, *, abs_values: bool = True,
                             method: Optional[str] = None,
                             maxit: int = 64):
    """EXACT per-leaf q-quantiles of a pytree in ONE segmented solve.

    Flattens the tree to one concatenated array with a leaf-id segment
    vector (leaf boundaries are static, so the per-leaf target ranks
    resolve host-side at f64) and runs a single
    ``selection.segmented_order_statistic`` — every engine data pass is
    shared by all leaves, so K per-layer thresholds cost the passes of one
    scalar quantile, not K of them.  Returns a pytree with the same
    structure holding one scalar threshold per leaf.

    Unlike :func:`pytree_quantile` (which never reshapes its leaves, so
    sharded gradients stay sharded), the concatenation materializes the
    flattened |tree| once — the per-leaf regime is the single-device /
    replicated-clip path; see ``benchmarks/clip_bench.py`` for the
    head-to-head.
    """
    leaves = list(jax.tree.leaves(tree))
    if not leaves:
        return tree
    sizes = [int(l.size) for l in leaves]

    def absf(l):
        l = l.astype(jnp.float32)
        return jnp.abs(l) if abs_values else l

    x = jnp.concatenate([absf(l).reshape(-1) for l in leaves])
    seg = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                           for i, s in enumerate(sizes)])
    res = selection.segmented_quantiles(x, seg, q, sizes, method=method,
                                        maxit=maxit)
    return jax.tree.unflatten(jax.tree.structure(tree),
                              [res.value[i] for i in range(len(sizes))])


def hist_quantile(tree, q, *, bins: int = 512, abs_values: bool = True):
    """Two-pass histogram quantile over a pytree (|x| by default) —
    APPROXIMATE, by bin resolution.

    Pass 1: min/max; pass 2: one 512-bin histogram (log-spaced) built with
    scatter-adds; the quantile is read from the cumulative histogram.  Bin
    resolution ~1.8% relative — plenty for clipping — at 2 data sweeps vs
    the CP solver's ~maxit sweeps.  The histogram is additive across shards
    (one psum of 512 floats under GSPMD), preserving the paper's
    scalar-ish-communication property.

    For EXACT thresholds at a comparable pass count, use the engine's
    binned descent instead: :func:`pytree_quantile` (global, ~maxit CP
    passes), or :func:`pytree_quantile_per_leaf` / the underlying
    ``selection.segmented_quantiles`` (exact per-leaf thresholds, 2-3
    histogram sweeps + an O(cap) finalize) — measured head-to-head in
    ``benchmarks/clip_bench.py``.
    """
    leaves = list(jax.tree.leaves(tree))
    n = sum(l.size for l in leaves)

    def absf(l):
        l = l.astype(jnp.float32)
        return jnp.abs(l) if abs_values else l

    lo = functools.reduce(jnp.minimum, [jnp.min(absf(l)) for l in leaves])
    hi = functools.reduce(jnp.maximum, [jnp.max(absf(l)) for l in leaves])
    lo = jnp.maximum(lo, 1e-12)
    hi = jnp.maximum(hi, lo * (1 + 1e-6))
    llo, lhi = jnp.log(lo), jnp.log(hi)
    scale = (bins - 1) / jnp.maximum(lhi - llo, 1e-12)

    hist = jnp.zeros((bins,), jnp.float32)
    for l in leaves:
        v = jnp.clip(jnp.log(jnp.maximum(absf(l), 1e-12)), llo, lhi)
        idx = ((v - llo) * scale).astype(jnp.int32).reshape(-1)
        hist = hist.at[idx].add(1.0)
    cum = jnp.cumsum(hist)
    k = jnp.float32(q) * jnp.float32(n)
    bin_idx = jnp.argmax(cum >= k)  # first bin reaching the target count
    # upper edge of the bin (conservative for clipping)
    return jnp.exp(llo + (bin_idx.astype(jnp.float32) + 1.0) / scale)


def clip_by_quantile(tree, q: float = 0.99, *, maxit: int = 16,
                     min_scale: float = 1e-8, per_leaf: bool = False):
    """Clip gradient magnitudes at their q-quantile (paper-primitive
    alternative to global-norm clipping; robust to exploding coordinates).

    ``per_leaf=False`` (default): ONE global threshold from
    :func:`pytree_quantile`; returns ``(clipped_tree, threshold)``.

    ``per_leaf=True``: per-LAYER thresholds — every leaf is clipped at its
    own exact q-quantile, all resolved by one segmented multi-k solve
    (:func:`pytree_quantile_per_leaf`: the engine's data passes are shared
    across leaves, so K thresholds cost the passes of one).  Returns
    ``(clipped_tree, thresholds_tree)`` with one scalar per leaf.
    """
    if per_leaf:
        thrs = pytree_quantile_per_leaf(tree, q)
        thrs = jax.tree.map(lambda t: jnp.maximum(t, min_scale), thrs)
        clipped = jax.tree.map(
            lambda g, t: jnp.clip(g, -t.astype(g.dtype), t.astype(g.dtype)),
            tree, thrs)
        return clipped, thrs
    thr = pytree_quantile(tree, q, maxit=maxit)
    thr = jnp.maximum(thr, min_scale)
    clipped = jax.tree.map(
        lambda g: jnp.clip(g, -thr.astype(g.dtype), thr.astype(g.dtype)),
        tree)
    return clipped, thr
