"""Selection (k-th order statistic) by convex minimization — Beliakov (2011).

Batched-first, measure-unified architecture
-------------------------------------------
The engine is *batched-first*: the bracket loop, the exact-hit certificates
and the hybrid finalize all operate on ``(B,)`` state vectors, fed by an
:class:`repro.core.objective.Evaluator` (pivots ``(B,)`` -> :class:`FG`
partials ``(B,)``).  Scalar selection is the ``B = 1`` view.  Two batched
regimes:

* **rows mode** (:func:`select_rows` / :func:`weighted_select_rows`) —
  ``(B, n)`` independent problems with per-row targets, driven by the
  row-wise fused kernels.  This is the production workload: coordinate-wise
  medians, LMS/LTS concentration over elemental starts, kNN cutoff rows,
  Theil-Sen / IRLS weighted medians.
* **shared-x mode** (:func:`multi_order_statistic` / :func:`quantiles` and
  the weighted variants) — ONE array, ``(K,)`` targets, driven by the
  multi-pivot Pallas kernels that read each ``x`` tile into VMEM once and
  emit partials for all K live pivots — K× less HBM traffic than K
  lock-stepped independent solves.

There is ONE engine for counts and weights (see ``objective.py``): the
loops compare the evaluator's measure fields (``m_lt``/``m_le`` — int32
counts on the counting leg, fp weight masses on the weighted leg) against
the target measure ``k``, while the int32 element counts keep driving the
cap-based stopping rule on both legs (buffer capacity is a count, not a
mass).  Uniform weights with ``wk = k`` make every mass comparison an exact
integer-valued comparison, reproducing the counting decisions bit for bit —
weighted selection is not a second code path, and the counting leg still
rides the smaller four-partial kernels (no weights array read from HBM).

Methods (shared skeleton, they differ only in the next-pivot proposal):

* ``binned``    — binned bracket descent (default for large n): each data
  pass histograms the live bracket into ``nbins`` sub-intervals, so one
  sweep buys log2(nbins) bisection-equivalents of narrowing (Tibshirani's
  successive-binning, arXiv:0806.3301, generalized to any order statistic,
  any weight measure, and to batched/sharded data).  Phase 1 runs ~2-3
  histogram sweeps until every row's in-bracket count is under ``cap``;
  phase 2 compacts the survivors into the ``(B, cap)`` buffer and finalizes
  exactly — O(cap) work on O(n) data touched ~3 times instead of ~15.
* ``binned_polish`` — binned descent + in-bin CP polish: every sweep
  centers half its bins geometrically around the cutting-plane cut derived
  from the PREVIOUS sweep's per-bin sums (the support-line intersection
  inside the straddling bin — see :func:`binned_loop_batched`), so the
  next sweep resolves the answer's neighborhood at ~2^-30 of the bracket
  instead of 1/nbins.  Fewer sweeps on hard mass distributions, same
  certificates: the polish only chooses WHERE the realized edges go; every
  narrowing decision still runs through the measured-count invariants.
* ``cp``        — Kelley's cutting-plane method (Algorithm 1 of the paper).
* ``bisection`` — classical bisection on the subgradient sign (paper Sec. III).
* ``golden``    — golden-section-style bracket shrink (paper baseline).
* ``brent``     — parabolic fit with bisection safeguard (paper baseline).
* ``sort``      — full ``jnp.sort`` (the paper's "GPU radix sort" baseline).

Each iteration costs exactly one fused pass over the data — the paper's
``maxit + O(1)`` parallel reductions — regardless of how many problems ride
in the batch; ``binned`` needs ~3 such passes where ``cp`` needs ~15.
``method=None`` (the default) resolves to ``binned`` for
``n >= BINNED_MIN_N`` on EVERY backend: the Pallas kernels bin in-register
(a sweep costs the same HBM traffic as an FG pass), and the jnp path's
verified arithmetic binning (``kernels.ref.bin_slots``: multiply/floor/clip
slots checked against the realized edges, factored one-hot reduction)
brought the CPU sweep from ~25-70x a fused pass down to ~2-4x (below one
cp engine-iteration at engine granularity) — so 2-3 sweeps beat ~9 cp
passes end-to-end at 1M where binned used to lose 10x — see
``_resolve_method`` / ``_resolve_nbins`` and BENCH_selection.json.

Exactness: unlike the paper (which stops on a float tolerance and then scans
for the largest ``x_i <= y~``), we carry the measures through the loop PER
ROW, which yields

  1. an *exact-hit* certificate ``m_lt < k <= m_le  =>  pivot == x_(k)``;
  2. a count-based stopping rule ``count(y_L < x <= y_R) <= cap`` that turns
     the paper's dynamic-size ``copy_if`` into a *static-shape* fixed-capacity
     compaction (required for ``jit``), performed row-wise into a
     ``(B, cap)`` buffer sorted in one batched sort;
  3. a tie-safe fallback: if more than ``cap`` duplicates of ``x_(k)`` exist
     in a row, the next distinct value above that row's ``y_L`` is verified
     by one extra counting pass.

Rows stop independently (per-row live mask); the loop exits when every row
has either certified an exact hit or shrunk its pivot interval under ``cap``.

Invariants maintained per row (proved by the subdifferential signs, see
``objective.py``):   measure(x <= y_L) < k <= measure(x <= y_R).

fp contract for the weighted leg: masses accumulate in floating point, so
results are bit-identical to the f64 sorted-cumsum oracle exactly when the
weights are exactly summable (integers / bounded dyadics, incl. uniform ==
the counting engine bit-for-bit); otherwise the answer is a data element
certified by the engine's own measured invariant, within one mass-rounding
of the oracle.  The late-sweep ``hit_lo`` binned certificate is demoted to
a stall (only sweep 1 may pin ``xmin``): with inexact masses an ulp-flip
could otherwise mint a non-element edge value — on the counting leg the
demotion is provably dead code (exact integer prefix counts make a late
fire impossible), so the one gate serves both legs.

``transform='log1p'`` and the batched finalize: the loop runs on the
monotone image ``F(x) = log1p(x - min(x))`` (per row in rows mode), and the
final bracket is mapped back to original values *data-consistently* before
the exact finalize — ``y_orig = max{x_i : F(x_i) <= y_t}`` preserves counts
exactly, so the row invariants transfer and the compaction/tie logic runs on
untransformed data.  Exact-hit certificates do NOT survive the fp roundtrip
(F is not injective in fp): they are dropped per row and re-derived by the
original-space finalize.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objective import (
    FG,
    Evaluator,
    RowsEvaluator,
    SharedEvaluator,
    _weight_accum_dtype,
    os_weights,
)
from repro.core import transforms

METHODS = ("binned", "binned_polish", "cp", "cp_hybrid", "bisection",
           "golden", "brent", "sort")

# method=None resolution: histogram sweeps win once the O(n) data pass
# dominates (~3 sweeps vs ~15 CP passes); below this the per-sweep bin
# bookkeeping isn't worth it and Kelley cuts converge in microseconds.
BINNED_MIN_N = 1 << 16

# Sub-intervals per histogram sweep on the Pallas kernel path (one sweep =
# log2(128) = 7 bisection-equivalents of bracket narrowing); the kernels
# take the bin count from the edge array the engine builds.
DEF_NBINS = 128

# jnp-path default: the verified-arithmetic histogram's factored one-hot
# reduction scales with the slot count, and a 16-bin sweep (4 bisection
# equivalents) already resolves 1M -> cap in 2 sweeps — the CPU-measured
# knee (see BENCH_selection.json hist_pass).
DEF_NBINS_JNP = 16

BINNED_IMPLS = (None, "searchsorted", "arithmetic")


def _kernel_path(backend: Optional[str]) -> bool:
    from repro.kernels.ops import _on_tpu  # deferred: core <-> kernels

    return backend in ("pallas", "pallas_interpret") or (
        backend is None and _on_tpu())


def _resolve_method(method: Optional[str], n: int,
                    backend: Optional[str] = None) -> str:
    """``None``/``'auto'`` -> 'binned' for large n on EVERY backend.

    The binned descent is a bandwidth trade: each sweep touches the data
    once but buys log2(nbins) bisection steps.  On the Pallas kernel path a
    sweep costs the same HBM traffic as a fused FG pass; on the CPU jnp
    path the verified arithmetic-binning pass (multiply/floor/clip slots +
    factored one-hot reduction, see ``kernels.ref.bin_slots``) brought the
    sweep from ~25-70x a fused pass down to ~2-4x at 1M
    (BENCH_selection.json, ``hist_pass``), so 2-3 sweeps beat ~9 cp
    passes end-to-end (binned used to lose ~10x on CPU) and auto picks
    'binned' everywhere above ``BINNED_MIN_N`` — the schedule whose pass
    count scales as log(nbins) per data touch.  Auto stays on plain
    'binned' (not 'binned_polish') until the polish schedule is
    TPU-validated (see ROADMAP).
    """
    if method in (None, "auto"):
        return "binned" if n >= BINNED_MIN_N else "cp"
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; one of {METHODS}")
    return method


def _resolve_nbins(nbins: Optional[int], backend: Optional[str],
                   dtype=None) -> int:
    """``None`` -> the backend-tuned sweep width: ``DEF_NBINS`` (128) where
    the histogram kernels bin in-register (slot count is nearly free),
    ``DEF_NBINS_JNP`` (16) on the jnp path where the factored reduction's
    cost is ~linear in the slot count.  Both resolve 1M -> cap in 2 sweeps;
    explicit values always win.

    ``dtype``: the data's (promoted) dtype — f64 inputs are rerouted by
    ``kernels.ops`` to the jnp oracle even when the kernel path was
    requested (``pallas_interpret`` deliberately excepted), so their
    sweeps get the jnp-tuned width too.
    """
    if nbins is not None:
        return int(nbins)
    kernel = _kernel_path(backend)
    if (kernel and backend != "pallas_interpret" and dtype is not None
            and jnp.dtype(dtype) == jnp.float64):
        kernel = False  # the f64 reroute lands this pass on the jnp oracle
    return DEF_NBINS if kernel else DEF_NBINS_JNP


def _check_binned_impl(binned_impl: Optional[str]) -> Optional[str]:
    if binned_impl not in BINNED_IMPLS:
        raise ValueError(f"unknown binned_impl {binned_impl!r}; one of "
                         f"{BINNED_IMPLS}")
    return binned_impl

# Status codes for SelectResult.status
EXACT_HIT = 0       # pivot certified equal to x_(k) during iterations
HYBRID_SORT = 1     # answer from compact+sort of the pivot interval
TIE_FALLBACK = 2    # answer = next distinct value, certified by counts
NOT_CONVERGED = 3   # approximate answer (bracket right end)


class SelectResult(NamedTuple):
    value: jax.Array        # the order statistic (exact unless status==3)
    iters: jax.Array        # number of f/g evaluations this row was live for
    status: jax.Array       # see codes above
    y_lo: jax.Array         # final bracket
    y_hi: jax.Array
    n_in: jax.Array         # count(y_lo < x <= y_hi) at exit


class Prior(NamedTuple):
    """Warm-start carry for repeated selection (``prior=`` on every public
    API): the previous answer, its realized bracket, and the last polish
    cut.  All fields are arrays broadcastable to the solve's batch shape
    ((B,) rows / (K,) shared-x / scalar distributed).

    The prior steers only the FIRST pivot (cp family) or the FIRST sweep's
    edge PLACEMENT (binned family, :func:`prior_edges`) — exactly the
    polish-cut contract: every narrowing decision and every certificate
    still runs off measured prefix invariants, so a stale, garbage, NaN or
    wrong-array prior costs sweeps (or a psum round), never exactness.
    Build one from a previous :class:`SelectResult` with :func:`as_prior`
    (also accepted directly as the ``prior=`` argument)."""
    value: jax.Array   # previous answer
    y_lo: jax.Array    # realized final bracket, reused verbatim as edges
    y_hi: jax.Array
    cut: jax.Array     # last polish cut (seeds the carried in-bin CP cut)


def as_prior(prior) -> Optional["Prior"]:
    """Normalize a ``prior=`` argument: ``None`` | :class:`Prior` |
    :class:`SelectResult` (the natural carry — bracket reused verbatim,
    the answer doubles as the cut) | bare value (answer-only seed)."""
    if prior is None:
        return None
    if isinstance(prior, Prior):
        return prior
    if isinstance(prior, SelectResult):
        return Prior(value=prior.value, y_lo=prior.y_lo, y_hi=prior.y_hi,
                     cut=prior.value)
    v = jnp.asarray(prior)
    return Prior(value=v, y_lo=v, y_hi=v, cut=v)


class BatchState(NamedTuple):
    """Bracket-loop state; every field is (B,)-shaped except the scalar
    global iteration counter ``it`` (frozen rows stop updating but the batch
    iterates until all rows are done)."""
    yL: jax.Array
    fL: jax.Array
    gL: jax.Array   # right one-sided derivative at yL (< 0)
    yR: jax.Array
    fR: jax.Array
    gR: jax.Array   # left one-sided derivative at yR (> 0)
    cleL: jax.Array  # lower bound on count(x <= yL)  (exact after 1st move)
    cleR: jax.Array  # exact count(x <= yR)
    t_exact: jax.Array
    found_exact: jax.Array
    iters: jax.Array  # per-row live-iteration count
    it: jax.Array     # global (batch) iteration count
    # golden/brent bookkeeping: previous probe (for parabolic fit); the
    # binned polish reuses it as the carried in-bin CP cut
    tp: jax.Array
    fp: jax.Array


def _propose_cp(s: BatchState):
    """Kelley cut intersection: minimizer of max of the two support lines."""
    return (s.fR - s.fL + s.yL * s.gL - s.yR * s.gR) / (s.gL - s.gR)


def _propose_bisection(s: BatchState):
    return 0.5 * (s.yL + s.yR)


_INV_GOLDEN = 0.381966011250105  # 2 - golden ratio


def _propose_golden(s: BatchState):
    # Shrink from the side whose objective value is larger (descent side).
    left = s.fL > s.fR
    w = jnp.where(left, _INV_GOLDEN, 1.0 - _INV_GOLDEN)
    return s.yL + w * (s.yR - s.yL)


def _propose_brent(s: BatchState):
    """Parabola through (yL,fL), (tp,fp), (yR,fR); midpoint safeguard."""
    x1, f1, x2, f2, x3, f3 = s.yL, s.fL, s.tp, s.fp, s.yR, s.fR
    num = (x2 - x1) ** 2 * (f2 - f3) - (x2 - x3) ** 2 * (f2 - f1)
    den = (x2 - x1) * (f2 - f3) - (x2 - x3) * (f2 - f1)
    ok = jnp.abs(den) > 1e-30
    t = x2 - 0.5 * num / jnp.where(ok, den, 1.0)
    mid = 0.5 * (s.yL + s.yR)
    inside = (t > s.yL) & (t < s.yR)
    return jnp.where(ok & inside, t, mid)


_PROPOSALS = {
    "cp": _propose_cp,
    "cp_hybrid": _propose_cp,
    "bisection": _propose_bisection,
    "golden": _propose_golden,
    "brent": _propose_brent,
}


def _live(s: BatchState, cap):
    return (~s.found_exact) & (s.cleR - s.cleL > cap) & (s.yR > s.yL)


def _seed_state(ev: Evaluator, found0, t0):
    """Shared loop seed: analytic bracket/cut init from one stats pass.

    Returns ``(s0, xmin, xmax, kk, dtype)``; used by both the cutting-plane
    loop and the binned histogram loop (the f/g fields seed the former's
    cuts and the polish's first in-bin jump).

    Counting leg: the slopes use the paper's normalized weights with the
    conservative tie count 1, which keeps the support lines *lower* bounds
    (valid cuts) even with duplicated extremes.  Weighted leg: the
    mass-normalized coefficients ``alpha = (W - wk)/W`` and ``beta = wk/W``
    (zero-crossing exactly at mass ``wk``) with the conservative extreme
    slopes ``-wk/W`` / ``(W - wk)/W`` (no mass assumed at the extremes —
    flatter than the truth, so the support lines stay lower bounds); ``f``
    seeds anchor on the weighted mean.
    """
    xmin, xmax, xmean = ev.init_stats()
    k = ev.k
    shape = jnp.broadcast_shapes(jnp.shape(xmin), jnp.shape(k))
    dtype = xmin.dtype
    kk = jnp.broadcast_to(jnp.asarray(k), shape)
    bc = lambda v: jnp.broadcast_to(jnp.asarray(v, dtype), shape)
    xmin, xmax, xmean = bc(xmin), bc(xmax), bc(xmean)

    if ev.weighted:
        Wf = jnp.broadcast_to(jnp.asarray(ev.W, kk.dtype), shape)
        Wsafe = jnp.maximum(Wf, jnp.asarray(1e-30, Wf.dtype))
        alpha = ((Wf - kk) / Wsafe).astype(dtype)
        beta = (kk / Wsafe).astype(dtype)
        gL0, gR0 = -beta, alpha
    else:
        nf = jnp.broadcast_to(jnp.asarray(ev.n, dtype), shape)
        alpha, beta = os_weights(nf, kk, dtype)
        gL0 = alpha * (1.0 / nf) - beta * (nf - 1.0) / nf
        gR0 = alpha * (nf - 1.0) / nf - beta * (1.0 / nf)

    # Analytic init at the extremes (paper: single fused reduction).
    fL0 = beta * (xmean - xmin)
    fR0 = alpha * (xmax - xmean)

    if found0 is None:
        found0 = jnp.zeros(shape, bool)
    if t0 is None:
        t0 = jnp.full(shape, jnp.nan, dtype)
    s0 = BatchState(
        yL=xmin, fL=fL0, gL=gL0,
        yR=xmax, fR=fR0, gR=gR0,
        cleL=jnp.ones(shape, jnp.int32),   # count(x<=min) >= 1 (conservative)
        cleR=jnp.broadcast_to(jnp.asarray(ev.n, jnp.int32), shape),
        t_exact=t0,
        found_exact=jnp.broadcast_to(found0, shape),
        iters=jnp.zeros(shape, jnp.int32),
        it=jnp.asarray(0, jnp.int32),
        tp=0.5 * (xmin + xmax), fp=jnp.maximum(fL0, fR0),
    )
    return s0, xmin, xmax, kk, dtype


def bracket_loop_batched(
    ev: Evaluator,
    *,
    method: str = "cp",
    maxit: int = 64,
    cap=0,
    found0: Optional[jax.Array] = None,
    t0: Optional[jax.Array] = None,
    prior: Optional[Prior] = None,
):
    """Run the batched bracket-shrinking loop against an evaluator.

    ``ev`` owns the data AND the measure (counts or weight masses — see
    ``objective.py``); this loop only sees ``(B,)`` vectors and compares
    the returned measure fields against the target ``ev.k``:

    * ``m_lt < k <= m_le`` certifies the pivot as the (weighted) order
      statistic (on the counting leg this is the classic count invariant;
      on the weighted leg ``m_lt < m_le`` forces positive mass at the
      pivot, so a certified pivot is a data element);
    * ``m_le < k`` means the pivot is strictly left of the minimizer
      (``== g_hi < 0`` in exact arithmetic, but compared in the measure's
      own dtype — exact int32 on the counting leg).

    ``cap`` is the per-row stopping count (0 = iterate to exact hit /
    maxit, the distributed across-axis regime); ``cleL``/``cleR`` carry
    INTEGER counts on both legs — the compaction buffer is sized in
    elements, not mass.  ``found0``/``t0`` pre-seed rows whose answer is
    already certified (e.g. extreme ranks) so they never go live.

    ``prior``: warm-start carry — the prior answer overrides the FIRST
    proposal only, and only where it is finite and strictly inside the
    open bracket; the measured partials decide every move, so an exact
    prior certifies in one pass and a wrong one costs passes, never
    exactness.

    Returns ``(final BatchState, xmin, xmax)`` with per-row extremes.
    """
    propose = _PROPOSALS[method]
    s0, xmin, xmax, kk, dtype = _seed_state(ev, found0, t0)
    pv0 = None
    if prior is not None:
        pv0 = jnp.broadcast_to(jnp.asarray(prior.value, dtype),
                               s0.yL.shape)

    def cond(s: BatchState):
        return (s.it < maxit) & jnp.any(_live(s, cap))

    def body(s: BatchState):
        lv = _live(s, cap)
        t = propose(s)
        # numerical safeguard: keep strictly inside the open bracket (frozen
        # rows get the midpoint — their updates are masked out anyway)
        bad = ~jnp.isfinite(t) | (t <= s.yL) | (t >= s.yR)
        t = jnp.where(bad, 0.5 * (s.yL + s.yR), t).astype(dtype)
        if pv0 is not None:
            use = ((s.it == 0) & jnp.isfinite(pv0)
                   & (pv0 > s.yL) & (pv0 < s.yR))
            t = jnp.where(use, pv0, t)
        fg: FG = ev(t)
        exact = (fg.m_lt < kk) & (kk <= fg.m_le) & lv
        # exact => 0 in [g_lo, g_hi] => g_hi >= 0, so the two are disjoint:
        move_left = (fg.m_le < kk) & lv  # t strictly left of the minimizer
        move_right = lv & ~move_left & ~exact  # then m_lt >= k: right of it
        return BatchState(
            yL=jnp.where(move_left, t, s.yL),
            fL=jnp.where(move_left, fg.f, s.fL),
            gL=jnp.where(move_left, fg.g_hi, s.gL),
            yR=jnp.where(move_right, t, s.yR),
            fR=jnp.where(move_right, fg.f, s.fR),
            gR=jnp.where(move_right, fg.g_lo, s.gR),
            cleL=jnp.where(move_left, fg.n_le, s.cleL),
            cleR=jnp.where(move_right, fg.n_le, s.cleR),
            t_exact=jnp.where(exact, t, s.t_exact),
            found_exact=s.found_exact | exact,
            iters=s.iters + lv.astype(jnp.int32),
            it=s.it + 1,
            tp=jnp.where(lv, t, s.tp), fp=jnp.where(lv, fg.f, s.fp),
        )

    return jax.lax.while_loop(cond, body, s0), xmin, xmax


def binned_descent_step(cum, edges, yL, yR, kk):
    """One binned-descent narrowing decision from prefix measures.

    ``cum[..., j] = measure(x <= e_j)`` at the realized ``edges``
    ``(..., nbins+1)`` of the bracket ``[yL, yR]`` (leading dims = batch,
    possibly none) — int32 prefix counts on the counting leg, fp prefix
    masses on the weighted leg (the comparisons below are ordering-only,
    so both take the same path); ``edges`` MUST be the same array the
    histogram pass binned against — it is computed once per sweep and
    shared, never recomputed (XLA FMA contraction makes recomputed edge
    arithmetic fusion-context-dependent).  Returns
    ``(yLn, yRn, cLn, cRn, jm1, jstar, hit_lo, exact, stall)``:

    * ``jstar`` — first edge whose prefix measure reaches ``kk``; the
      answer lies in the single bin ``(e_{jstar-1}, e_jstar]``;
    * ``hit_lo`` — ``jstar == 0``, i.e. ``measure(x <= yL) >= k``: possible
      only while ``yL`` is the initial minimum (afterwards the invariant
      ``measure(x <= yL) < k`` forbids it), and certifies ``x_(k) == yL``;
    * ``exact`` — ``hit_lo`` or ulp-collapse: ``(yLn, yRn]`` holds a single
      representable value, so the invariant certifies ``x_(k) == yRn``;
    * ``stall`` — the chosen bin IS the whole bracket (bin width underflowed
      against denormal-scale data), or the prefix measures are inconsistent
      with the bracket invariant (``cum[-1] < k`` — NaN data, a kernel
      miscount): no trustworthy progress is possible, the caller should
      freeze this problem and let its finalize fallback resolve it.

    This is the exactness-critical core of the binned method, shared by the
    batched loop below and the distributed loop in ``core.distributed`` —
    keep it the single implementation.
    """
    reached = cum >= kk[..., None]
    jstar = jnp.argmax(reached, axis=-1).astype(jnp.int32)
    jm1 = jnp.maximum(jstar - 1, 0)
    take = lambda a, i: jnp.take_along_axis(a, i[..., None], axis=-1)[..., 0]
    yLn, yRn = take(edges, jm1), take(edges, jstar)
    cLn, cRn = take(cum, jm1), take(cum, jstar)
    # measure-invariant sanity: measure(x <= yR) >= k must hold; if it
    # doesn't, argmax over all-False returned 0 and NOTHING below may
    # certify — a violated invariant must fail safe (stall), never mint
    # EXACT_HIT.
    ok = reached[..., -1]
    hit_lo = (jstar == 0) & reached[..., 0]
    collapse = transforms.next_float(yLn) >= yRn
    exact = (hit_lo | collapse) & ok
    stall = ~exact & (~ok | ((yLn == yL) & (yRn == yR)))
    return yLn, yRn, cLn, cRn, jm1, jstar, hit_lo, exact, stall


def polish_edges(lo, hi, t, nbins: int):
    """CP-centered realized bin edges for one polish sweep.

    Half the edges cover ``[lo, hi]`` uniformly (worst-case factor
    ``nbins/2`` shrink, exactly like a plain sweep with fewer bins); the
    other half sit geometrically around the carried cut ``t`` at offsets
    ``halfwidth * 2^-j`` down to ``~2^-(nbins/4)`` of the bracket — when
    ``t`` is near the answer (it is: ``t`` is the in-bin support-line
    intersection of the previous sweep), the straddling bin comes out
    orders of magnitude narrower than ``1/nbins`` of the bracket.

    Exactness is inherited, not re-proven: the output is a monotone
    (sorted) array of realized fp values in ``[lo, hi]`` with
    ``e_0 == lo`` and ``e_nbins == hi`` exactly, built ONCE per sweep and
    shared by the histogram pass and the narrowing decision — the same
    contract as ``kernels.ref.bin_edges``, which supplies the uniform
    half.  A garbage cut (NaN / out of bracket) degrades to the bracket
    midpoint; the certificates never trust the cut itself.  The endpoint
    anchoring is pinned AFTER the sort: on FTZ hardware a denormal-scale
    bracket makes the ladder values compare DAZ-equal, and the sort may
    otherwise scramble which bit pattern lands at the ends (every value is
    already clipped into ``[lo, hi]``, so the pin preserves the platform
    ordering).
    """
    from repro.kernels.ref import bin_edges  # deferred: core <-> kernels

    lo = jnp.asarray(lo)
    hi = jnp.asarray(hi, lo.dtype)
    nu = nbins // 2
    m = (nbins - nu) // 2
    extra = nbins - nu - 2 * m
    base = bin_edges(lo, hi, nu)                       # (..., nu + 1)
    mid = 0.5 * lo + 0.5 * hi
    t = jnp.asarray(t, lo.dtype)
    tc = jnp.clip(jnp.where(jnp.isfinite(t), t, mid), lo, hi)
    half = hi / 2 - lo / 2   # overflow-safe half-width (divide BEFORE diff)
    j = jnp.arange(1, m + 1, dtype=lo.dtype)
    d = half[..., None] * jnp.asarray(2.0, lo.dtype) ** (-j)
    lo1, hi1 = lo[..., None], hi[..., None]
    ladder = jnp.concatenate(
        [jnp.clip(tc[..., None] - d, lo1, hi1),
         jnp.clip(tc[..., None] + d, lo1, hi1)], axis=-1)
    parts = [base, ladder]
    if extra:
        parts.append(jnp.broadcast_to(tc[..., None], tc.shape + (extra,)))
    e = jnp.sort(jnp.concatenate(parts, axis=-1), axis=-1)
    return e.at[..., 0].set(lo).at[..., -1].set(hi)


def prior_edges(lo, hi, prior: Prior, nbins: int):
    """Prior-seeded realized bin edges for the FIRST sweep of a warm solve.

    Layout (``nbins + 1`` edges total, same realized-edges contract as
    :func:`polish_edges` — sorted, clipped into ``[lo, hi]``, endpoints
    pinned after the sort, built ONCE and shared by the histogram pass and
    the narrowing decision):

    * half the edges cover ``[lo, hi]`` uniformly — the worst-case
      guarantee: a garbage prior still buys a factor ``nbins/2`` shrink;
    * the prior's realized bracket endpoints ``y_lo``/``y_hi`` are placed
      VERBATIM — when the data is unchanged, the carried bracket's
      in-bracket count is already under cap, so the sweep-1 straddling bin
      lands inside it and the row stops after ONE sweep;
    * the pair ``(prev_float(value), value)`` — an unchanged answer makes
      the straddling bin a single-representable-value bin, so the existing
      ulp-collapse certificate in :func:`binned_descent_step` fires:
      steady-state re-selection is 1 sweep WITH an exact-hit certificate;
    * the rest is a geometric ladder around ``value`` at offsets
      ``w0 * 2^j`` with ``w0 = max(y_hi - y_lo, 1 ulp)`` — small drift
      lands in a bin about one prior-bracket wide (still ~cap elements).

    Soundness is inherited, not re-proven: like the polish cut, the prior
    chooses WHERE edges go; NaN/inf fields degrade to the bracket midpoint
    and every certificate runs off measured prefix measures.
    """
    from repro.kernels.ref import bin_edges  # deferred: core <-> kernels

    lo = jnp.asarray(lo)
    hi = jnp.asarray(hi, lo.dtype)
    dt = lo.dtype
    mid = 0.5 * lo + 0.5 * hi
    san = lambda v: jnp.clip(
        jnp.where(jnp.isfinite(v), jnp.asarray(v, dt), mid), lo, hi)
    pv = san(jnp.asarray(prior.value, dt))
    plo = san(jnp.asarray(prior.y_lo, dt))
    phi = san(jnp.asarray(prior.y_hi, dt))
    nu = max(nbins // 2, 1)
    r = nbins - nu
    base = bin_edges(lo, hi, nu)                       # (..., nu + 1)
    sharp = [pv, jnp.clip(transforms.prev_float(pv), lo, hi), plo, phi][:r]
    m = (r - len(sharp)) // 2
    extra = r - len(sharp) - 2 * m
    parts = [base]
    if sharp:
        parts.append(jnp.stack(jnp.broadcast_arrays(*sharp), axis=-1))
    if m > 0:
        fmax = jnp.asarray(jnp.finfo(dt).max, dt)
        w0 = jnp.maximum(phi - plo, transforms.next_float(pv) - pv)
        w0 = jnp.clip(w0, jnp.asarray(jnp.finfo(dt).tiny, dt), fmax)
        j = jnp.arange(m, dtype=dt)
        d = jnp.clip(w0[..., None] * jnp.asarray(2.0, dt) ** j, 0, fmax)
        lo1, hi1 = lo[..., None], hi[..., None]
        parts.append(jnp.clip(pv[..., None] - d, lo1, hi1))
        parts.append(jnp.clip(pv[..., None] + d, lo1, hi1))
    if extra:
        parts.append(jnp.broadcast_to(pv[..., None], pv.shape + (extra,)))
    e = jnp.sort(jnp.concatenate(parts, axis=-1), axis=-1)
    return e.at[..., 0].set(lo).at[..., -1].set(hi)


def binned_loop_batched(
    ev: Evaluator,
    *,
    nbins: int = DEF_NBINS,
    maxit: int = 16,
    cap=0,
    found0: Optional[jax.Array] = None,
    t0: Optional[jax.Array] = None,
    polish: bool = False,
    prior: Optional[Prior] = None,
):
    """Phase 1 of the binned two-phase schedule: histogram bracket descent.

    Each sweep builds the bracket's realized edges once
    (``kernels.ref.bin_edges``; :func:`polish_edges` when ``polish``),
    calls ``ev.histogram(edges)`` — ONE fused data pass — and narrows every
    live row's bracket to the single sub-interval ``(e_{j-1}, e_j]`` whose
    prefix MEASURE straddles that row's target
    (``measure(x <= e_{j-1}) < k <= measure(x <= e_j)``), a factor-``nbins``
    shrink per pass where the cutting-plane loop gets one pivot.  The
    measure is the evaluator's: int32 counts or fp weight masses — the
    narrowing decision (:func:`binned_descent_step`) is ordering-only, so
    both legs take the same path and the fail-safe certificate gates carry
    over verbatim.  Integer prefix counts at the chosen edges keep feeding
    the cap-based stopping rule on both legs.  Rows stop independently once
    their in-bracket count is under ``cap`` (phase 2, the survivor
    compaction + exact finalize, takes over), on the exact certificates
    below, or at ``maxit``.

    Exactness bookkeeping mirrors the cutting-plane loop: brackets only move
    to REALIZED fp edge values whose prefix measures were measured, so the
    row invariant ``measure(x <= yL) < k <= measure(x <= yR)`` holds exactly
    at every step and transfers to the finalize (and across the log1p
    roundtrip).  Two in-loop certificates short-circuit a row: a first-sweep
    ``measure(x <= xmin) >= k`` pins ``x_(k) = xmin``, and a bracket
    collapsed to one representable value ``(yL, nextafter(yL)]`` pins
    ``x_(k) = yR``.  A LATE ``hit_lo`` is demoted to a stall: with inexact
    masses it can only be a summation-order ulp-flip (the invariant forbids
    it in exact arithmetic) and must never mint a non-element edge value;
    on the counting leg the exact integer prefix counts make a late fire
    impossible, so the one gate serves both legs for free.

    The in-bin CP polish (``polish=True``): the histogram pass already
    emits per-slot sums ``Σ (w·)x``, so the convex objective's support
    lines at the straddling bin's edges come free — with prefix measures
    ``M`` and prefix sums ``S``, the support line anchored at edge ``e`` is
    ``ψ(e) + (M(e) - k)·(y - e)`` with ``ψ(e) = e·M(e) - S(e) - k·e``
    (+const), and the Kelley intersection of the two bin-edge lines
    algebraically collapses to the bin's mass centroid
    ``(S_R - S_L)/(M_R - M_L) = Σ_bin w·x / Σ_bin w``.  The loop carries
    that cut (seeded from the analytic extreme cuts before sweep 1) and
    hands it to :func:`polish_edges`, so the NEXT sweep already has
    near-ulp resolution around the minimizer — typically saving the last
    uniform sweep.  The cut steers only edge PLACEMENT; every certificate
    still runs off measured prefix invariants, so a bad cut costs a sweep,
    never exactness.

    ``prior`` (warm start): sweep 1's edges come from :func:`prior_edges`
    instead of the uniform/polish layout — the prior's realized bracket
    endpoints are reused verbatim and the ``(prev_float(value), value)``
    pair makes an unchanged answer collapse-certify in exactly one sweep;
    the prior's carried cut also seeds ``tp`` (overriding the analytic
    polish seed).  Same contract as the polish cut: placement only.

    Returns ``(BatchState, xmin, xmax)`` like :func:`bracket_loop_batched`;
    the f/g cut fields keep their analytic seeds (only the polish seed
    reads them), and ``iters`` counts histogram sweeps.
    """
    from repro.kernels.ref import bin_edges  # deferred: core <-> kernels

    s0, xmin, xmax, kk, dtype = _seed_state(ev, found0, t0)
    # Brackets narrow to realized fp edge values and the finalize recounts
    # against exactly those values, so the loop state must not round edges
    # through a storage dtype below the kernels' f32 accumulation (bf16
    # data would otherwise round yL up and break the count invariant).
    dt = jnp.promote_types(dtype, jnp.float32)
    s0 = s0._replace(yL=s0.yL.astype(dt), yR=s0.yR.astype(dt),
                     t_exact=s0.t_exact.astype(dt), tp=s0.tp.astype(dt))
    if polish:
        # seed the carried cut with the analytic CP intersection so even
        # sweep 1 concentrates half its bins near the expected minimizer
        t_seed = _propose_cp(s0)
        bad = ~jnp.isfinite(t_seed) | (t_seed <= s0.yL) | (t_seed >= s0.yR)
        s0 = s0._replace(
            tp=jnp.where(bad, 0.5 * (s0.yL + s0.yR), t_seed).astype(dt))
    pb = None
    if prior is not None:
        pb = Prior(*(jnp.broadcast_to(jnp.asarray(f, dt), s0.yL.shape)
                     for f in prior))
        # the prior's carried cut beats the analytic seed where usable
        okc = jnp.isfinite(pb.cut) & (pb.cut > s0.yL) & (pb.cut < s0.yR)
        s0 = s0._replace(tp=jnp.where(okc, pb.cut, s0.tp))
    stalled0 = jnp.zeros(s0.found_exact.shape, bool)

    def live(s, stalled):
        return _live(s, cap) & ~stalled

    def cond(carry):
        s, stalled = carry
        return (s.it < maxit) & jnp.any(live(s, stalled))

    def body(carry):
        s, stalled = carry
        lv = live(s, stalled)
        # the realized edges are computed ONCE here and shared by the data
        # pass and the narrowing decision (the exactness contract)
        if polish:
            edges = polish_edges(s.yL, s.yR, s.tp, nbins)
        else:
            edges = bin_edges(s.yL, s.yR, nbins)
        if pb is not None:
            # warm start: sweep 1 places its edges from the prior (the
            # realized carried bracket verbatim + the collapse pair around
            # the prior answer); later sweeps revert to the normal layout
            edges = jnp.where(s.it == 0,
                              prior_edges(s.yL, s.yR, pb, nbins), edges)
        cnt, mass, msum = ev.histogram(edges, need_msum=polish)
        # prefix measures at the realized edges drive the narrowing:
        # cum[..., j] = measure(x <= e_j)
        cum = jnp.cumsum(mass[..., :-1], axis=-1)
        yLn, yRn, cLm, cRm, jm1, jstar, hit_lo, exact, stall = \
            binned_descent_step(cum, edges, s.yL, s.yR, kk)
        take = lambda a, i: jnp.take_along_axis(
            a, i[..., None], axis=-1)[..., 0]
        if mass is cnt:
            # counting leg: the prefix measures ARE the integer counts
            cLn, cRn = cLm, cRm
        else:
            # integer prefix counts at the same edges feed the cap rule
            cumn = jnp.cumsum(cnt[..., :-1], axis=-1)
            cLn, cRn = take(cumn, jm1), take(cumn, jstar)
        # late hit_lo can only be an inexact-mass ulp-flip: fail safe (dead
        # code on the counting leg — exact prefixes cannot fire it late)
        late_hit_lo = hit_lo & (s.it > 0)
        exact = lv & exact & ~late_hit_lo
        t_ex = jnp.where(hit_lo, s.yL, yRn)
        # stalled rows freeze; the finalize's fallback chain resolves them
        # from the current bracket instead of burning sweeps to maxit
        stall_n = lv & (stall | late_hit_lo)
        upd = lv & ~exact & ~stall_n
        if polish:
            if msum is None:
                raise ValueError(
                    "binned polish needs the per-bin sums; this evaluator's "
                    "histogram pass returns msum=None")
            # the in-bin support-line intersection == the straddling bin's
            # mass centroid (see the docstring); guard degenerate bins
            mbin = take(mass, jstar).astype(msum.dtype)
            sbin = take(msum, jstar)
            tcut = sbin / jnp.where(mbin > 0, mbin, 1)
            good = (mbin > 0) & jnp.isfinite(tcut)
            tcut = jnp.where(good, jnp.clip(tcut, yLn, yRn),
                             0.5 * (yLn + yRn)).astype(dt)
            tp_n = jnp.where(upd, tcut, s.tp)
        else:
            tp_n = s.tp
        s = s._replace(
            yL=jnp.where(upd, yLn, s.yL),
            yR=jnp.where(upd, yRn, s.yR),
            cleL=jnp.where(upd, cLn, s.cleL),
            cleR=jnp.where(upd, cRn, s.cleR),
            t_exact=jnp.where(exact, t_ex, s.t_exact),
            found_exact=s.found_exact | exact,
            iters=s.iters + lv.astype(jnp.int32),
            it=s.it + 1,
            tp=tp_n,
        )
        return s, stalled | stall_n

    s, _ = jax.lax.while_loop(cond, body, (s0, stalled0))
    return s, xmin, xmax


def _run_bracket_phase(ev, method, maxit, cap, nbins, prior=None):
    """Dispatch the phase-1 loop for a resolved method (any evaluator leg).

    ``prior`` threads the warm-start carry into whichever loop runs (first
    sweep's edge placement / first proposal pivot — see the loops)."""
    if method in ("binned", "binned_polish"):
        return binned_loop_batched(ev, nbins=nbins, maxit=maxit, cap=cap,
                                   polish=method == "binned_polish",
                                   prior=prior)
    return bracket_loop_batched(ev, method=method, maxit=maxit, cap=cap,
                                prior=prior)


def rank_compact(mask_in, cap: int, cols):
    """First-``cap`` survivors of a 1-D mask by RANK GATHER.

    The paper's ``copy_if`` as a static-shape gather: ``pos`` is each
    element's inclusive survivor rank (a cumsum of the mask), so the i-th
    survivor's index is ``searchsorted(pos, i + 1)`` — O(cap log n) cheap
    gathers where a full-length scatter lowers to an O(n) serialized loop
    on XLA:CPU (~20x the whole finalize at 1M, see BENCH_selection.json).
    ``cols`` is a sequence of ``(values, pad)`` pairs gathered at the same
    survivor indices (aligned buffers; ``pad`` fills slots past the last
    survivor).  Returns ``(buffers, n_in)``.  Shared by the local finalize
    (:func:`_compact_interval`) and the distributed per-shard finalize —
    keep it the single implementation.
    """
    n_in = jnp.sum(mask_in, dtype=jnp.int32)
    pos = jnp.cumsum(mask_in.astype(jnp.int32))
    idx = jnp.minimum(
        jnp.searchsorted(pos, jnp.arange(1, cap + 1, dtype=jnp.int32),
                         side="left"),
        mask_in.size - 1).astype(jnp.int32)
    have = jnp.arange(cap) < n_in
    return [jnp.where(have, v[idx], pad) for v, pad in cols], n_in


def _compact_interval(x, w, yL, yR, cap):
    """ONE problem's phase-2 survivor compaction + fallback probes (1-D x).

    The open pivot interval ``(yL, yR]`` lands in a ``(cap,)`` buffer via
    :func:`rank_compact` (first ``cap`` survivors in data order, +inf
    pad), alongside the measure certificates the answer assembly needs —
    ``cLm = measure(x <= yL)``, the in-bracket count, the next distinct
    value above ``yL`` and its inclusive measure (tie fallback
    verification).  Everything downstream is O(cap), not O(n).

    ``w=None`` is the counting leg: the measures are the int32 counts and
    the weight buffer comes back ``None`` (no weight reads).  With
    weights, the (value, weight) PAIRS land in aligned buffers via the
    same rank indices (pad values +inf, pad weights 0 so sorted prefix
    masses are unaffected).
    """
    big = jnp.asarray(jnp.inf, x.dtype)
    mask_in = (x > yL) & (x <= yR)
    cL = jnp.sum(x <= yL, dtype=jnp.int32)
    vnext = jnp.min(jnp.where(x > yL, x, big))
    if w is None:
        (z,), n_in = rank_compact(mask_in, cap, [(x, big)])
        m_le_v = jnp.sum(x <= vnext, dtype=jnp.int32)
        return z, None, cL, n_in, vnext, m_le_v
    dtw = w.dtype
    (z, zw), n_in = rank_compact(mask_in, cap,
                                 [(x, big), (w, jnp.zeros((), dtw))])
    cLw = jnp.sum(jnp.where(x <= yL, w, 0), dtype=dtw)
    w_le_v = jnp.sum(jnp.where(x <= vnext, w, 0), dtype=dtw)
    return z, zw, cLw, n_in, vnext, w_le_v


def _assemble_answers(kk, s: BatchState, cap, zs, zws, cLm, n_in, vnext,
                      m_le_v, m_lt_max, xmin, xmax) -> SelectResult:
    """Per-problem answer/status cascade from compacted buffers + measures.

    Shared by the rows-mode and shared-x finalizes on BOTH measure legs —
    all inputs are batch-shaped except the value-sorted ``(B, cap)`` buffer
    ``zs`` and its aligned weights ``zws`` (``None`` on the counting leg).

    Counting leg (``zws is None``): the in-buffer answer is direct indexing
    at ``k - cL - 1`` and the extreme shortcuts fire off the exact integer
    measures alone.  Weighted leg: the answer is the first survivor whose
    cumulative mass (on top of the below-bracket mass ``cLm``) reaches
    ``k`` — the sorted-prefix-weight generalization — and, because the
    masses here are RE-MEASURED by a differently-ordered sum than the
    loop's histogram passes, the buffer certifies only when its total mass
    actually reaches ``k`` and the extreme shortcuts are gated on the seed
    bracket (a rounding flip near ``k`` with the bracket off the extreme
    falls through to the sort/fallback chain — fail safe).
    """
    if zws is None:
        # exact integer measure: index straight into the sorted buffer
        sort_idx = jnp.clip(kk - cLm - 1, 0, cap - 1)
        ans_sort = jnp.take_along_axis(zs, sort_idx[..., None],
                                       axis=-1)[..., 0]
        sort_ok = n_in <= cap
        at_min = cLm >= kk
        at_max = m_lt_max < kk
    else:
        cumw = cLm[..., None] + jnp.cumsum(zws, axis=-1)
        reach = cumw >= kk[..., None]
        sidx = jnp.argmax(reach, axis=-1).astype(jnp.int32)
        ans_sort = jnp.take_along_axis(zs, sidx[..., None], axis=-1)[..., 0]
        # the buffer certifies only when it holds every survivor AND its
        # total mass actually reaches k (all-False argmax must not certify)
        sort_ok = (n_in <= cap) & reach[..., -1]
        at_min = (cLm >= kk) & (s.yL == xmin)
        at_max = (m_lt_max < kk) & (s.yR == xmax)
    fallback_ok = (cLm < kk) & (kk <= m_le_v)

    value = jnp.where(
        s.found_exact,
        s.t_exact,
        jnp.where(sort_ok, ans_sort,
                  jnp.where(fallback_ok, vnext, s.yR)),
    )
    status = jnp.where(
        s.found_exact,
        EXACT_HIT,
        jnp.where(
            sort_ok,
            HYBRID_SORT,
            jnp.where(fallback_ok, TIE_FALLBACK, NOT_CONVERGED),
        ),
    )
    # Extreme shortcuts (the bracket invariant measure(y_L) < k only holds
    # for answers strictly inside the data range): if measure(x <= y_L) >= k
    # the answer is at or below y_L, which can only be the minimum (y_L
    # starts at the min and only moves to points certified < k).  Symmetric
    # test at the max.  Also covers k==1, k==n and all-equal rows.
    value = jnp.where(at_min, xmin, jnp.where(at_max, xmax, value))
    status = jnp.where(at_min | at_max, EXACT_HIT, status)
    return SelectResult(
        value=value, iters=s.iters, status=status.astype(jnp.int32),
        y_lo=s.yL, y_hi=s.yR, n_in=n_in,
    )


def _finalize_rows(x, kk, s: BatchState, cap, xmin, xmax,
                   w=None) -> SelectResult:
    """Exact per-row recovery from the final brackets.  Two fused passes.

    Pass 1 (the paper's ``copy_if`` + count, row-wise): compact each row's
    open pivot interval into a fixed ``(B, cap)`` buffer, measure
    ``cLm = measure(x<=y_L)`` and find the next distinct value above
    ``y_L``; one batched sort of the (B, cap) buffer (carrying the aligned
    weights through on the weighted leg).
    Pass 2 (tie fallback verification): ``measure(x <= vnext)`` per row.
    """
    if w is None:
        z, _, cLm, n_in, vnext, m_le_v = jax.vmap(
            lambda xi, lo, hi: _compact_interval(xi, None, lo, hi, cap)
        )(x, s.yL, s.yR)
        zs = jnp.sort(z, axis=-1)
        zws = None
        m_lt_max = jnp.sum(x < xmax[:, None], axis=1, dtype=jnp.int32)
    else:
        z, zw, cLm, n_in, vnext, m_le_v = jax.vmap(
            lambda xi, wi, lo, hi: _compact_interval(xi, wi, lo, hi, cap)
        )(x, w, s.yL, s.yR)
        order = jnp.argsort(z, axis=-1)
        zs = jnp.take_along_axis(z, order, axis=-1)
        zws = jnp.take_along_axis(zw, order, axis=-1)
        m_lt_max = jnp.sum(jnp.where(x < xmax[:, None], w, 0), axis=1,
                           dtype=w.dtype)
    return _assemble_answers(kk, s, cap, zs, zws, cLm, n_in, vnext, m_le_v,
                             m_lt_max, xmin, xmax)


def _finalize_shared(x, kk, s: BatchState, cap, xmin, xmax,
                     w=None) -> SelectResult:
    """Shared-x exact finalize on per-pivot compacted buffers.

    The compaction runs per pivot against the ONE ``(n,)`` array (pair on
    the weighted leg), sequential ``lax.map`` over the K brackets, so peak
    memory stays O(n + K*cap) — the hot iterations (multi-bracket kernel)
    and the finalize both avoid materializing ``(K, n)``.
    """
    x = x.reshape(-1)
    if w is None:
        z, _, cLm, n_in, vnext, m_le_v = jax.lax.map(
            lambda args: _compact_interval(x, None, args[0], args[1], cap),
            (s.yL, s.yR))
        zs = jnp.sort(z, axis=-1)
        zws = None
        # one shared pass: xmin/xmax are (K,) broadcasts of global extremes
        m_lt_max = jnp.broadcast_to(
            jnp.sum(x < jnp.max(xmax), dtype=jnp.int32), kk.shape)
    else:
        w = w.reshape(-1)
        z, zw, cLm, n_in, vnext, m_le_v = jax.lax.map(
            lambda args: _compact_interval(x, w, args[0], args[1], cap),
            (s.yL, s.yR))
        order = jnp.argsort(z, axis=-1)
        zs = jnp.take_along_axis(z, order, axis=-1)
        zws = jnp.take_along_axis(zw, order, axis=-1)
        m_lt_max = jnp.broadcast_to(
            jnp.sum(jnp.where(x < jnp.max(xmax), w, 0), dtype=w.dtype),
            kk.shape)
    return _assemble_answers(kk, s, cap, zs, zws, cLm, n_in, vnext, m_le_v,
                             m_lt_max, xmin, xmax)


def _default_cap(n: int) -> int:
    # generous: >= 2 * sqrt-ish growth, bounded; paper observed |z| ~ 1-5% n.
    return int(min(max(4096, n // 64), 1 << 19))


def _default_cap_rows(n: int) -> int:
    # Batched regimes keep a (B, cap) compaction buffer, so the per-row cap
    # is tighter than the scalar default: a few more bracket iterations
    # (cheap fused passes, shared by the whole batch) buy a much smaller
    # batched sort.  Benchmarked in benchmarks/batched_selection_bench.py.
    return int(min(max(256, n // 64), 4096))


def _map_bracket_back_rows(x, xt, s: BatchState) -> BatchState:
    """Map a transformed-domain bracket back to original values, row-wise.

    F is monotone non-decreasing in fp on the data, so
        y_orig = max{x_i : F(x_i) <= y_t}
    preserves counts exactly: count(x <= y_orig) == count(F(x) <= y_t).
    Both loop invariants (c(y_L) < k <= c(y_R)) therefore transfer to the
    original domain, and the finalize stays exact.  On an exact hit the
    t-space image may merge several distinct originals (F is not injective
    in fp): collapse the bracket to the image's preimage set and drop the
    certificate — the original-space finalize re-resolves it.
    """
    neg = jnp.asarray(-jnp.inf, x.dtype)
    yL_t = jnp.where(s.found_exact, s.t_exact, s.yL)[:, None]
    yR_t = jnp.where(s.found_exact, s.t_exact, s.yR)[:, None]
    yL = jnp.where(
        s.found_exact,
        jnp.max(jnp.where(xt < yL_t, x, neg), axis=1),  # strict: preimage
        jnp.max(jnp.where(xt <= yL_t, x, neg), axis=1),
    )
    yR = jnp.max(jnp.where(xt <= yR_t, x, neg), axis=1)
    return s._replace(
        yL=yL, yR=yR,
        # exactness certificates do not survive the fp roundtrip:
        found_exact=jnp.zeros_like(s.found_exact),
    )


def _map_bracket_back_shared(x, xt, s: BatchState) -> BatchState:
    """Shared-x analogue of :func:`_map_bracket_back_rows`: one ``(n,)``
    array, (K,) transformed brackets, mapped back by the same
    count-preserving preimage reductions — per pivot via ``lax.map`` so the
    ``(K, n)`` broadcast never materializes."""
    neg = jnp.asarray(-jnp.inf, x.dtype)
    x = x.reshape(-1)
    xt = xt.reshape(-1)

    def one(args):
        yL_t, yR_t, t_ex, found = args
        lo_t = jnp.where(found, t_ex, yL_t)
        hi_t = jnp.where(found, t_ex, yR_t)
        yL = jnp.where(
            found,
            jnp.max(jnp.where(xt < lo_t, x, neg)),  # strict: preimage
            jnp.max(jnp.where(xt <= lo_t, x, neg)),
        )
        yR = jnp.max(jnp.where(xt <= hi_t, x, neg))
        return yL, yR

    yL, yR = jax.lax.map(one, (s.yL, s.yR, s.t_exact, s.found_exact))
    return s._replace(
        yL=yL, yR=yR,
        # exactness certificates do not survive the fp roundtrip:
        found_exact=jnp.zeros_like(s.found_exact),
    )


@functools.partial(
    jax.jit,
    static_argnames=("method", "maxit", "cap", "transform", "backend",
                     "nbins", "binned_impl"),
)
def select_rows(
    x: jax.Array,
    k,
    *,
    method: Optional[str] = None,
    maxit: int = 64,
    cap: Optional[int] = None,
    transform: Optional[str] = None,
    backend: Optional[str] = None,
    nbins: Optional[int] = None,
    binned_impl: Optional[str] = None,
    prior=None,
) -> SelectResult:
    """Rows-mode batched selection: ``x`` is (B, n), ``k`` scalar or (B,).

    Every field of the returned :class:`SelectResult` is (B,)-shaped; row
    ``i`` solves the independent problem ``x[i], k[i]`` with the same
    exactness guarantees as the scalar solver (which is the B=1 view of this
    function).  ``method=None`` resolves to 'binned' for n >= BINNED_MIN_N
    and 'cp' otherwise (see ``_resolve_method``); ``nbins`` sizes the
    binned histogram sweeps (``None``: backend-tuned, see
    ``_resolve_nbins``); ``binned_impl`` routes the jnp histogram slotting
    ('searchsorted' | 'arithmetic' — bit-identical, for differential
    testing).  ``backend`` selects the fused data pass ('jnp' | 'pallas' |
    'pallas_interpret', default: pallas on TPU).

    ``prior``: warm-start carry for repeated selection — ``None``, a
    previous :class:`SelectResult` (fields (B,) or scalar), a
    :class:`Prior`, or a bare value.  The result is bit-identical to a
    cold solve under the engine's exactness contract (only sweep counts
    change); an unchanged answer re-certifies in 1 sweep / 1 cp pass.
    """
    if x.ndim != 2:
        raise ValueError(f"select_rows wants (B, n) data, got {x.shape}")
    b, n = x.shape
    prior = as_prior(prior)
    method = _resolve_method(method, n, backend)
    nbins = _resolve_nbins(nbins, backend, x.dtype)
    binned_impl = _check_binned_impl(binned_impl)
    if cap is None:
        cap = _default_cap_rows(n)
    cap = min(cap, n)
    ks = jnp.broadcast_to(jnp.clip(jnp.asarray(k, jnp.int32), 1, n), (b,))

    if method == "sort":
        xs = jnp.sort(x, axis=1)
        value = jnp.take_along_axis(xs, (ks - 1)[:, None], axis=1)[:, 0]
        zero = jnp.zeros((b,), jnp.int32)
        return SelectResult(
            value=value, iters=zero,
            status=jnp.full((b,), EXACT_HIT, jnp.int32),
            y_lo=xs[:, 0], y_hi=xs[:, -1],
            n_in=jnp.full((b,), n, jnp.int32),
        )

    if transform == "log1p":
        xt = transforms.log1p_transform_rows(x)
        if prior is not None:
            # map the (original-space) prior through the row anchors; a
            # value below the anchor maps to NaN and is sanitized away
            # inside prior_edges — the prior is advisory either way
            x0 = jnp.min(x, axis=1)
            ft = lambda v: jnp.log1p(jnp.asarray(v, x.dtype) - x0)
            prior = Prior(ft(prior.value), ft(prior.y_lo),
                          ft(prior.y_hi), ft(prior.cut))
        s, _, _ = _run_bracket_phase(
            RowsEvaluator(xt, ks, backend=backend,
                          binned_impl=binned_impl), method, maxit, cap,
            nbins, prior=prior)
        s = _map_bracket_back_rows(x, xt, s)
        return _finalize_rows(x, ks, s, cap,
                              jnp.min(x, axis=1), jnp.max(x, axis=1))
    elif transform is not None:
        raise ValueError(f"unknown transform {transform!r}")

    ev = RowsEvaluator(x, ks, backend=backend, binned_impl=binned_impl)
    s, xmin, xmax = _run_bracket_phase(ev, method, maxit, cap, nbins,
                                       prior=prior)
    return _finalize_rows(x, ks, s, cap, xmin, xmax)


def order_statistic(
    x: jax.Array,
    k,
    *,
    method: Optional[str] = None,
    maxit: int = 64,
    cap: Optional[int] = None,
    transform: Optional[str] = None,
    backend: Optional[str] = None,
    nbins: Optional[int] = None,
    binned_impl: Optional[str] = None,
    prior=None,
) -> SelectResult:
    """k-th smallest element of ``x`` (k is 1-indexed, may be traced).

    The ``B = 1`` view of :func:`select_rows`.  ``method`` in {"binned",
    "binned_polish", "cp", "cp_hybrid", "bisection", "golden", "brent",
    "sort"}; ``None`` resolves to 'binned' for large n, 'cp' otherwise
    (see ``_resolve_method``).
    ``cp`` and ``cp_hybrid`` are aliases (the hybrid finalize is always on —
    it is what makes the result exact).  ``transform='log1p'`` applies the
    paper's monotone guard for extreme-valued data (Sec. V-D).
    """
    x = x.reshape(-1)
    if cap is None:
        cap = _default_cap(x.size)  # scalar policy: one generous buffer
    res = select_rows(
        x[None, :], jnp.asarray(k, jnp.int32).reshape(1),
        method=method, maxit=maxit, cap=cap, transform=transform,
        backend=backend, nbins=nbins, binned_impl=binned_impl,
        prior=as_prior(prior),
    )
    return jax.tree.map(lambda a: a[0], res)


def median(x: jax.Array, **kw) -> SelectResult:
    """Med(x) = x_([(n+1)/2]) (paper Sec. I convention)."""
    n = x.size
    return order_statistic(x, (n + 1) // 2, **kw)


def ranks_from_quantiles(qs, n: int):
    """Target ranks ``ceil(q * n)`` clipped to ``[1, n]``, resolved in f64
    BEFORE tracing whenever ``qs`` is concrete.

    Under default x64-off the traced product rounds ``q`` and ``q * n``
    through f32, whose spacing at ``n ~ 2^25`` is 4 ulps of an integer —
    a high quantile (q = 0.999999) can land on the wrong rank entirely.
    Concrete ``qs`` (the overwhelmingly common call) are resolved host-side
    in numpy f64, where every rank below 2^53 is exact; traced ``qs`` fall
    back to the on-device product (exact whenever ``q * n`` is
    f32-representable).
    """
    if isinstance(qs, jax.core.Tracer):
        return jnp.clip(jnp.ceil(jnp.asarray(qs) * n).astype(jnp.int32),
                        1, n)
    qv = np.asarray(qs, np.float64)
    return jnp.asarray(np.clip(np.ceil(qv * float(n)), 1, n)
                       .astype(np.int32))


def quantile(x: jax.Array, q, **kw) -> SelectResult:
    """Lower empirical q-quantile: x_(ceil(q*n)) clipped to [1, n]."""
    return order_statistic(x, ranks_from_quantiles(q, x.size), **kw)


def topk_threshold(x: jax.Array, m, **kw) -> SelectResult:
    """Value of the m-th largest element (for kNN / trimming)."""
    n = x.size
    return order_statistic(x, n - jnp.asarray(m, jnp.int32) + 1, **kw)


@functools.partial(
    jax.jit,
    static_argnames=("method", "maxit", "cap", "transform", "backend",
                     "nbins", "binned_impl"),
)
def multi_order_statistic(
    x: jax.Array,
    ks,
    *,
    method: Optional[str] = None,
    maxit: int = 64,
    cap: Optional[int] = None,
    transform: Optional[str] = None,
    backend: Optional[str] = None,
    nbins: Optional[int] = None,
    binned_impl: Optional[str] = None,
    prior=None,
) -> SelectResult:
    """Several order statistics of the SAME array at once (shared-x mode).

    All K brackets iterate together against the multi-pivot fused kernel:
    each iteration reads ``x`` ONCE and evaluates every live pivot from the
    resident tile (on TPU: one VMEM load per tile for all K pivots) — the
    cheap way to get (p25, p50, p75, p99, ...) telemetry sets.  The finalize
    compacts survivors per pivot straight from the ``(n,)`` array
    (:func:`_finalize_shared`), so neither the hot iterations nor the
    finalize ever materialize ``(K, n)``.  ``prior`` warm-starts every
    target's bracket from a previous ``(K,)`` result (see
    :func:`select_rows`).
    """
    x = x.reshape(-1)
    n = x.size
    prior = as_prior(prior)
    method = _resolve_method(method, n, backend)
    nbins = _resolve_nbins(nbins, backend, x.dtype)
    binned_impl = _check_binned_impl(binned_impl)
    ks = jnp.clip(jnp.asarray(ks, jnp.int32).reshape(-1), 1, n)
    nk = ks.shape[0]
    if cap is None:
        cap = _default_cap_rows(n)
    cap = min(cap, n)

    if method == "sort":
        xs = jax.lax.sort(x)
        zero = jnp.zeros((nk,), jnp.int32)
        return SelectResult(
            value=xs[ks - 1], iters=zero,
            status=jnp.full((nk,), EXACT_HIT, jnp.int32),
            y_lo=jnp.broadcast_to(xs[0], (nk,)),
            y_hi=jnp.broadcast_to(xs[-1], (nk,)),
            n_in=jnp.full((nk,), n, jnp.int32),
        )

    if transform == "log1p":
        xt, _ = transforms.log1p_transform(x)
        if prior is not None:
            x0 = jnp.min(x)
            ft = lambda v: jnp.log1p(jnp.asarray(v, x.dtype) - x0)
            prior = Prior(ft(prior.value), ft(prior.y_lo),
                          ft(prior.y_hi), ft(prior.cut))
        s, _, _ = _run_bracket_phase(
            SharedEvaluator(xt, ks, backend=backend,
                            binned_impl=binned_impl), method, maxit, cap,
            nbins, prior=prior)
        s = _map_bracket_back_shared(x, xt, s)
        bcast = lambda v: jnp.broadcast_to(v, (nk,))
        return _finalize_shared(x, ks, s, cap,
                                bcast(jnp.min(x)), bcast(jnp.max(x)))
    elif transform is not None:
        raise ValueError(f"unknown transform {transform!r}")

    ev = SharedEvaluator(x, ks, backend=backend, binned_impl=binned_impl)
    s, xmin, xmax = _run_bracket_phase(ev, method, maxit, cap, nbins,
                                       prior=prior)
    return _finalize_shared(x, ks, s, cap, xmin, xmax)


def quantiles(x: jax.Array, qs, **kw) -> SelectResult:
    """Lower empirical quantiles at each q in ``qs`` (one shared-x solve).

    With ``method='binned'``/``'binned_polish'`` the K brackets narrow
    simultaneously from ONE histogram sweep per round (the shared-x
    multi-bracket pass), so a decile vector costs the data traffic of a
    single binned median, not ~K× it.
    """
    return multi_order_statistic(x, ranks_from_quantiles(qs, x.size), **kw)


# ---------------------------------------------------------------------------
# Segmented selection: per-segment order statistics of ONE concatenated
# array — the per-leaf regime (gradient-clip thresholds over a pytree)
# ---------------------------------------------------------------------------


def _finalize_segmented(x, seg, kk, s: BatchState, cap, xmin,
                        xmax) -> SelectResult:
    """Per-segment exact finalize: :func:`_finalize_shared` with every
    reduction masked to its own segment.  Sequential ``lax.map`` over the K
    segments keeps peak memory O(n + K*cap) — no ``(K, n)`` broadcast."""
    x = x.reshape(-1)
    big = jnp.asarray(jnp.inf, x.dtype)
    sids = jnp.arange(kk.shape[0], dtype=jnp.int32)

    def one(args):
        sid, lo, hi, xm = args
        inseg = seg == sid
        mask_in = inseg & (x > lo) & (x <= hi)
        cL = jnp.sum(inseg & (x <= lo), dtype=jnp.int32)
        vnext = jnp.min(jnp.where(inseg & (x > lo), x, big))
        (z,), n_in = rank_compact(mask_in, cap, [(x, big)])
        m_le_v = jnp.sum(inseg & (x <= vnext), dtype=jnp.int32)
        m_lt_max = jnp.sum(inseg & (x < xm), dtype=jnp.int32)
        return z, cL, n_in, vnext, m_le_v, m_lt_max

    z, cLm, n_in, vnext, m_le_v, m_lt_max = jax.lax.map(
        one, (sids, s.yL, s.yR, xmax))
    zs = jnp.sort(z, axis=-1)
    return _assemble_answers(kk, s, cap, zs, None, cLm, n_in, vnext,
                             m_le_v, m_lt_max, xmin, xmax)


@functools.partial(
    jax.jit,
    static_argnames=("nsegs", "method", "maxit", "cap", "nbins"),
)
def segmented_order_statistic(
    x: jax.Array,
    seg: jax.Array,
    ks,
    *,
    nsegs: int,
    method: Optional[str] = None,
    maxit: int = 64,
    cap: Optional[int] = None,
    nbins: Optional[int] = None,
    prior=None,
) -> SelectResult:
    """Per-segment order statistics of one concatenated array.

    ``x`` (n,) holds K segments' data interleaved/concatenated, ``seg``
    (n,) int32 gives each element's segment id in ``[0, nsegs)``, and
    ``ks`` (nsegs,) the 1-indexed target rank WITHIN each segment (clipped
    to the segment size).  Every segment must be non-empty.  Returns a
    :class:`SelectResult` with (nsegs,) fields — segment ``i`` solves the
    independent problem ``x[seg == i], ks[i]`` with the engine's full
    exactness guarantees.

    This is the per-leaf regime: per-layer gradient-clip thresholds solve
    ONE of these over the flattened pytree instead of one scalar selection
    per leaf.  All data passes are shared: the FG pass is a handful of
    ``segment_sum`` reductions, and the binned pass buys every segment a
    factor-``nbins`` narrowing from one chunked sweep
    (``kernels.ref.segmented_histogram_ref`` — per-element binary search
    into its own segment's realized edge ladder, no ``(K, n)``
    intermediate).  ``method``/``maxit``/``cap``/``nbins`` as in
    :func:`multi_order_statistic`; the segmented data pass is jnp-only
    (XLA fuses it), so there is no ``backend`` knob.
    """
    from repro.kernels import ref as kref  # deferred: core <-> kernels

    x = x.reshape(-1)
    n = x.size
    seg = jnp.asarray(seg, jnp.int32).reshape(-1)
    method = _resolve_method(method, n, None)
    nbins = _resolve_nbins(nbins, None, x.dtype)
    if cap is None:
        cap = _default_cap_rows(n)
    cap = min(cap, n)
    ones = jnp.ones(n, jnp.int32)
    counts = jax.ops.segment_sum(ones, seg, num_segments=nsegs)
    kk = jnp.clip(jnp.asarray(ks, jnp.int32).reshape(-1), 1,
                  jnp.maximum(counts, 1))

    if method == "sort":
        # per-segment rank via one global sort on (seg, x) lexicographic
        order = jnp.lexsort((x, seg))
        xs = x[order]
        starts = jnp.cumsum(counts) - counts
        value = xs[jnp.clip(starts + kk - 1, 0, n - 1)]
        zero = jnp.zeros((nsegs,), jnp.int32)
        xmin = jax.ops.segment_min(x, seg, num_segments=nsegs)
        xmax = jax.ops.segment_max(x, seg, num_segments=nsegs)
        return SelectResult(
            value=value, iters=zero,
            status=jnp.full((nsegs,), EXACT_HIT, jnp.int32),
            y_lo=xmin, y_hi=xmax,
            n_in=counts,
        )

    def partials(y):
        d = x - y[seg]
        ssum = lambda v: jax.ops.segment_sum(v, seg, num_segments=nsegs)
        return (ssum(jnp.maximum(d, 0)), ssum(jnp.maximum(-d, 0)),
                ssum((d < 0).astype(jnp.int32)),
                ssum((d <= 0).astype(jnp.int32)))

    def init_stats():
        xmin = jax.ops.segment_min(x, seg, num_segments=nsegs)
        xmax = jax.ops.segment_max(x, seg, num_segments=nsegs)
        mean = jax.ops.segment_sum(x, seg, num_segments=nsegs) \
            / jnp.maximum(counts, 1).astype(x.dtype)
        return xmin, xmax, mean.astype(x.dtype)

    def histogram(edges, need_msum=False):
        out = kref.segmented_histogram_ref(
            x, seg, edges, rows=(x,) if need_msum else ())
        cnt = out[0]
        return cnt, cnt, (out[1] if need_msum else None)

    from repro.core.objective import FnEvaluator

    ev = FnEvaluator(partials, counts, kk, init_stats, histogram=histogram)
    s, xmin, xmax = _run_bracket_phase(ev, method, maxit, cap, nbins,
                                       prior=as_prior(prior))
    return _finalize_segmented(x, seg, kk, s, cap, xmin, xmax)


def segmented_quantiles(x: jax.Array, seg: jax.Array, q, sizes,
                        **kw) -> SelectResult:
    """Per-segment lower q-quantile from STATIC segment sizes.

    ``sizes`` (a python sequence — the leaf sizes are static in the
    per-leaf regime) turns ``q`` into per-segment ranks host-side at f64
    (:func:`ranks_from_quantiles` per segment), then runs ONE
    :func:`segmented_order_statistic` solve.  ``q`` may be a scalar (same
    quantile every segment, the clip-threshold case) or a length-``nsegs``
    sequence.
    """
    sizes = [int(v) for v in np.asarray(sizes).reshape(-1)]
    qv = np.broadcast_to(np.asarray(q, np.float64).reshape(-1),
                         (len(sizes),))
    ks = np.asarray([int(np.clip(np.ceil(qi * ni), 1, max(ni, 1)))
                     for qi, ni in zip(qv, sizes)], np.int32)
    return segmented_order_statistic(x, seg, jnp.asarray(ks),
                                     nsegs=len(sizes), **kw)


# ---------------------------------------------------------------------------
# Weighted selection: the weight-measure leg of the SAME engine
# ---------------------------------------------------------------------------
#
# The weighted k-th order statistic is the smallest element ``v`` whose
# cumulative weight ``W_le(v) = sum(w_i : x_i <= v)`` reaches the target
# mass ``wk`` — the minimizer of F_w(y) = sum_i w_i * rho(x_i - y) (see
# ``objective.py``).  There is NO weighted engine: the public functions
# below construct a weighted evaluator (whose measure fields carry masses)
# and run the very same bracket/binned loops and finalize chain as the
# counting path.  Uniform weights w_i == 1 with wk = k make every mass
# comparison an exact integer-valued comparison, reproducing the counting
# decisions bit for bit.  The fp contract for inexact masses is documented
# in the module docstring.


def _weighted_sort_cumsum(xs, cumw, wkk):
    """Answer/validity of the full-sort baseline: first sorted value whose
    cumulative mass reaches the target."""
    reach = cumw >= wkk[..., None]
    idx = jnp.argmax(reach, axis=-1).astype(jnp.int32)
    value = jnp.take_along_axis(xs, idx[..., None], axis=-1)[..., 0]
    # nothing reaches wk (all-False argmax): the target mass exceeds the
    # measured total — take the maximum, the limit of the definition
    value = jnp.where(reach[..., -1], value, xs[..., -1])
    return value


@functools.partial(
    jax.jit,
    static_argnames=("method", "maxit", "cap", "backend", "nbins",
                     "binned_impl"),
)
def weighted_select_rows(
    x: jax.Array,
    w: jax.Array,
    wk,
    *,
    method: Optional[str] = None,
    maxit: int = 64,
    cap: Optional[int] = None,
    backend: Optional[str] = None,
    nbins: Optional[int] = None,
    binned_impl: Optional[str] = None,
    prior=None,
) -> SelectResult:
    """Rows-mode weighted selection: ``x``/``w`` (B, n), ``wk`` scalar or
    (B,) target cumulative weights.

    Row ``i`` returns the smallest element ``v`` of ``x[i]`` with
    ``sum(w[i, x[i] <= v]) >= wk[i]`` (``wk`` is clipped to the row's total
    mass).  Weights must be non-negative; uniform weights with ``wk = k``
    reproduce :func:`select_rows` exactly.  ``method`` as in
    :func:`select_rows` minus ``transform`` support; ``'sort'`` is the
    weighted sort-cumsum baseline.
    """
    if x.ndim != 2:
        raise ValueError(f"weighted_select_rows wants (B, n) data, got "
                         f"{x.shape}")
    b, n = x.shape
    w = jnp.broadcast_to(jnp.asarray(w), x.shape)
    method = _resolve_method(method, n, backend)
    # either-operand f64 triggers the jnp reroute, so promote for nbins
    nbins = _resolve_nbins(nbins, backend,
                           jnp.promote_types(x.dtype, w.dtype))
    binned_impl = _check_binned_impl(binned_impl)
    if cap is None:
        cap = _default_cap_rows(n)
    cap = min(cap, n)
    ev = RowsEvaluator(x, wk, backend=backend, weights=w,
                       binned_impl=binned_impl)
    wkk = ev.k  # clipped target masses, accumulation dtype, (B,)

    if method == "sort":
        order = jnp.argsort(x, axis=1)
        xs = jnp.take_along_axis(x, order, axis=1)
        ws = jnp.take_along_axis(w.astype(wkk.dtype), order, axis=1)
        value = _weighted_sort_cumsum(xs, jnp.cumsum(ws, axis=1), wkk)
        zero = jnp.zeros((b,), jnp.int32)
        return SelectResult(
            value=value, iters=zero,
            status=jnp.full((b,), EXACT_HIT, jnp.int32),
            y_lo=xs[:, 0], y_hi=xs[:, -1],
            n_in=jnp.full((b,), n, jnp.int32),
        )

    s, xmin, xmax = _run_bracket_phase(ev, method, maxit, cap, nbins,
                                       prior=as_prior(prior))
    return _finalize_rows(x, wkk, s, cap, xmin, xmax,
                          w=w.astype(wkk.dtype))


def weighted_order_statistic(
    x: jax.Array,
    w: jax.Array,
    wk,
    *,
    method: Optional[str] = None,
    maxit: int = 64,
    cap: Optional[int] = None,
    backend: Optional[str] = None,
    nbins: Optional[int] = None,
    binned_impl: Optional[str] = None,
    prior=None,
) -> SelectResult:
    """Smallest element of ``x`` whose cumulative weight reaches ``wk``.

    The B = 1 view of :func:`weighted_select_rows`.  With ``w = ones`` and
    ``wk = k`` this is exactly :func:`order_statistic`.
    """
    x = x.reshape(-1)
    if cap is None:
        cap = _default_cap(x.size)  # scalar policy: one generous buffer
    res = weighted_select_rows(
        x[None, :], jnp.asarray(w).reshape(1, -1),
        jnp.asarray(wk).reshape(1),
        method=method, maxit=maxit, cap=cap, backend=backend, nbins=nbins,
        binned_impl=binned_impl, prior=as_prior(prior),
    )
    return jax.tree.map(lambda a: a[0], res)


def _total_mass(x, w):
    """Total weight at the mass-accumulation dtype (the wk/W reference)."""
    return jnp.sum(w, dtype=_weight_accum_dtype(jnp.asarray(x), w))


def weighted_median(x: jax.Array, w: jax.Array, **kw) -> SelectResult:
    """Lower weighted median: smallest v with ``mass(x <= v) >= W/2``.

    Uniform weights reproduce :func:`median` (= x_([(n+1)/2])) exactly.
    """
    w = jnp.asarray(w).reshape(-1)
    return weighted_order_statistic(x, w, 0.5 * _total_mass(x, w), **kw)


def weighted_quantile(x: jax.Array, w: jax.Array, q, **kw) -> SelectResult:
    """Lower weighted q-quantile: smallest v with ``mass(x <= v) >= q*W``."""
    w = jnp.asarray(w).reshape(-1)
    W = _total_mass(x, w)
    return weighted_order_statistic(x, w, jnp.asarray(q, W.dtype) * W, **kw)


@functools.partial(
    jax.jit,
    static_argnames=("method", "maxit", "cap", "backend", "nbins",
                     "binned_impl"),
)
def weighted_multi_order_statistic(
    x: jax.Array,
    w: jax.Array,
    wks,
    *,
    method: Optional[str] = None,
    maxit: int = 64,
    cap: Optional[int] = None,
    backend: Optional[str] = None,
    nbins: Optional[int] = None,
    binned_impl: Optional[str] = None,
    prior=None,
) -> SelectResult:
    """Several weighted order statistics of the SAME array at once.

    Shared-x mode: all K target masses iterate together against the
    weighted multi-pivot kernels (each x/w tile read once per sweep for
    every live bracket), exactly like :func:`multi_order_statistic`.
    """
    x = x.reshape(-1)
    n = x.size
    w = jnp.broadcast_to(jnp.asarray(w).reshape(-1), x.shape)
    method = _resolve_method(method, n, backend)
    # either-operand f64 triggers the jnp reroute, so promote for nbins
    nbins = _resolve_nbins(nbins, backend,
                           jnp.promote_types(x.dtype, w.dtype))
    binned_impl = _check_binned_impl(binned_impl)
    if cap is None:
        cap = _default_cap_rows(n)
    cap = min(cap, n)
    ev = SharedEvaluator(x, wks, backend=backend, weights=w,
                         binned_impl=binned_impl)
    wkk = ev.k
    nk = wkk.shape[0]

    if method == "sort":
        order = jnp.argsort(x)
        xs = x[order]
        cumw = jnp.cumsum(w.astype(wkk.dtype)[order])
        value = _weighted_sort_cumsum(xs[None, :], cumw[None, :],
                                      wkk)  # broadcast over K targets
        zero = jnp.zeros((nk,), jnp.int32)
        return SelectResult(
            value=value, iters=zero,
            status=jnp.full((nk,), EXACT_HIT, jnp.int32),
            y_lo=jnp.broadcast_to(xs[0], (nk,)),
            y_hi=jnp.broadcast_to(xs[-1], (nk,)),
            n_in=jnp.full((nk,), n, jnp.int32),
        )

    s, xmin, xmax = _run_bracket_phase(ev, method, maxit, cap, nbins,
                                       prior=as_prior(prior))
    return _finalize_shared(x, wkk, s, cap, xmin, xmax,
                            w=w.astype(wkk.dtype))


def weighted_quantiles(x: jax.Array, w: jax.Array, qs, **kw) -> SelectResult:
    """Lower weighted quantiles at each q in ``qs`` (one shared-x solve).

    The target masses ``q * W`` are formed at f64 host-side whenever both
    ``qs`` and the measured total mass are concrete (a single rounding into
    the accumulation dtype instead of the double-rounded f32 product —
    same rationale as :func:`ranks_from_quantiles`); traced operands fall
    back to the on-device product.
    """
    x = jnp.asarray(x).reshape(-1)
    w = jnp.asarray(w).reshape(-1)
    W = _total_mass(x, w)
    if isinstance(W, jax.core.Tracer) or isinstance(qs, jax.core.Tracer):
        wks = jnp.asarray(qs, W.dtype).reshape(-1) * W
    else:
        wks = jnp.asarray(
            np.asarray(qs, np.float64).reshape(-1) * float(W), W.dtype)
    return weighted_multi_order_statistic(x, w, wks, **kw)
