"""Selection (k-th order statistic) by convex minimization — Beliakov (2011).

Batched-first architecture
--------------------------
The engine is *batched-first*: the bracket loop, the exact-hit certificates
and the hybrid finalize all operate on ``(B,)`` state vectors, fed by an
:class:`repro.core.objective.Evaluator` (pivots ``(B,)`` -> ``FG`` partials
``(B,)``).  Scalar selection is the ``B = 1`` view.  Two batched regimes:

* **rows mode** (:func:`select_rows`) — ``(B, n)`` independent problems with
  per-row ``k``, driven by the row-wise fused kernel
  (``kernels.ops.fused_partials_batched``).  This is the production workload:
  coordinate-wise medians, LMS/LTS concentration over elemental starts, kNN
  cutoff rows.
* **shared-x mode** (:func:`multi_order_statistic` / :func:`quantiles`) — ONE
  array, ``(K,)`` target ranks, driven by the multi-pivot Pallas kernel
  (``kernels.ops.fused_partials_multi``) that reads each ``x`` tile into VMEM
  once and emits partials for all K live pivots — K× less HBM traffic than K
  lock-stepped independent solves.

Methods (shared skeleton, they differ only in the next-pivot proposal):

* ``cp``        — Kelley's cutting-plane method (Algorithm 1 of the paper).
* ``bisection`` — classical bisection on the subgradient sign (paper Sec. III).
* ``golden``    — golden-section-style bracket shrink (paper baseline).
* ``brent``     — parabolic fit with bisection safeguard (paper baseline).
* ``sort``      — full ``jnp.sort`` (the paper's "GPU radix sort" baseline).

Each iteration costs exactly one fused pass over the data — the paper's
``maxit + O(1)`` parallel reductions — regardless of how many problems ride
in the batch.

Exactness: unlike the paper (which stops on a float tolerance and then scans
for the largest ``x_i <= y~``), we carry the counts ``n_lt / n_le`` through
the loop PER ROW, which yields

  1. an *exact-hit* certificate ``n_lt < k <= n_le  =>  pivot == x_(k)``;
  2. a count-based stopping rule ``count(y_L < x <= y_R) <= cap`` that turns
     the paper's dynamic-size ``copy_if`` into a *static-shape* fixed-capacity
     compaction (required for ``jit``), performed row-wise into a
     ``(B, cap)`` buffer sorted in one batched sort;
  3. a tie-safe fallback: if more than ``cap`` duplicates of ``x_(k)`` exist
     in a row, the next distinct value above that row's ``y_L`` is verified
     by one extra counting pass.

Rows stop independently (per-row live mask); the loop exits when every row
has either certified an exact hit or shrunk its pivot interval under ``cap``.

Invariants maintained per row (proved by the subdifferential signs, see
``objective.py``):   count(x <= y_L) < k <= count(x <= y_R).

``transform='log1p'`` and the batched finalize: the loop runs on the
monotone image ``F(x) = log1p(x - min(x))`` (per row in rows mode), and the
final bracket is mapped back to original values *data-consistently* before
the exact finalize — ``y_orig = max{x_i : F(x_i) <= y_t}`` preserves counts
exactly, so the row invariants transfer and the compaction/tie logic runs on
untransformed data.  Exact-hit certificates do NOT survive the fp roundtrip
(F is not injective in fp): they are dropped per row and re-derived by the
original-space finalize.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.objective import (
    FG,
    Evaluator,
    RowsEvaluator,
    SharedEvaluator,
    os_weights,
)
from repro.core import transforms

METHODS = ("cp", "cp_hybrid", "bisection", "golden", "brent", "sort")

# Status codes for SelectResult.status
EXACT_HIT = 0       # pivot certified equal to x_(k) during iterations
HYBRID_SORT = 1     # answer from compact+sort of the pivot interval
TIE_FALLBACK = 2    # answer = next distinct value, certified by counts
NOT_CONVERGED = 3   # approximate answer (bracket right end)


class SelectResult(NamedTuple):
    value: jax.Array        # the order statistic (exact unless status==3)
    iters: jax.Array        # number of f/g evaluations this row was live for
    status: jax.Array       # see codes above
    y_lo: jax.Array         # final bracket
    y_hi: jax.Array
    n_in: jax.Array         # count(y_lo < x <= y_hi) at exit


class BatchState(NamedTuple):
    """Bracket-loop state; every field is (B,)-shaped except the scalar
    global iteration counter ``it`` (frozen rows stop updating but the batch
    iterates until all rows are done)."""
    yL: jax.Array
    fL: jax.Array
    gL: jax.Array   # right one-sided derivative at yL (< 0)
    yR: jax.Array
    fR: jax.Array
    gR: jax.Array   # left one-sided derivative at yR (> 0)
    cleL: jax.Array  # lower bound on count(x <= yL)  (exact after 1st move)
    cleR: jax.Array  # exact count(x <= yR)
    t_exact: jax.Array
    found_exact: jax.Array
    iters: jax.Array  # per-row live-iteration count
    it: jax.Array     # global (batch) iteration count
    # golden/brent bookkeeping: previous probe (for parabolic fit)
    tp: jax.Array
    fp: jax.Array


def _propose_cp(s: BatchState):
    """Kelley cut intersection: minimizer of max of the two support lines."""
    return (s.fR - s.fL + s.yL * s.gL - s.yR * s.gR) / (s.gL - s.gR)


def _propose_bisection(s: BatchState):
    return 0.5 * (s.yL + s.yR)


_INV_GOLDEN = 0.381966011250105  # 2 - golden ratio


def _propose_golden(s: BatchState):
    # Shrink from the side whose objective value is larger (descent side).
    left = s.fL > s.fR
    w = jnp.where(left, _INV_GOLDEN, 1.0 - _INV_GOLDEN)
    return s.yL + w * (s.yR - s.yL)


def _propose_brent(s: BatchState):
    """Parabola through (yL,fL), (tp,fp), (yR,fR); midpoint safeguard."""
    x1, f1, x2, f2, x3, f3 = s.yL, s.fL, s.tp, s.fp, s.yR, s.fR
    num = (x2 - x1) ** 2 * (f2 - f3) - (x2 - x3) ** 2 * (f2 - f1)
    den = (x2 - x1) * (f2 - f3) - (x2 - x3) * (f2 - f1)
    ok = jnp.abs(den) > 1e-30
    t = x2 - 0.5 * num / jnp.where(ok, den, 1.0)
    mid = 0.5 * (s.yL + s.yR)
    inside = (t > s.yL) & (t < s.yR)
    return jnp.where(ok & inside, t, mid)


_PROPOSALS = {
    "cp": _propose_cp,
    "cp_hybrid": _propose_cp,
    "bisection": _propose_bisection,
    "golden": _propose_golden,
    "brent": _propose_brent,
}


def _live(s: BatchState, cap):
    return (~s.found_exact) & (s.cleR - s.cleL > cap) & (s.yR > s.yL)


def bracket_loop_batched(
    ev: Evaluator,
    *,
    method: str = "cp",
    maxit: int = 64,
    cap=0,
    found0: Optional[jax.Array] = None,
    t0: Optional[jax.Array] = None,
):
    """Run the batched bracket-shrinking loop against an evaluator.

    ``ev`` owns the data; this loop only sees ``(B,)`` vectors.  ``cap`` is
    the per-row stopping count (0 = iterate to exact hit / maxit, the
    distributed across-axis regime).  ``found0``/``t0`` pre-seed rows whose
    answer is already certified (e.g. extreme ranks) so they never go live.

    Returns ``(final BatchState, xmin, xmax)`` with per-row extremes.
    """
    propose = _PROPOSALS[method]
    xmin, xmax, xmean = ev.init_stats()
    k = ev.k
    shape = jnp.broadcast_shapes(jnp.shape(xmin), jnp.shape(k))
    dtype = xmin.dtype
    nf = jnp.broadcast_to(jnp.asarray(ev.n, dtype), shape)
    kk = jnp.broadcast_to(jnp.asarray(k, jnp.int32), shape)
    alpha, beta = os_weights(nf, kk, dtype)
    bc = lambda v: jnp.broadcast_to(jnp.asarray(v, dtype), shape)

    # Analytic init at the extremes (paper: single fused reduction).  The
    # slopes use the conservative tie count 1, which keeps the support lines
    # *lower* bounds (valid cuts) even with duplicated extremes.
    xmin, xmax, xmean = bc(xmin), bc(xmax), bc(xmean)
    fL0 = beta * (xmean - xmin)
    fR0 = alpha * (xmax - xmean)
    gL0 = alpha * (1.0 / nf) - beta * (nf - 1.0) / nf
    gR0 = alpha * (nf - 1.0) / nf - beta * (1.0 / nf)

    if found0 is None:
        found0 = jnp.zeros(shape, bool)
    if t0 is None:
        t0 = jnp.full(shape, jnp.nan, dtype)
    s0 = BatchState(
        yL=xmin, fL=fL0, gL=gL0,
        yR=xmax, fR=fR0, gR=gR0,
        cleL=jnp.ones(shape, jnp.int32),   # count(x<=min) >= 1 (conservative)
        cleR=jnp.broadcast_to(jnp.asarray(ev.n, jnp.int32), shape),
        t_exact=t0,
        found_exact=jnp.broadcast_to(found0, shape),
        iters=jnp.zeros(shape, jnp.int32),
        it=jnp.asarray(0, jnp.int32),
        tp=0.5 * (xmin + xmax), fp=jnp.maximum(fL0, fR0),
    )

    def cond(s: BatchState):
        return (s.it < maxit) & jnp.any(_live(s, cap))

    def body(s: BatchState):
        lv = _live(s, cap)
        t = propose(s)
        # numerical safeguard: keep strictly inside the open bracket (frozen
        # rows get the midpoint — their updates are masked out anyway)
        bad = ~jnp.isfinite(t) | (t <= s.yL) | (t >= s.yR)
        t = jnp.where(bad, 0.5 * (s.yL + s.yR), t).astype(dtype)
        fg: FG = ev(t)
        exact = (fg.n_lt < kk) & (kk <= fg.n_le) & lv
        # exact => 0 in [g_lo, g_hi] => g_hi >= 0, so the two are disjoint:
        move_left = (fg.g_hi < 0) & lv   # t strictly left of the minimizer
        move_right = lv & ~move_left & ~exact  # then g_lo > 0: strictly right
        return BatchState(
            yL=jnp.where(move_left, t, s.yL),
            fL=jnp.where(move_left, fg.f, s.fL),
            gL=jnp.where(move_left, fg.g_hi, s.gL),
            yR=jnp.where(move_right, t, s.yR),
            fR=jnp.where(move_right, fg.f, s.fR),
            gR=jnp.where(move_right, fg.g_lo, s.gR),
            cleL=jnp.where(move_left, fg.n_le, s.cleL),
            cleR=jnp.where(move_right, fg.n_le, s.cleR),
            t_exact=jnp.where(exact, t, s.t_exact),
            found_exact=s.found_exact | exact,
            iters=s.iters + lv.astype(jnp.int32),
            it=s.it + 1,
            tp=jnp.where(lv, t, s.tp), fp=jnp.where(lv, fg.f, s.fp),
        )

    return jax.lax.while_loop(cond, body, s0), xmin, xmax


def _finalize_rows(x, ks, s: BatchState, cap, xmin, xmax) -> SelectResult:
    """Exact per-row recovery from the final brackets.  Two fused passes.

    Pass 1 (the paper's ``copy_if`` + count, row-wise): compact each row's
    open pivot interval into a fixed ``(B, cap)`` buffer (slot ``cap`` is the
    overflow trash slot), count ``c_L = count(x<=y_L)`` and find the next
    distinct value above ``y_L``; one batched sort of the (B, cap) buffer.
    Pass 2 (tie fallback verification): ``count(x <= vnext)`` per row.
    """
    b, n = x.shape
    kk = jnp.broadcast_to(jnp.asarray(ks, jnp.int32), (b,))
    yL = s.yL[:, None]
    yR = s.yR[:, None]

    mask_in = (x > yL) & (x <= yR)
    cL = jnp.sum(x <= yL, axis=1, dtype=jnp.int32)
    n_in = jnp.sum(mask_in, axis=1, dtype=jnp.int32)
    # fixed-capacity row-wise compaction
    pos = jnp.cumsum(mask_in.astype(jnp.int32), axis=1) - 1
    idx = jnp.where(mask_in, jnp.minimum(pos, cap), cap)
    big = jnp.asarray(jnp.inf, x.dtype)
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    z = jnp.full((b, cap + 1), big, x.dtype).at[rows, idx].set(
        jnp.where(mask_in, x, big))
    zs = jnp.sort(z[:, :cap], axis=1)
    sort_idx = jnp.clip(kk - cL - 1, 0, cap - 1)
    ans_sort = jnp.take_along_axis(zs, sort_idx[:, None], axis=1)[:, 0]

    vnext = jnp.min(jnp.where(x > yL, x, big), axis=1)
    n_le_v = jnp.sum(x <= vnext[:, None], axis=1, dtype=jnp.int32)
    fallback_ok = (cL < kk) & (kk <= n_le_v)

    value = jnp.where(
        s.found_exact,
        s.t_exact,
        jnp.where(n_in <= cap, ans_sort,
                  jnp.where(fallback_ok, vnext, s.yR)),
    )
    status = jnp.where(
        s.found_exact,
        EXACT_HIT,
        jnp.where(
            n_in <= cap,
            HYBRID_SORT,
            jnp.where(fallback_ok, TIE_FALLBACK, NOT_CONVERGED),
        ),
    )
    # Extreme-tie shortcuts (the bracket invariant c(y_L) < k only holds for
    # answers strictly inside the data range): if count(x <= y_L) >= k the
    # answer is at or below y_L, which can only be x_(1)=min (y_L starts at
    # the min and only moves to points certified count(x<=t) < k).  Symmetric
    # test at the max.  Also covers k==1, k==n and all-equal rows.
    n_lt_max = jnp.sum(x < xmax[:, None], axis=1, dtype=jnp.int32)
    at_min = cL >= kk
    at_max = n_lt_max < kk
    value = jnp.where(at_min, xmin, jnp.where(at_max, xmax, value))
    status = jnp.where(at_min | at_max, EXACT_HIT, status)
    return SelectResult(
        value=value, iters=s.iters, status=status.astype(jnp.int32),
        y_lo=s.yL, y_hi=s.yR, n_in=n_in,
    )


def _default_cap(n: int) -> int:
    # generous: >= 2 * sqrt-ish growth, bounded; paper observed |z| ~ 1-5% n.
    return int(min(max(4096, n // 64), 1 << 19))


def _default_cap_rows(n: int) -> int:
    # Batched regimes keep a (B, cap) compaction buffer, so the per-row cap
    # is tighter than the scalar default: a few more bracket iterations
    # (cheap fused passes, shared by the whole batch) buy a much smaller
    # batched sort.  Benchmarked in benchmarks/batched_selection_bench.py.
    return int(min(max(256, n // 64), 4096))


def _map_bracket_back_rows(x, xt, s: BatchState) -> BatchState:
    """Map a transformed-domain bracket back to original values, row-wise.

    F is monotone non-decreasing in fp on the data, so
        y_orig = max{x_i : F(x_i) <= y_t}
    preserves counts exactly: count(x <= y_orig) == count(F(x) <= y_t).
    Both loop invariants (c(y_L) < k <= c(y_R)) therefore transfer to the
    original domain, and the finalize stays exact.  On an exact hit the
    t-space image may merge several distinct originals (F is not injective
    in fp): collapse the bracket to the image's preimage set and drop the
    certificate — the original-space finalize re-resolves it.
    """
    neg = jnp.asarray(-jnp.inf, x.dtype)
    yL_t = jnp.where(s.found_exact, s.t_exact, s.yL)[:, None]
    yR_t = jnp.where(s.found_exact, s.t_exact, s.yR)[:, None]
    yL = jnp.where(
        s.found_exact,
        jnp.max(jnp.where(xt < yL_t, x, neg), axis=1),  # strict: preimage
        jnp.max(jnp.where(xt <= yL_t, x, neg), axis=1),
    )
    yR = jnp.max(jnp.where(xt <= yR_t, x, neg), axis=1)
    return s._replace(
        yL=yL, yR=yR,
        # exactness certificates do not survive the fp roundtrip:
        found_exact=jnp.zeros_like(s.found_exact),
    )


@functools.partial(
    jax.jit,
    static_argnames=("method", "maxit", "cap", "transform", "backend"),
)
def select_rows(
    x: jax.Array,
    k,
    *,
    method: str = "cp",
    maxit: int = 64,
    cap: Optional[int] = None,
    transform: Optional[str] = None,
    backend: Optional[str] = None,
) -> SelectResult:
    """Rows-mode batched selection: ``x`` is (B, n), ``k`` scalar or (B,).

    Every field of the returned :class:`SelectResult` is (B,)-shaped; row
    ``i`` solves the independent problem ``x[i], k[i]`` with the same
    exactness guarantees as the scalar solver (which is the B=1 view of this
    function).  ``backend`` selects the fused data pass
    ('jnp' | 'pallas' | 'pallas_interpret', default: pallas on TPU).
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; one of {METHODS}")
    if x.ndim != 2:
        raise ValueError(f"select_rows wants (B, n) data, got {x.shape}")
    b, n = x.shape
    if cap is None:
        cap = _default_cap_rows(n)
    cap = min(cap, n)
    ks = jnp.broadcast_to(jnp.clip(jnp.asarray(k, jnp.int32), 1, n), (b,))

    if method == "sort":
        xs = jnp.sort(x, axis=1)
        value = jnp.take_along_axis(xs, (ks - 1)[:, None], axis=1)[:, 0]
        zero = jnp.zeros((b,), jnp.int32)
        return SelectResult(
            value=value, iters=zero,
            status=jnp.full((b,), EXACT_HIT, jnp.int32),
            y_lo=xs[:, 0], y_hi=xs[:, -1],
            n_in=jnp.full((b,), n, jnp.int32),
        )

    if transform == "log1p":
        xt = transforms.log1p_transform_rows(x)
        s, _, _ = bracket_loop_batched(
            RowsEvaluator(xt, ks, backend=backend),
            method=method, maxit=maxit, cap=cap)
        s = _map_bracket_back_rows(x, xt, s)
        return _finalize_rows(x, ks, s, cap,
                              jnp.min(x, axis=1), jnp.max(x, axis=1))
    elif transform is not None:
        raise ValueError(f"unknown transform {transform!r}")

    ev = RowsEvaluator(x, ks, backend=backend)
    s, xmin, xmax = bracket_loop_batched(ev, method=method, maxit=maxit,
                                         cap=cap)
    return _finalize_rows(x, ks, s, cap, xmin, xmax)


def order_statistic(
    x: jax.Array,
    k,
    *,
    method: str = "cp",
    maxit: int = 64,
    cap: Optional[int] = None,
    transform: Optional[str] = None,
    backend: Optional[str] = None,
) -> SelectResult:
    """k-th smallest element of ``x`` (k is 1-indexed, may be traced).

    The ``B = 1`` view of :func:`select_rows`.  ``method`` in {"cp",
    "cp_hybrid", "bisection", "golden", "brent", "sort"}.  ``cp`` and
    ``cp_hybrid`` are aliases (the hybrid finalize is always on — it is what
    makes the result exact).  ``transform='log1p'`` applies the paper's
    monotone guard for extreme-valued data (Sec. V-D).
    """
    x = x.reshape(-1)
    if cap is None:
        cap = _default_cap(x.size)  # scalar policy: one generous buffer
    res = select_rows(
        x[None, :], jnp.asarray(k, jnp.int32).reshape(1),
        method=method, maxit=maxit, cap=cap, transform=transform,
        backend=backend,
    )
    return jax.tree.map(lambda a: a[0], res)


def median(x: jax.Array, **kw) -> SelectResult:
    """Med(x) = x_([(n+1)/2]) (paper Sec. I convention)."""
    n = x.size
    return order_statistic(x, (n + 1) // 2, **kw)


def quantile(x: jax.Array, q, **kw) -> SelectResult:
    """Lower empirical q-quantile: x_(ceil(q*n)) clipped to [1, n]."""
    n = x.size
    k = jnp.clip(jnp.ceil(jnp.asarray(q) * n).astype(jnp.int32), 1, n)
    return order_statistic(x, k, **kw)


def topk_threshold(x: jax.Array, m, **kw) -> SelectResult:
    """Value of the m-th largest element (for kNN / trimming)."""
    n = x.size
    return order_statistic(x, n - jnp.asarray(m, jnp.int32) + 1, **kw)


@functools.partial(
    jax.jit,
    static_argnames=("method", "maxit", "cap", "transform", "backend"),
)
def multi_order_statistic(
    x: jax.Array,
    ks,
    *,
    method: str = "cp",
    maxit: int = 64,
    cap: Optional[int] = None,
    transform: Optional[str] = None,
    backend: Optional[str] = None,
) -> SelectResult:
    """Several order statistics of the SAME array at once (shared-x mode).

    All K brackets iterate together against the multi-pivot fused kernel:
    each iteration reads ``x`` ONCE and evaluates every live pivot from the
    resident tile (on TPU: one VMEM load per tile for all K pivots) — the
    cheap way to get (p25, p50, p75, p99, ...) telemetry sets.  The finalize
    broadcasts ``x`` across the K rows for the O(1) compaction passes only;
    the ``maxit`` hot iterations never duplicate the data.
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; one of {METHODS}")
    x = x.reshape(-1)
    n = x.size
    ks = jnp.clip(jnp.asarray(ks, jnp.int32).reshape(-1), 1, n)
    nk = ks.shape[0]
    if cap is None:
        cap = _default_cap_rows(n)
    cap = min(cap, n)

    if method == "sort":
        xs = jax.lax.sort(x)
        zero = jnp.zeros((nk,), jnp.int32)
        return SelectResult(
            value=xs[ks - 1], iters=zero,
            status=jnp.full((nk,), EXACT_HIT, jnp.int32),
            y_lo=jnp.broadcast_to(xs[0], (nk,)),
            y_hi=jnp.broadcast_to(xs[-1], (nk,)),
            n_in=jnp.full((nk,), n, jnp.int32),
        )

    if transform == "log1p":
        xt, _ = transforms.log1p_transform(x)
        s, _, _ = bracket_loop_batched(
            SharedEvaluator(xt, ks, backend=backend),
            method=method, maxit=maxit, cap=cap)
        xb = jnp.broadcast_to(x[None, :], (nk, n))
        s = _map_bracket_back_rows(xb, jnp.broadcast_to(xt[None, :],
                                                        (nk, n)), s)
        bcast = lambda v: jnp.broadcast_to(v, (nk,))
        return _finalize_rows(xb, ks, s, cap,
                              bcast(jnp.min(x)), bcast(jnp.max(x)))
    elif transform is not None:
        raise ValueError(f"unknown transform {transform!r}")

    ev = SharedEvaluator(x, ks, backend=backend)
    s, xmin, xmax = bracket_loop_batched(ev, method=method, maxit=maxit,
                                         cap=cap)
    xb = jnp.broadcast_to(x[None, :], (nk, n))
    return _finalize_rows(xb, ks, s, cap, xmin, xmax)


def quantiles(x: jax.Array, qs, **kw) -> SelectResult:
    """Lower empirical quantiles at each q in ``qs`` (one shared-x solve)."""
    n = x.size
    ks = jnp.clip(jnp.ceil(jnp.asarray(qs) * n).astype(jnp.int32), 1, n)
    return multi_order_statistic(x, ks, **kw)


# ---------------------------------------------------------------------------
# Scalar views of the engine internals (kernel-backend plumbing and tests)
# ---------------------------------------------------------------------------


class _ScalarFnEvaluator:
    """Adapter lifting a scalar ``eval_fn(t) -> FG`` plus 1-D data into the
    (B=1,) evaluator protocol — lets callers drive the batched engine with a
    custom scalar backend (see tests/test_kernels.py)."""

    def __init__(self, x, k, eval_fn):
        self.x = x = x.reshape(-1)
        self._eval_fn = eval_fn
        self.n = jnp.asarray(x.size, jnp.int32)
        self.k = jnp.clip(jnp.asarray(k, jnp.int32), 1, x.size).reshape(1)

    def __call__(self, y: jax.Array) -> FG:
        fg = self._eval_fn(y.reshape(()))
        return FG(*(jnp.reshape(v, (1,)) for v in fg))

    def init_stats(self):
        x = self.x
        one = lambda v: jnp.reshape(v, (1,))
        return (one(jnp.min(x)), one(jnp.max(x)),
                one(jnp.mean(x, dtype=x.dtype)))


def _bracket_loop(x, k, *, method, maxit, cap, eval_fn=None):
    """Scalar (B=1) view of :func:`bracket_loop_batched`.

    Returns ``(state with (1,)-shaped fields, xmin, xmax)``; ``eval_fn``
    overrides the data pass with a custom scalar FG backend.
    """
    x = x.reshape(-1)
    if eval_fn is None:
        ev = RowsEvaluator(x[None, :],
                           jnp.asarray(k, jnp.int32).reshape(1))
    else:
        ev = _ScalarFnEvaluator(x, k, eval_fn)
    s, xmin, xmax = bracket_loop_batched(ev, method=method, maxit=maxit,
                                         cap=cap)
    return s, xmin[0], xmax[0]


def _finalize(x, k, s: BatchState, cap, xmin, xmax) -> SelectResult:
    """Scalar (B=1) view of :func:`_finalize_rows`."""
    x = x.reshape(-1)
    one = lambda v: jnp.reshape(jnp.asarray(v), (1,))
    res = _finalize_rows(
        x[None, :], jnp.asarray(k, jnp.int32).reshape(1), s, cap,
        one(xmin).astype(x.dtype), one(xmax).astype(x.dtype))
    return jax.tree.map(lambda a: a[0], res)
