"""Selection (k-th order statistic) by convex minimization — Beliakov (2011).

Batched-first architecture
--------------------------
The engine is *batched-first*: the bracket loop, the exact-hit certificates
and the hybrid finalize all operate on ``(B,)`` state vectors, fed by an
:class:`repro.core.objective.Evaluator` (pivots ``(B,)`` -> ``FG`` partials
``(B,)``).  Scalar selection is the ``B = 1`` view.  Two batched regimes:

* **rows mode** (:func:`select_rows`) — ``(B, n)`` independent problems with
  per-row ``k``, driven by the row-wise fused kernel
  (``kernels.ops.fused_partials_batched``).  This is the production workload:
  coordinate-wise medians, LMS/LTS concentration over elemental starts, kNN
  cutoff rows.
* **shared-x mode** (:func:`multi_order_statistic` / :func:`quantiles`) — ONE
  array, ``(K,)`` target ranks, driven by the multi-pivot Pallas kernel
  (``kernels.ops.fused_partials_multi``) that reads each ``x`` tile into VMEM
  once and emits partials for all K live pivots — K× less HBM traffic than K
  lock-stepped independent solves.

Methods (shared skeleton, they differ only in the next-pivot proposal):

* ``binned``    — binned bracket descent (default for large n): each data
  pass histograms the live bracket into ``nbins`` sub-intervals, so one
  sweep buys log2(nbins) bisection-equivalents of narrowing (Tibshirani's
  successive-binning, arXiv:0806.3301, generalized to any order statistic
  and to batched/sharded data).  Phase 1 runs ~2-3 histogram sweeps until
  every row's in-bracket count is under ``cap``; phase 2 compacts the
  survivors into the ``(B, cap)`` buffer and finalizes exactly — O(cap)
  work on O(n) data touched ~3 times instead of ~15.
* ``cp``        — Kelley's cutting-plane method (Algorithm 1 of the paper).
* ``bisection`` — classical bisection on the subgradient sign (paper Sec. III).
* ``golden``    — golden-section-style bracket shrink (paper baseline).
* ``brent``     — parabolic fit with bisection safeguard (paper baseline).
* ``sort``      — full ``jnp.sort`` (the paper's "GPU radix sort" baseline).

Each iteration costs exactly one fused pass over the data — the paper's
``maxit + O(1)`` parallel reductions — regardless of how many problems ride
in the batch; ``binned`` needs ~3 such passes where ``cp`` needs ~15.
``method=None`` (the default) resolves per backend: ``binned`` for
``n >= BINNED_MIN_N`` on the Pallas kernel path (where a histogram sweep
costs the same HBM traffic as an FG pass), ``cp`` otherwise (the CPU jnp
histogram is scatter-bound — see ``_resolve_method``).

Exactness: unlike the paper (which stops on a float tolerance and then scans
for the largest ``x_i <= y~``), we carry the counts ``n_lt / n_le`` through
the loop PER ROW, which yields

  1. an *exact-hit* certificate ``n_lt < k <= n_le  =>  pivot == x_(k)``;
  2. a count-based stopping rule ``count(y_L < x <= y_R) <= cap`` that turns
     the paper's dynamic-size ``copy_if`` into a *static-shape* fixed-capacity
     compaction (required for ``jit``), performed row-wise into a
     ``(B, cap)`` buffer sorted in one batched sort;
  3. a tie-safe fallback: if more than ``cap`` duplicates of ``x_(k)`` exist
     in a row, the next distinct value above that row's ``y_L`` is verified
     by one extra counting pass.

Rows stop independently (per-row live mask); the loop exits when every row
has either certified an exact hit or shrunk its pivot interval under ``cap``.

Invariants maintained per row (proved by the subdifferential signs, see
``objective.py``):   count(x <= y_L) < k <= count(x <= y_R).

``transform='log1p'`` and the batched finalize: the loop runs on the
monotone image ``F(x) = log1p(x - min(x))`` (per row in rows mode), and the
final bracket is mapped back to original values *data-consistently* before
the exact finalize — ``y_orig = max{x_i : F(x_i) <= y_t}`` preserves counts
exactly, so the row invariants transfer and the compaction/tie logic runs on
untransformed data.  Exact-hit certificates do NOT survive the fp roundtrip
(F is not injective in fp): they are dropped per row and re-derived by the
original-space finalize.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.objective import (
    FG,
    WFG,
    Evaluator,
    RowsEvaluator,
    SharedEvaluator,
    _weight_accum_dtype,
    os_weights,
)
from repro.core import transforms

METHODS = ("binned", "cp", "cp_hybrid", "bisection", "golden", "brent",
           "sort")

# method=None resolution: histogram sweeps win once the O(n) data pass
# dominates (~3 sweeps vs ~15 CP passes); below this the per-sweep bin
# bookkeeping isn't worth it and Kelley cuts converge in microseconds.
BINNED_MIN_N = 1 << 16

# Sub-intervals per histogram sweep (one sweep = log2(128) = 7
# bisection-equivalents of bracket narrowing); the kernels take the bin
# count from the edge array the engine builds with this default.
DEF_NBINS = 128


def _resolve_method(method: Optional[str], n: int,
                    backend: Optional[str] = None) -> str:
    """``None``/``'auto'`` -> 'binned' on the kernel path for large n.

    The binned descent is a bandwidth trade: each sweep touches the data
    once (like a fused FG pass) but buys log2(nbins) bisection steps, so it
    wins wherever the pass cost is HBM-bound — the Pallas kernel path.  On
    the CPU jnp fallback a histogram sweep is scatter/searchsorted-bound
    (~25x a fused pass at 1M elements, see BENCH_selection.json), so auto
    keeps 'cp' there; callers can still force ``method='binned'`` (exact on
    every backend, and the pass-count telemetry is what the perf trajectory
    tracks).
    """
    if method in (None, "auto"):
        from repro.kernels.ops import _on_tpu  # deferred: core <-> kernels

        kernel_path = backend == "pallas" or (backend is None and _on_tpu())
        return "binned" if (kernel_path and n >= BINNED_MIN_N) else "cp"
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; one of {METHODS}")
    return method

# Status codes for SelectResult.status
EXACT_HIT = 0       # pivot certified equal to x_(k) during iterations
HYBRID_SORT = 1     # answer from compact+sort of the pivot interval
TIE_FALLBACK = 2    # answer = next distinct value, certified by counts
NOT_CONVERGED = 3   # approximate answer (bracket right end)


class SelectResult(NamedTuple):
    value: jax.Array        # the order statistic (exact unless status==3)
    iters: jax.Array        # number of f/g evaluations this row was live for
    status: jax.Array       # see codes above
    y_lo: jax.Array         # final bracket
    y_hi: jax.Array
    n_in: jax.Array         # count(y_lo < x <= y_hi) at exit


class BatchState(NamedTuple):
    """Bracket-loop state; every field is (B,)-shaped except the scalar
    global iteration counter ``it`` (frozen rows stop updating but the batch
    iterates until all rows are done)."""
    yL: jax.Array
    fL: jax.Array
    gL: jax.Array   # right one-sided derivative at yL (< 0)
    yR: jax.Array
    fR: jax.Array
    gR: jax.Array   # left one-sided derivative at yR (> 0)
    cleL: jax.Array  # lower bound on count(x <= yL)  (exact after 1st move)
    cleR: jax.Array  # exact count(x <= yR)
    t_exact: jax.Array
    found_exact: jax.Array
    iters: jax.Array  # per-row live-iteration count
    it: jax.Array     # global (batch) iteration count
    # golden/brent bookkeeping: previous probe (for parabolic fit)
    tp: jax.Array
    fp: jax.Array


def _propose_cp(s: BatchState):
    """Kelley cut intersection: minimizer of max of the two support lines."""
    return (s.fR - s.fL + s.yL * s.gL - s.yR * s.gR) / (s.gL - s.gR)


def _propose_bisection(s: BatchState):
    return 0.5 * (s.yL + s.yR)


_INV_GOLDEN = 0.381966011250105  # 2 - golden ratio


def _propose_golden(s: BatchState):
    # Shrink from the side whose objective value is larger (descent side).
    left = s.fL > s.fR
    w = jnp.where(left, _INV_GOLDEN, 1.0 - _INV_GOLDEN)
    return s.yL + w * (s.yR - s.yL)


def _propose_brent(s: BatchState):
    """Parabola through (yL,fL), (tp,fp), (yR,fR); midpoint safeguard."""
    x1, f1, x2, f2, x3, f3 = s.yL, s.fL, s.tp, s.fp, s.yR, s.fR
    num = (x2 - x1) ** 2 * (f2 - f3) - (x2 - x3) ** 2 * (f2 - f1)
    den = (x2 - x1) * (f2 - f3) - (x2 - x3) * (f2 - f1)
    ok = jnp.abs(den) > 1e-30
    t = x2 - 0.5 * num / jnp.where(ok, den, 1.0)
    mid = 0.5 * (s.yL + s.yR)
    inside = (t > s.yL) & (t < s.yR)
    return jnp.where(ok & inside, t, mid)


_PROPOSALS = {
    "cp": _propose_cp,
    "cp_hybrid": _propose_cp,
    "bisection": _propose_bisection,
    "golden": _propose_golden,
    "brent": _propose_brent,
}


def _live(s: BatchState, cap):
    return (~s.found_exact) & (s.cleR - s.cleL > cap) & (s.yR > s.yL)


def _seed_state(ev: Evaluator, found0, t0):
    """Shared loop seed: analytic bracket/cut init from one stats pass.

    Returns ``(s0, xmin, xmax, kk, dtype)``; used by both the cutting-plane
    loop and the binned histogram loop (the f/g fields are only meaningful
    to the former).  The slopes use the conservative tie count 1, which
    keeps the support lines *lower* bounds (valid cuts) even with
    duplicated extremes.
    """
    xmin, xmax, xmean = ev.init_stats()
    k = ev.k
    shape = jnp.broadcast_shapes(jnp.shape(xmin), jnp.shape(k))
    dtype = xmin.dtype
    nf = jnp.broadcast_to(jnp.asarray(ev.n, dtype), shape)
    kk = jnp.broadcast_to(jnp.asarray(k, jnp.int32), shape)
    alpha, beta = os_weights(nf, kk, dtype)
    bc = lambda v: jnp.broadcast_to(jnp.asarray(v, dtype), shape)

    # Analytic init at the extremes (paper: single fused reduction).
    xmin, xmax, xmean = bc(xmin), bc(xmax), bc(xmean)
    fL0 = beta * (xmean - xmin)
    fR0 = alpha * (xmax - xmean)
    gL0 = alpha * (1.0 / nf) - beta * (nf - 1.0) / nf
    gR0 = alpha * (nf - 1.0) / nf - beta * (1.0 / nf)

    if found0 is None:
        found0 = jnp.zeros(shape, bool)
    if t0 is None:
        t0 = jnp.full(shape, jnp.nan, dtype)
    s0 = BatchState(
        yL=xmin, fL=fL0, gL=gL0,
        yR=xmax, fR=fR0, gR=gR0,
        cleL=jnp.ones(shape, jnp.int32),   # count(x<=min) >= 1 (conservative)
        cleR=jnp.broadcast_to(jnp.asarray(ev.n, jnp.int32), shape),
        t_exact=t0,
        found_exact=jnp.broadcast_to(found0, shape),
        iters=jnp.zeros(shape, jnp.int32),
        it=jnp.asarray(0, jnp.int32),
        tp=0.5 * (xmin + xmax), fp=jnp.maximum(fL0, fR0),
    )
    return s0, xmin, xmax, kk, dtype


def bracket_loop_batched(
    ev: Evaluator,
    *,
    method: str = "cp",
    maxit: int = 64,
    cap=0,
    found0: Optional[jax.Array] = None,
    t0: Optional[jax.Array] = None,
):
    """Run the batched bracket-shrinking loop against an evaluator.

    ``ev`` owns the data; this loop only sees ``(B,)`` vectors.  ``cap`` is
    the per-row stopping count (0 = iterate to exact hit / maxit, the
    distributed across-axis regime).  ``found0``/``t0`` pre-seed rows whose
    answer is already certified (e.g. extreme ranks) so they never go live.

    Returns ``(final BatchState, xmin, xmax)`` with per-row extremes.
    """
    propose = _PROPOSALS[method]
    s0, xmin, xmax, kk, dtype = _seed_state(ev, found0, t0)

    def cond(s: BatchState):
        return (s.it < maxit) & jnp.any(_live(s, cap))

    def body(s: BatchState):
        lv = _live(s, cap)
        t = propose(s)
        # numerical safeguard: keep strictly inside the open bracket (frozen
        # rows get the midpoint — their updates are masked out anyway)
        bad = ~jnp.isfinite(t) | (t <= s.yL) | (t >= s.yR)
        t = jnp.where(bad, 0.5 * (s.yL + s.yR), t).astype(dtype)
        fg: FG = ev(t)
        exact = (fg.n_lt < kk) & (kk <= fg.n_le) & lv
        # exact => 0 in [g_lo, g_hi] => g_hi >= 0, so the two are disjoint:
        move_left = (fg.g_hi < 0) & lv   # t strictly left of the minimizer
        move_right = lv & ~move_left & ~exact  # then g_lo > 0: strictly right
        return BatchState(
            yL=jnp.where(move_left, t, s.yL),
            fL=jnp.where(move_left, fg.f, s.fL),
            gL=jnp.where(move_left, fg.g_hi, s.gL),
            yR=jnp.where(move_right, t, s.yR),
            fR=jnp.where(move_right, fg.f, s.fR),
            gR=jnp.where(move_right, fg.g_lo, s.gR),
            cleL=jnp.where(move_left, fg.n_le, s.cleL),
            cleR=jnp.where(move_right, fg.n_le, s.cleR),
            t_exact=jnp.where(exact, t, s.t_exact),
            found_exact=s.found_exact | exact,
            iters=s.iters + lv.astype(jnp.int32),
            it=s.it + 1,
            tp=jnp.where(lv, t, s.tp), fp=jnp.where(lv, fg.f, s.fp),
        )

    return jax.lax.while_loop(cond, body, s0), xmin, xmax


def binned_descent_step(cum, edges, yL, yR, kk):
    """One binned-descent narrowing decision from prefix counts.

    ``cum[..., j] = count(x <= e_j)`` at the realized ``edges``
    ``(..., nbins+1)`` of the bracket ``[yL, yR]`` (leading dims = batch,
    possibly none); ``edges`` MUST be the same array the histogram pass
    binned against — it is computed once per sweep and shared, never
    recomputed (XLA FMA contraction makes recomputed edge arithmetic
    fusion-context-dependent).  Returns
    ``(yLn, yRn, cLn, cRn, jm1, jstar, hit_lo, exact, stall)``:

    * ``jstar`` — first edge whose prefix count reaches ``kk``; the answer
      lies in the single bin ``(e_{jstar-1}, e_jstar]``;
    * ``hit_lo`` — ``jstar == 0``, i.e. ``count(x <= yL) >= k``: possible
      only while ``yL`` is the initial minimum (afterwards the invariant
      ``count(x <= yL) < k`` forbids it), and certifies ``x_(k) == yL``;
    * ``exact`` — ``hit_lo`` or ulp-collapse: ``(yLn, yRn]`` holds a single
      representable value, so the invariant certifies ``x_(k) == yRn``;
    * ``stall`` — the chosen bin IS the whole bracket (bin width underflowed
      against denormal-scale data), or the prefix counts are inconsistent
      with the bracket invariant (``cum[-1] < k`` — NaN data, a kernel
      miscount): no trustworthy progress is possible, the caller should
      freeze this problem and let its finalize fallback resolve it.

    This is the exactness-critical core of the binned method, shared by the
    batched loop below and the distributed loop in ``core.distributed`` —
    keep it the single implementation.
    """
    reached = cum >= kk[..., None]
    jstar = jnp.argmax(reached, axis=-1).astype(jnp.int32)
    jm1 = jnp.maximum(jstar - 1, 0)
    take = lambda a, i: jnp.take_along_axis(a, i[..., None], axis=-1)[..., 0]
    yLn, yRn = take(edges, jm1), take(edges, jstar)
    cLn, cRn = take(cum, jm1), take(cum, jstar)
    # count-invariant sanity: count(x <= yR) >= k must hold; if it doesn't,
    # argmax over all-False returned 0 and NOTHING below may certify — a
    # violated invariant must fail safe (stall), never mint EXACT_HIT.
    ok = reached[..., -1]
    hit_lo = (jstar == 0) & reached[..., 0]
    collapse = transforms.next_float(yLn) >= yRn
    exact = (hit_lo | collapse) & ok
    stall = ~exact & (~ok | ((yLn == yL) & (yRn == yR)))
    return yLn, yRn, cLn, cRn, jm1, jstar, hit_lo, exact, stall


def binned_loop_batched(
    ev: Evaluator,
    *,
    nbins: int = DEF_NBINS,
    maxit: int = 16,
    cap=0,
    found0: Optional[jax.Array] = None,
    t0: Optional[jax.Array] = None,
):
    """Phase 1 of the binned two-phase schedule: histogram bracket descent.

    Each sweep builds the bracket's realized edges once
    (``kernels.ref.bin_edges``), calls ``ev.histogram(edges)`` — ONE fused
    data pass — and narrows every live row's bracket to the single
    sub-interval
    ``(e_{j-1}, e_j]`` whose prefix count straddles that row's rank
    (``count(x <= e_{j-1}) < k <= count(x <= e_j)``), a factor-``nbins``
    shrink per pass where the cutting-plane loop gets one pivot.  Rows stop
    independently once their in-bracket count is under ``cap`` (phase 2,
    the survivor compaction + exact finalize, takes over), on the exact
    certificates below, or at ``maxit``.

    Exactness bookkeeping mirrors the cutting-plane loop: brackets only move
    to REALIZED fp edge values whose prefix counts were measured, so the row
    invariant ``count(x <= yL) < k <= count(x <= yR)`` holds exactly at
    every step and transfers to the finalize (and across the log1p
    roundtrip).  Two in-loop certificates short-circuit a row: a first-sweep
    ``count(x <= xmin) >= k`` pins ``x_(k) = xmin``, and a bracket collapsed
    to one representable value ``(yL, nextafter(yL)]`` pins ``x_(k) = yR``.

    Returns ``(BatchState, xmin, xmax)`` like :func:`bracket_loop_batched`;
    the f/g cut fields keep their analytic seeds (the binned proposal never
    reads them), and ``iters`` counts histogram sweeps.
    """
    from repro.kernels.ref import bin_edges  # deferred: core <-> kernels

    s0, xmin, xmax, kk, dtype = _seed_state(ev, found0, t0)
    # Brackets narrow to realized fp edge values and the finalize recounts
    # against exactly those values, so the loop state must not round edges
    # through a storage dtype below the kernels' f32 accumulation (bf16
    # data would otherwise round yL up and break the count invariant).
    dt = jnp.promote_types(dtype, jnp.float32)
    s0 = s0._replace(yL=s0.yL.astype(dt), yR=s0.yR.astype(dt),
                     t_exact=s0.t_exact.astype(dt))
    stalled0 = jnp.zeros(s0.found_exact.shape, bool)

    def live(s, stalled):
        return _live(s, cap) & ~stalled

    def cond(carry):
        s, stalled = carry
        return (s.it < maxit) & jnp.any(live(s, stalled))

    def body(carry):
        s, stalled = carry
        lv = live(s, stalled)
        # the realized edges are computed ONCE here and shared by the data
        # pass and the narrowing decision (the exactness contract)
        edges = bin_edges(s.yL, s.yR, nbins)
        cnt, _sums = ev.histogram(edges)
        # prefix counts at the realized edges: cum[..., j] = count(x <= e_j)
        cum = jnp.cumsum(cnt[..., :-1], axis=-1)
        yLn, yRn, cLn, cRn, _, _, hit_lo, exact, stall = \
            binned_descent_step(cum, edges, s.yL, s.yR, kk)
        exact = lv & exact
        t_ex = jnp.where(hit_lo, s.yL, yRn)
        # stalled rows freeze; the finalize's fallback chain resolves them
        # from the current bracket instead of burning sweeps to maxit
        stall_n = lv & stall
        upd = lv & ~exact & ~stall_n
        s = s._replace(
            yL=jnp.where(upd, yLn, s.yL),
            yR=jnp.where(upd, yRn, s.yR),
            cleL=jnp.where(upd, cLn, s.cleL),
            cleR=jnp.where(upd, cRn, s.cleR),
            t_exact=jnp.where(exact, t_ex, s.t_exact),
            found_exact=s.found_exact | exact,
            iters=s.iters + lv.astype(jnp.int32),
            it=s.it + 1,
        )
        return s, stalled | stall_n

    s, _ = jax.lax.while_loop(cond, body, (s0, stalled0))
    return s, xmin, xmax


def _run_bracket_phase(ev, method, maxit, cap, nbins):
    """Dispatch the phase-1 loop for a resolved method."""
    if method == "binned":
        return binned_loop_batched(ev, nbins=nbins, maxit=maxit, cap=cap)
    return bracket_loop_batched(ev, method=method, maxit=maxit, cap=cap)


def _compact_interval(x, yL, yR, cap):
    """ONE problem's phase-2 survivor compaction + fallback probes (1-D x).

    The paper's ``copy_if`` as a static-shape gather: the open pivot
    interval ``(yL, yR]`` lands in a ``(cap,)`` buffer (slot ``cap`` is the
    overflow trash slot), alongside the count certificates the answer
    assembly needs — ``c_L = count(x <= yL)``, the in-bracket count, the
    next distinct value above ``yL`` and its inclusive count (tie fallback
    verification).  Everything downstream is O(cap), not O(n).
    """
    big = jnp.asarray(jnp.inf, x.dtype)
    mask_in = (x > yL) & (x <= yR)
    cL = jnp.sum(x <= yL, dtype=jnp.int32)
    n_in = jnp.sum(mask_in, dtype=jnp.int32)
    pos = jnp.cumsum(mask_in.astype(jnp.int32)) - 1
    idx = jnp.where(mask_in, jnp.minimum(pos, cap), cap)
    z = jnp.full((cap + 1,), big, x.dtype).at[idx].set(
        jnp.where(mask_in, x, big))
    vnext = jnp.min(jnp.where(x > yL, x, big))
    n_le_v = jnp.sum(x <= vnext, dtype=jnp.int32)
    return z[:cap], cL, n_in, vnext, n_le_v


def _assemble_answers(kk, s: BatchState, cap, zs, cL, n_in, vnext, n_le_v,
                      n_lt_max, xmin, xmax) -> SelectResult:
    """Per-problem answer/status cascade from compacted buffers + counts.

    Shared by the rows-mode and shared-x finalizes — all inputs are
    batch-shaped except the sorted ``(B, cap)`` buffer ``zs``.
    """
    sort_idx = jnp.clip(kk - cL - 1, 0, cap - 1)
    ans_sort = jnp.take_along_axis(zs, sort_idx[..., None], axis=-1)[..., 0]
    fallback_ok = (cL < kk) & (kk <= n_le_v)

    value = jnp.where(
        s.found_exact,
        s.t_exact,
        jnp.where(n_in <= cap, ans_sort,
                  jnp.where(fallback_ok, vnext, s.yR)),
    )
    status = jnp.where(
        s.found_exact,
        EXACT_HIT,
        jnp.where(
            n_in <= cap,
            HYBRID_SORT,
            jnp.where(fallback_ok, TIE_FALLBACK, NOT_CONVERGED),
        ),
    )
    # Extreme-tie shortcuts (the bracket invariant c(y_L) < k only holds for
    # answers strictly inside the data range): if count(x <= y_L) >= k the
    # answer is at or below y_L, which can only be x_(1)=min (y_L starts at
    # the min and only moves to points certified count(x<=t) < k).  Symmetric
    # test at the max.  Also covers k==1, k==n and all-equal rows.
    at_min = cL >= kk
    at_max = n_lt_max < kk
    value = jnp.where(at_min, xmin, jnp.where(at_max, xmax, value))
    status = jnp.where(at_min | at_max, EXACT_HIT, status)
    return SelectResult(
        value=value, iters=s.iters, status=status.astype(jnp.int32),
        y_lo=s.yL, y_hi=s.yR, n_in=n_in,
    )


def _finalize_rows(x, ks, s: BatchState, cap, xmin, xmax) -> SelectResult:
    """Exact per-row recovery from the final brackets.  Two fused passes.

    Pass 1 (the paper's ``copy_if`` + count, row-wise): compact each row's
    open pivot interval into a fixed ``(B, cap)`` buffer, count
    ``c_L = count(x<=y_L)`` and find the next distinct value above ``y_L``;
    one batched sort of the (B, cap) buffer.
    Pass 2 (tie fallback verification): ``count(x <= vnext)`` per row.
    """
    b, n = x.shape
    kk = jnp.broadcast_to(jnp.asarray(ks, jnp.int32), (b,))
    z, cL, n_in, vnext, n_le_v = jax.vmap(
        lambda xi, lo, hi: _compact_interval(xi, lo, hi, cap)
    )(x, s.yL, s.yR)
    zs = jnp.sort(z, axis=-1)
    n_lt_max = jnp.sum(x < xmax[:, None], axis=1, dtype=jnp.int32)
    return _assemble_answers(kk, s, cap, zs, cL, n_in, vnext, n_le_v,
                             n_lt_max, xmin, xmax)


def _finalize_shared(x, ks, s: BatchState, cap, xmin, xmax) -> SelectResult:
    """Shared-x exact finalize on per-pivot compacted buffers.

    The compaction runs per pivot against the ONE ``(n,)`` array
    (sequential ``lax.map`` over the K brackets), so peak memory stays
    O(n + K*cap) — the hot iterations (multi-bracket kernel) and the
    finalize now both avoid materializing ``(K, n)``.
    """
    x = x.reshape(-1)
    kk = jnp.asarray(ks, jnp.int32).reshape(-1)
    z, cL, n_in, vnext, n_le_v = jax.lax.map(
        lambda args: _compact_interval(x, args[0], args[1], cap),
        (s.yL, s.yR))
    zs = jnp.sort(z, axis=-1)
    # one shared pass: xmin/xmax are (K,) broadcasts of the global extremes
    n_lt_max = jnp.broadcast_to(
        jnp.sum(x < jnp.max(xmax), dtype=jnp.int32), kk.shape)
    return _assemble_answers(kk, s, cap, zs, cL, n_in, vnext, n_le_v,
                             n_lt_max, xmin, xmax)


def _default_cap(n: int) -> int:
    # generous: >= 2 * sqrt-ish growth, bounded; paper observed |z| ~ 1-5% n.
    return int(min(max(4096, n // 64), 1 << 19))


def _default_cap_rows(n: int) -> int:
    # Batched regimes keep a (B, cap) compaction buffer, so the per-row cap
    # is tighter than the scalar default: a few more bracket iterations
    # (cheap fused passes, shared by the whole batch) buy a much smaller
    # batched sort.  Benchmarked in benchmarks/batched_selection_bench.py.
    return int(min(max(256, n // 64), 4096))


def _map_bracket_back_rows(x, xt, s: BatchState) -> BatchState:
    """Map a transformed-domain bracket back to original values, row-wise.

    F is monotone non-decreasing in fp on the data, so
        y_orig = max{x_i : F(x_i) <= y_t}
    preserves counts exactly: count(x <= y_orig) == count(F(x) <= y_t).
    Both loop invariants (c(y_L) < k <= c(y_R)) therefore transfer to the
    original domain, and the finalize stays exact.  On an exact hit the
    t-space image may merge several distinct originals (F is not injective
    in fp): collapse the bracket to the image's preimage set and drop the
    certificate — the original-space finalize re-resolves it.
    """
    neg = jnp.asarray(-jnp.inf, x.dtype)
    yL_t = jnp.where(s.found_exact, s.t_exact, s.yL)[:, None]
    yR_t = jnp.where(s.found_exact, s.t_exact, s.yR)[:, None]
    yL = jnp.where(
        s.found_exact,
        jnp.max(jnp.where(xt < yL_t, x, neg), axis=1),  # strict: preimage
        jnp.max(jnp.where(xt <= yL_t, x, neg), axis=1),
    )
    yR = jnp.max(jnp.where(xt <= yR_t, x, neg), axis=1)
    return s._replace(
        yL=yL, yR=yR,
        # exactness certificates do not survive the fp roundtrip:
        found_exact=jnp.zeros_like(s.found_exact),
    )


def _map_bracket_back_shared(x, xt, s: BatchState) -> BatchState:
    """Shared-x analogue of :func:`_map_bracket_back_rows`: one ``(n,)``
    array, (K,) transformed brackets, mapped back by the same
    count-preserving preimage reductions — per pivot via ``lax.map`` so the
    ``(K, n)`` broadcast never materializes."""
    neg = jnp.asarray(-jnp.inf, x.dtype)
    x = x.reshape(-1)
    xt = xt.reshape(-1)

    def one(args):
        yL_t, yR_t, t_ex, found = args
        lo_t = jnp.where(found, t_ex, yL_t)
        hi_t = jnp.where(found, t_ex, yR_t)
        yL = jnp.where(
            found,
            jnp.max(jnp.where(xt < lo_t, x, neg)),  # strict: preimage
            jnp.max(jnp.where(xt <= lo_t, x, neg)),
        )
        yR = jnp.max(jnp.where(xt <= hi_t, x, neg))
        return yL, yR

    yL, yR = jax.lax.map(one, (s.yL, s.yR, s.t_exact, s.found_exact))
    return s._replace(
        yL=yL, yR=yR,
        # exactness certificates do not survive the fp roundtrip:
        found_exact=jnp.zeros_like(s.found_exact),
    )


@functools.partial(
    jax.jit,
    static_argnames=("method", "maxit", "cap", "transform", "backend",
                     "nbins"),
)
def select_rows(
    x: jax.Array,
    k,
    *,
    method: Optional[str] = None,
    maxit: int = 64,
    cap: Optional[int] = None,
    transform: Optional[str] = None,
    backend: Optional[str] = None,
    nbins: int = DEF_NBINS,
) -> SelectResult:
    """Rows-mode batched selection: ``x`` is (B, n), ``k`` scalar or (B,).

    Every field of the returned :class:`SelectResult` is (B,)-shaped; row
    ``i`` solves the independent problem ``x[i], k[i]`` with the same
    exactness guarantees as the scalar solver (which is the B=1 view of this
    function).  ``method=None`` resolves to 'binned' for n >= BINNED_MIN_N
    on the Pallas kernel path and 'cp' otherwise (see ``_resolve_method``);
    ``nbins`` sizes the binned histogram sweeps.  ``backend`` selects the
    fused data pass ('jnp' | 'pallas' | 'pallas_interpret', default: pallas
    on TPU).
    """
    if x.ndim != 2:
        raise ValueError(f"select_rows wants (B, n) data, got {x.shape}")
    b, n = x.shape
    method = _resolve_method(method, n, backend)
    if cap is None:
        cap = _default_cap_rows(n)
    cap = min(cap, n)
    ks = jnp.broadcast_to(jnp.clip(jnp.asarray(k, jnp.int32), 1, n), (b,))

    if method == "sort":
        xs = jnp.sort(x, axis=1)
        value = jnp.take_along_axis(xs, (ks - 1)[:, None], axis=1)[:, 0]
        zero = jnp.zeros((b,), jnp.int32)
        return SelectResult(
            value=value, iters=zero,
            status=jnp.full((b,), EXACT_HIT, jnp.int32),
            y_lo=xs[:, 0], y_hi=xs[:, -1],
            n_in=jnp.full((b,), n, jnp.int32),
        )

    if transform == "log1p":
        xt = transforms.log1p_transform_rows(x)
        s, _, _ = _run_bracket_phase(
            RowsEvaluator(xt, ks, backend=backend), method, maxit, cap,
            nbins)
        s = _map_bracket_back_rows(x, xt, s)
        return _finalize_rows(x, ks, s, cap,
                              jnp.min(x, axis=1), jnp.max(x, axis=1))
    elif transform is not None:
        raise ValueError(f"unknown transform {transform!r}")

    ev = RowsEvaluator(x, ks, backend=backend)
    s, xmin, xmax = _run_bracket_phase(ev, method, maxit, cap, nbins)
    return _finalize_rows(x, ks, s, cap, xmin, xmax)


def order_statistic(
    x: jax.Array,
    k,
    *,
    method: Optional[str] = None,
    maxit: int = 64,
    cap: Optional[int] = None,
    transform: Optional[str] = None,
    backend: Optional[str] = None,
    nbins: int = DEF_NBINS,
) -> SelectResult:
    """k-th smallest element of ``x`` (k is 1-indexed, may be traced).

    The ``B = 1`` view of :func:`select_rows`.  ``method`` in {"binned",
    "cp", "cp_hybrid", "bisection", "golden", "brent", "sort"}; ``None``
    resolves to 'binned' for large n on the Pallas kernel path, 'cp'
    otherwise (see ``_resolve_method``).
    ``cp`` and ``cp_hybrid`` are aliases (the hybrid finalize is always on —
    it is what makes the result exact).  ``transform='log1p'`` applies the
    paper's monotone guard for extreme-valued data (Sec. V-D).
    """
    x = x.reshape(-1)
    if cap is None:
        cap = _default_cap(x.size)  # scalar policy: one generous buffer
    res = select_rows(
        x[None, :], jnp.asarray(k, jnp.int32).reshape(1),
        method=method, maxit=maxit, cap=cap, transform=transform,
        backend=backend, nbins=nbins,
    )
    return jax.tree.map(lambda a: a[0], res)


def median(x: jax.Array, **kw) -> SelectResult:
    """Med(x) = x_([(n+1)/2]) (paper Sec. I convention)."""
    n = x.size
    return order_statistic(x, (n + 1) // 2, **kw)


def quantile(x: jax.Array, q, **kw) -> SelectResult:
    """Lower empirical q-quantile: x_(ceil(q*n)) clipped to [1, n]."""
    n = x.size
    k = jnp.clip(jnp.ceil(jnp.asarray(q) * n).astype(jnp.int32), 1, n)
    return order_statistic(x, k, **kw)


def topk_threshold(x: jax.Array, m, **kw) -> SelectResult:
    """Value of the m-th largest element (for kNN / trimming)."""
    n = x.size
    return order_statistic(x, n - jnp.asarray(m, jnp.int32) + 1, **kw)


@functools.partial(
    jax.jit,
    static_argnames=("method", "maxit", "cap", "transform", "backend",
                     "nbins"),
)
def multi_order_statistic(
    x: jax.Array,
    ks,
    *,
    method: Optional[str] = None,
    maxit: int = 64,
    cap: Optional[int] = None,
    transform: Optional[str] = None,
    backend: Optional[str] = None,
    nbins: int = DEF_NBINS,
) -> SelectResult:
    """Several order statistics of the SAME array at once (shared-x mode).

    All K brackets iterate together against the multi-pivot fused kernel:
    each iteration reads ``x`` ONCE and evaluates every live pivot from the
    resident tile (on TPU: one VMEM load per tile for all K pivots) — the
    cheap way to get (p25, p50, p75, p99, ...) telemetry sets.  The finalize
    compacts survivors per pivot straight from the ``(n,)`` array
    (:func:`_finalize_shared`), so neither the hot iterations nor the
    finalize ever materialize ``(K, n)``.
    """
    x = x.reshape(-1)
    n = x.size
    method = _resolve_method(method, n, backend)
    ks = jnp.clip(jnp.asarray(ks, jnp.int32).reshape(-1), 1, n)
    nk = ks.shape[0]
    if cap is None:
        cap = _default_cap_rows(n)
    cap = min(cap, n)

    if method == "sort":
        xs = jax.lax.sort(x)
        zero = jnp.zeros((nk,), jnp.int32)
        return SelectResult(
            value=xs[ks - 1], iters=zero,
            status=jnp.full((nk,), EXACT_HIT, jnp.int32),
            y_lo=jnp.broadcast_to(xs[0], (nk,)),
            y_hi=jnp.broadcast_to(xs[-1], (nk,)),
            n_in=jnp.full((nk,), n, jnp.int32),
        )

    if transform == "log1p":
        xt, _ = transforms.log1p_transform(x)
        s, _, _ = _run_bracket_phase(
            SharedEvaluator(xt, ks, backend=backend), method, maxit, cap,
            nbins)
        s = _map_bracket_back_shared(x, xt, s)
        bcast = lambda v: jnp.broadcast_to(v, (nk,))
        return _finalize_shared(x, ks, s, cap,
                                bcast(jnp.min(x)), bcast(jnp.max(x)))
    elif transform is not None:
        raise ValueError(f"unknown transform {transform!r}")

    ev = SharedEvaluator(x, ks, backend=backend)
    s, xmin, xmax = _run_bracket_phase(ev, method, maxit, cap, nbins)
    return _finalize_shared(x, ks, s, cap, xmin, xmax)


def quantiles(x: jax.Array, qs, **kw) -> SelectResult:
    """Lower empirical quantiles at each q in ``qs`` (one shared-x solve)."""
    n = x.size
    ks = jnp.clip(jnp.ceil(jnp.asarray(qs) * n).astype(jnp.int32), 1, n)
    return multi_order_statistic(x, ks, **kw)


# ---------------------------------------------------------------------------
# Weighted selection: counts generalized to weight mass
# ---------------------------------------------------------------------------
#
# The weighted k-th order statistic is the smallest element ``v`` whose
# cumulative weight ``W_le(v) = sum(w_i : x_i <= v)`` reaches the target
# mass ``wk`` — the minimizer of F_w(y) = sum_i w_i * rho(x_i - y) (see
# ``objective.py``).  The engine shape is IDENTICAL to the unweighted one:
#
# * the bracket loop's move/exact decisions compare weight MASSES against
#   ``wk`` (``W_lt < wk <= W_le`` is the element-hit certificate — it forces
#   positive mass AT the pivot, so a certified pivot is a data element);
# * the binned descent narrows against the cumulative-mass vector through
#   the SAME :func:`binned_descent_step` (its comparisons are ordering-only,
#   so integer counts and float masses take the same code path, and the
#   fail-safe gates — violated invariant => stall, never EXACT_HIT — carry
#   over to the weighted regime verbatim);
# * the survivor-compaction finalize resolves the exact answer among <= cap
#   survivors via SORTED PREFIX WEIGHTS: compact (value, weight) pairs,
#   sort by value, and pick the first prefix whose mass (on top of the
#   below-bracket mass) reaches ``wk``;
# * INTEGER element counts still ride the state: buffer capacity is a
#   count, so the cap-based stopping rule is unchanged.
#
# Uniform weights w_i == 1 with wk = k make every mass comparison an exact
# integer comparison, reproducing the unweighted decisions bit for bit.
#
# Exactness caveat (inherent to weighted selection in fp): weight masses
# accumulate in floating point, so when a cumulative mass lands within
# rounding distance of ``wk`` the <-vs-<= outcome depends on summation
# order.  With exactly-summable weights (integers, dyadic rationals with
# bounded total — incl. the uniform case) every comparison is exact and the
# result is bit-identical to the sorted-cumsum oracle; otherwise the result
# is still an element of ``x`` whose measured invariant certifies it, within
# one mass-rounding of the oracle's choice.  The late-sweep ``hit_lo``
# binned certificate is additionally demoted to a stall (only the first
# sweep can pin ``x_(wk) = xmin``): with inexact masses an ulp-flip could
# otherwise mint a non-element edge value.


def _seed_state_weighted(ev, found0, t0):
    """Weighted analogue of :func:`_seed_state`.

    The cut seeds use the mass-normalized coefficients ``alpha = (W - wk)/W``
    and ``beta = wk/W`` (zero-crossing exactly at mass ``wk``) and the
    conservative extreme slopes ``-wk/W`` / ``(W - wk)/W`` (no mass assumed
    at the extremes — flatter than the truth, so the support lines stay
    lower bounds).  ``f`` seeds anchor on the weighted mean.
    """
    xmin, xmax, wmean = ev.init_stats()
    wk = ev.k
    shape = jnp.broadcast_shapes(jnp.shape(xmin), jnp.shape(wk))
    dtype = xmin.dtype
    Wf = jnp.broadcast_to(jnp.asarray(ev.W, wk.dtype), shape)
    wkk = jnp.broadcast_to(wk, shape)
    bc = lambda v: jnp.broadcast_to(jnp.asarray(v, dtype), shape)

    xmin, xmax, wmean = bc(xmin), bc(xmax), bc(wmean)
    Wsafe = jnp.maximum(Wf, jnp.asarray(1e-30, Wf.dtype))
    alpha = ((Wf - wkk) / Wsafe).astype(dtype)
    beta = (wkk / Wsafe).astype(dtype)
    fL0 = beta * (wmean - xmin)
    fR0 = alpha * (xmax - wmean)
    gL0 = -beta
    gR0 = alpha

    if found0 is None:
        found0 = jnp.zeros(shape, bool)
    if t0 is None:
        t0 = jnp.full(shape, jnp.nan, dtype)
    s0 = BatchState(
        yL=xmin, fL=fL0, gL=gL0,
        yR=xmax, fR=fR0, gR=gR0,
        cleL=jnp.ones(shape, jnp.int32),   # count(x<=min) >= 1 (conservative)
        cleR=jnp.broadcast_to(jnp.asarray(ev.n, jnp.int32), shape),
        t_exact=t0,
        found_exact=jnp.broadcast_to(found0, shape),
        iters=jnp.zeros(shape, jnp.int32),
        it=jnp.asarray(0, jnp.int32),
        tp=0.5 * (xmin + xmax), fp=jnp.maximum(fL0, fR0),
    )
    return s0, xmin, xmax, wkk, dtype


def weighted_bracket_loop_batched(
    ev,
    *,
    method: str = "cp",
    maxit: int = 64,
    cap=0,
    found0: Optional[jax.Array] = None,
    t0: Optional[jax.Array] = None,
):
    """Weighted bracket-shrinking loop: :func:`bracket_loop_batched` with the
    move/exact decisions on weight masses.

    ``ev`` must be a weighted evaluator (``ev(y) -> WFG``, ``ev.k`` = target
    masses, ``ev.W`` = total mass).  The state is the shared
    :class:`BatchState`; ``cleL``/``cleR`` keep carrying INTEGER counts (the
    cap-based stopping rule bounds the compaction buffer, which is sized in
    elements, not mass).
    """
    propose = _PROPOSALS[method]
    s0, xmin, xmax, wkk, dtype = _seed_state_weighted(ev, found0, t0)

    def cond(s: BatchState):
        return (s.it < maxit) & jnp.any(_live(s, cap))

    def body(s: BatchState):
        lv = _live(s, cap)
        t = propose(s)
        bad = ~jnp.isfinite(t) | (t <= s.yL) | (t >= s.yR)
        t = jnp.where(bad, 0.5 * (s.yL + s.yR), t).astype(dtype)
        wfg: WFG = ev(t)
        # mass invariant replaces the count invariant: W_lt < wk <= W_le
        # certifies t == the weighted order statistic (positive mass at t)
        exact = (wfg.w_lt < wkk) & (wkk <= wfg.w_le) & lv
        move_left = (wfg.w_le < wkk) & lv   # == (g_hi < 0)
        move_right = lv & ~move_left & ~exact  # then W_lt >= wk
        return BatchState(
            yL=jnp.where(move_left, t, s.yL),
            fL=jnp.where(move_left, wfg.f, s.fL),
            gL=jnp.where(move_left, wfg.g_hi, s.gL),
            yR=jnp.where(move_right, t, s.yR),
            fR=jnp.where(move_right, wfg.f, s.fR),
            gR=jnp.where(move_right, wfg.g_lo, s.gR),
            cleL=jnp.where(move_left, wfg.n_le, s.cleL),
            cleR=jnp.where(move_right, wfg.n_le, s.cleR),
            t_exact=jnp.where(exact, t, s.t_exact),
            found_exact=s.found_exact | exact,
            iters=s.iters + lv.astype(jnp.int32),
            it=s.it + 1,
            tp=jnp.where(lv, t, s.tp), fp=jnp.where(lv, wfg.f, s.fp),
        )

    return jax.lax.while_loop(cond, body, s0), xmin, xmax


def weighted_binned_loop_batched(
    ev,
    *,
    nbins: int = DEF_NBINS,
    maxit: int = 16,
    cap=0,
    found0: Optional[jax.Array] = None,
    t0: Optional[jax.Array] = None,
):
    """Weighted histogram bracket descent (phase 1 of weighted 'binned').

    Each sweep histograms the live brackets ONCE — the weighted pass emits
    the per-slot ``(count, mass)`` pair — and narrows every row to the
    single bin whose cumulative MASS straddles that row's target ``wk``,
    through the same :func:`binned_descent_step` as the unweighted engine
    (its comparisons are ordering-only; float masses and integer counts
    take the same code path, so the fail-safe certificate gates carry
    over).  Integer prefix counts at the chosen edges keep feeding the
    cap-based stopping rule.

    The first-sweep ``hit_lo`` certificate pins ``xmin`` exactly as in the
    unweighted loop; on LATER sweeps ``hit_lo`` is demoted to a stall (in
    exact arithmetic the invariant mass(x <= yL) < wk forbids it, so a
    late fire can only be an inexact-mass ulp-flip — the fail-safe answer
    is the finalize's fallback chain, never a minted edge value).
    """
    from repro.kernels.ref import bin_edges  # deferred: core <-> kernels

    s0, xmin, xmax, wkk, dtype = _seed_state_weighted(ev, found0, t0)
    dt = jnp.promote_types(dtype, jnp.float32)
    s0 = s0._replace(yL=s0.yL.astype(dt), yR=s0.yR.astype(dt),
                     t_exact=s0.t_exact.astype(dt))
    stalled0 = jnp.zeros(s0.found_exact.shape, bool)

    def live(s, stalled):
        return _live(s, cap) & ~stalled

    def cond(carry):
        s, stalled = carry
        return (s.it < maxit) & jnp.any(live(s, stalled))

    def body(carry):
        s, stalled = carry
        lv = live(s, stalled)
        edges = bin_edges(s.yL, s.yR, nbins)
        cnt, wcnt, _wsum = ev.histogram(edges)
        # cumulative MASS at the realized edges drives the narrowing
        cumw = jnp.cumsum(wcnt[..., :-1], axis=-1)
        yLn, yRn, _, _, jm1, jstar, hit_lo, exact, stall = \
            binned_descent_step(cumw, edges, s.yL, s.yR, wkk)
        # integer prefix counts at the same edges feed the cap rule
        cumn = jnp.cumsum(cnt[..., :-1], axis=-1)
        take = lambda a, i: jnp.take_along_axis(
            a, i[..., None], axis=-1)[..., 0]
        cLn, cRn = take(cumn, jm1), take(cumn, jstar)
        # late hit_lo can only be an inexact-mass ulp-flip: fail safe
        late_hit_lo = hit_lo & (s.it > 0)
        exact = lv & exact & ~late_hit_lo
        t_ex = jnp.where(hit_lo, s.yL, yRn)
        stall_n = lv & (stall | late_hit_lo)
        upd = lv & ~exact & ~stall_n
        s = s._replace(
            yL=jnp.where(upd, yLn, s.yL),
            yR=jnp.where(upd, yRn, s.yR),
            cleL=jnp.where(upd, cLn, s.cleL),
            cleR=jnp.where(upd, cRn, s.cleR),
            t_exact=jnp.where(exact, t_ex, s.t_exact),
            found_exact=s.found_exact | exact,
            iters=s.iters + lv.astype(jnp.int32),
            it=s.it + 1,
        )
        return s, stalled | stall_n

    s, _ = jax.lax.while_loop(cond, body, (s0, stalled0))
    return s, xmin, xmax


def _run_weighted_bracket_phase(ev, method, maxit, cap, nbins):
    """Dispatch the weighted phase-1 loop for a resolved method."""
    if method == "binned":
        return weighted_binned_loop_batched(ev, nbins=nbins, maxit=maxit,
                                            cap=cap)
    return weighted_bracket_loop_batched(ev, method=method, maxit=maxit,
                                         cap=cap)


def _compact_interval_weighted(x, w, yL, yR, cap):
    """ONE problem's weighted survivor compaction (1-D ``x``/``w``).

    Like :func:`_compact_interval`, but the (value, weight) PAIRS land in
    aligned ``(cap,)`` buffers (trash slot ``cap``; pad values +inf, pad
    weights 0 so sorted prefix masses are unaffected), and the certificates
    are masses: ``cLw = mass(x <= yL)``, the next distinct value above
    ``yL`` with its inclusive mass (weighted tie-fallback verification).
    """
    big = jnp.asarray(jnp.inf, x.dtype)
    dtw = w.dtype
    mask_in = (x > yL) & (x <= yR)
    cL = jnp.sum(x <= yL, dtype=jnp.int32)
    cLw = jnp.sum(jnp.where(x <= yL, w, 0), dtype=dtw)
    n_in = jnp.sum(mask_in, dtype=jnp.int32)
    pos = jnp.cumsum(mask_in.astype(jnp.int32)) - 1
    idx = jnp.where(mask_in, jnp.minimum(pos, cap), cap)
    z = jnp.full((cap + 1,), big, x.dtype).at[idx].set(
        jnp.where(mask_in, x, big))
    zw = jnp.zeros((cap + 1,), dtw).at[idx].set(
        jnp.where(mask_in, w, 0))
    vnext = jnp.min(jnp.where(x > yL, x, big))
    w_le_v = jnp.sum(jnp.where(x <= vnext, w, 0), dtype=dtw)
    return z[:cap], zw[:cap], cL, cLw, n_in, vnext, w_le_v


def _assemble_answers_weighted(wkk, s: BatchState, cap, zs, zws, cLw, n_in,
                               vnext, w_le_v, w_lt_max, xmin,
                               xmax) -> SelectResult:
    """Weighted answer/status cascade: sorted-prefix-weight resolution.

    ``zs`` is the value-sorted ``(B, cap)`` survivor buffer, ``zws`` the
    aligned weights.  The in-buffer answer is the first survivor whose
    cumulative mass (on top of the below-bracket mass ``cLw``) reaches
    ``wk`` — the weighted generalization of indexing at ``k - cL``.
    """
    cumw = cLw[..., None] + jnp.cumsum(zws, axis=-1)
    reach = cumw >= wkk[..., None]
    sidx = jnp.argmax(reach, axis=-1).astype(jnp.int32)
    ans_sort = jnp.take_along_axis(zs, sidx[..., None], axis=-1)[..., 0]
    # the buffer certifies only when it holds every survivor AND its total
    # mass actually reaches wk (argmax over all-False must not certify)
    sort_ok = (n_in <= cap) & reach[..., -1]
    fallback_ok = (cLw < wkk) & (wkk <= w_le_v)

    value = jnp.where(
        s.found_exact,
        s.t_exact,
        jnp.where(sort_ok, ans_sort,
                  jnp.where(fallback_ok, vnext, s.yR)),
    )
    status = jnp.where(
        s.found_exact,
        EXACT_HIT,
        jnp.where(
            sort_ok,
            HYBRID_SORT,
            jnp.where(fallback_ok, TIE_FALLBACK, NOT_CONVERGED),
        ),
    )
    # Weighted extreme shortcuts: mass(x <= y_L) >= wk can only mean the
    # answer sits at or below y_L, which the invariant pins to the minimum;
    # symmetric test at the maximum (mass strictly below the max < wk).
    # Unlike the exact-count unweighted shortcuts, the masses here are
    # RE-MEASURED by a differently-ordered sum than the loop's histogram
    # psums, so a rounding flip near wk could fire them with the bracket
    # far from the extreme — gate on the only state the exact-arithmetic
    # invariant permits (bracket ends still AT the extremes); a gated-out
    # flip falls through to the sort/fallback chain (fail safe).
    at_min = (cLw >= wkk) & (s.yL == xmin)
    at_max = (w_lt_max < wkk) & (s.yR == xmax)
    value = jnp.where(at_min, xmin, jnp.where(at_max, xmax, value))
    status = jnp.where(at_min | at_max, EXACT_HIT, status)
    return SelectResult(
        value=value, iters=s.iters, status=status.astype(jnp.int32),
        y_lo=s.yL, y_hi=s.yR, n_in=n_in,
    )


def _finalize_rows_weighted(x, w, wkk, s: BatchState, cap, xmin,
                            xmax) -> SelectResult:
    """Weighted per-row exact recovery: compact (value, weight) pairs, one
    batched value-sort carrying the weights, sorted-prefix-mass answer."""
    z, zw, _cL, cLw, n_in, vnext, w_le_v = jax.vmap(
        lambda xi, wi, lo, hi: _compact_interval_weighted(xi, wi, lo, hi,
                                                          cap)
    )(x, w, s.yL, s.yR)
    order = jnp.argsort(z, axis=-1)
    zs = jnp.take_along_axis(z, order, axis=-1)
    zws = jnp.take_along_axis(zw, order, axis=-1)
    w_lt_max = jnp.sum(jnp.where(x < xmax[:, None], w, 0), axis=1,
                       dtype=w.dtype)
    return _assemble_answers_weighted(wkk, s, cap, zs, zws, cLw, n_in,
                                      vnext, w_le_v, w_lt_max, xmin, xmax)


def _finalize_shared_weighted(x, w, wkk, s: BatchState, cap, xmin,
                              xmax) -> SelectResult:
    """Shared-x weighted finalize: per-pivot compaction via ``lax.map``
    against the ONE ``(n,)`` array pair — O(n + K*cap) memory, exactly like
    the unweighted shared finalize."""
    x = x.reshape(-1)
    w = w.reshape(-1)
    z, zw, _cL, cLw, n_in, vnext, w_le_v = jax.lax.map(
        lambda args: _compact_interval_weighted(x, w, args[0], args[1], cap),
        (s.yL, s.yR))
    order = jnp.argsort(z, axis=-1)
    zs = jnp.take_along_axis(z, order, axis=-1)
    zws = jnp.take_along_axis(zw, order, axis=-1)
    w_lt_max = jnp.broadcast_to(
        jnp.sum(jnp.where(x < jnp.max(xmax), w, 0), dtype=w.dtype),
        wkk.shape)
    return _assemble_answers_weighted(wkk, s, cap, zs, zws, cLw, n_in,
                                      vnext, w_le_v, w_lt_max, xmin, xmax)


def _weighted_sort_cumsum(xs, cumw, wkk):
    """Answer/validity of the full-sort baseline: first sorted value whose
    cumulative mass reaches the target."""
    reach = cumw >= wkk[..., None]
    idx = jnp.argmax(reach, axis=-1).astype(jnp.int32)
    value = jnp.take_along_axis(xs, idx[..., None], axis=-1)[..., 0]
    # nothing reaches wk (all-False argmax): the target mass exceeds the
    # measured total — take the maximum, the limit of the definition
    value = jnp.where(reach[..., -1], value, xs[..., -1])
    return value


@functools.partial(
    jax.jit,
    static_argnames=("method", "maxit", "cap", "backend", "nbins"),
)
def weighted_select_rows(
    x: jax.Array,
    w: jax.Array,
    wk,
    *,
    method: Optional[str] = None,
    maxit: int = 64,
    cap: Optional[int] = None,
    backend: Optional[str] = None,
    nbins: int = DEF_NBINS,
) -> SelectResult:
    """Rows-mode weighted selection: ``x``/``w`` (B, n), ``wk`` scalar or
    (B,) target cumulative weights.

    Row ``i`` returns the smallest element ``v`` of ``x[i]`` with
    ``sum(w[i, x[i] <= v]) >= wk[i]`` (``wk`` is clipped to the row's total
    mass).  Weights must be non-negative; uniform weights with ``wk = k``
    reproduce :func:`select_rows` exactly.  ``method`` as in
    :func:`select_rows` minus ``transform`` support; ``'sort'`` is the
    weighted sort-cumsum baseline.
    """
    if x.ndim != 2:
        raise ValueError(f"weighted_select_rows wants (B, n) data, got "
                         f"{x.shape}")
    b, n = x.shape
    w = jnp.broadcast_to(jnp.asarray(w), x.shape)
    method = _resolve_method(method, n, backend)
    if cap is None:
        cap = _default_cap_rows(n)
    cap = min(cap, n)
    ev = RowsEvaluator(x, wk, backend=backend, weights=w)
    wkk = ev.k  # clipped target masses, accumulation dtype, (B,)

    if method == "sort":
        order = jnp.argsort(x, axis=1)
        xs = jnp.take_along_axis(x, order, axis=1)
        ws = jnp.take_along_axis(w.astype(wkk.dtype), order, axis=1)
        value = _weighted_sort_cumsum(xs, jnp.cumsum(ws, axis=1), wkk)
        zero = jnp.zeros((b,), jnp.int32)
        return SelectResult(
            value=value, iters=zero,
            status=jnp.full((b,), EXACT_HIT, jnp.int32),
            y_lo=xs[:, 0], y_hi=xs[:, -1],
            n_in=jnp.full((b,), n, jnp.int32),
        )

    s, xmin, xmax = _run_weighted_bracket_phase(ev, method, maxit, cap,
                                                nbins)
    return _finalize_rows_weighted(x, w.astype(wkk.dtype), wkk, s, cap,
                                   xmin, xmax)


def weighted_order_statistic(
    x: jax.Array,
    w: jax.Array,
    wk,
    *,
    method: Optional[str] = None,
    maxit: int = 64,
    cap: Optional[int] = None,
    backend: Optional[str] = None,
    nbins: int = DEF_NBINS,
) -> SelectResult:
    """Smallest element of ``x`` whose cumulative weight reaches ``wk``.

    The B = 1 view of :func:`weighted_select_rows`.  With ``w = ones`` and
    ``wk = k`` this is exactly :func:`order_statistic`.
    """
    x = x.reshape(-1)
    if cap is None:
        cap = _default_cap(x.size)  # scalar policy: one generous buffer
    res = weighted_select_rows(
        x[None, :], jnp.asarray(w).reshape(1, -1),
        jnp.asarray(wk).reshape(1),
        method=method, maxit=maxit, cap=cap, backend=backend, nbins=nbins,
    )
    return jax.tree.map(lambda a: a[0], res)


def _total_mass(x, w):
    """Total weight at the mass-accumulation dtype (the wk/W reference)."""
    return jnp.sum(w, dtype=_weight_accum_dtype(jnp.asarray(x), w))


def weighted_median(x: jax.Array, w: jax.Array, **kw) -> SelectResult:
    """Lower weighted median: smallest v with ``mass(x <= v) >= W/2``.

    Uniform weights reproduce :func:`median` (= x_([(n+1)/2])) exactly.
    """
    w = jnp.asarray(w).reshape(-1)
    return weighted_order_statistic(x, w, 0.5 * _total_mass(x, w), **kw)


def weighted_quantile(x: jax.Array, w: jax.Array, q, **kw) -> SelectResult:
    """Lower weighted q-quantile: smallest v with ``mass(x <= v) >= q*W``."""
    w = jnp.asarray(w).reshape(-1)
    W = _total_mass(x, w)
    return weighted_order_statistic(x, w, jnp.asarray(q, W.dtype) * W, **kw)


@functools.partial(
    jax.jit,
    static_argnames=("method", "maxit", "cap", "backend", "nbins"),
)
def weighted_multi_order_statistic(
    x: jax.Array,
    w: jax.Array,
    wks,
    *,
    method: Optional[str] = None,
    maxit: int = 64,
    cap: Optional[int] = None,
    backend: Optional[str] = None,
    nbins: int = DEF_NBINS,
) -> SelectResult:
    """Several weighted order statistics of the SAME array at once.

    Shared-x mode: all K target masses iterate together against the
    weighted multi-pivot kernels (each x/w tile read once per sweep for
    every live bracket), exactly like :func:`multi_order_statistic`.
    """
    x = x.reshape(-1)
    n = x.size
    w = jnp.broadcast_to(jnp.asarray(w).reshape(-1), x.shape)
    method = _resolve_method(method, n, backend)
    if cap is None:
        cap = _default_cap_rows(n)
    cap = min(cap, n)
    ev = SharedEvaluator(x, wks, backend=backend, weights=w)
    wkk = ev.k
    nk = wkk.shape[0]

    if method == "sort":
        order = jnp.argsort(x)
        xs = x[order]
        cumw = jnp.cumsum(w.astype(wkk.dtype)[order])
        value = _weighted_sort_cumsum(xs[None, :], cumw[None, :],
                                      wkk)  # broadcast over K targets
        zero = jnp.zeros((nk,), jnp.int32)
        return SelectResult(
            value=value, iters=zero,
            status=jnp.full((nk,), EXACT_HIT, jnp.int32),
            y_lo=jnp.broadcast_to(xs[0], (nk,)),
            y_hi=jnp.broadcast_to(xs[-1], (nk,)),
            n_in=jnp.full((nk,), n, jnp.int32),
        )

    s, xmin, xmax = _run_weighted_bracket_phase(ev, method, maxit, cap,
                                                nbins)
    return _finalize_shared_weighted(x, w.astype(wkk.dtype), wkk, s, cap,
                                     xmin, xmax)


def weighted_quantiles(x: jax.Array, w: jax.Array, qs, **kw) -> SelectResult:
    """Lower weighted quantiles at each q in ``qs`` (one shared-x solve)."""
    x = jnp.asarray(x).reshape(-1)
    w = jnp.asarray(w).reshape(-1)
    W = _total_mass(x, w)
    wks = jnp.asarray(qs, W.dtype).reshape(-1) * W
    return weighted_multi_order_statistic(x, w, wks, **kw)


# ---------------------------------------------------------------------------
# Scalar views of the engine internals (kernel-backend plumbing and tests)
# ---------------------------------------------------------------------------


class _ScalarFnEvaluator:
    """Adapter lifting a scalar ``eval_fn(t) -> FG`` plus 1-D data into the
    (B=1,) evaluator protocol — lets callers drive the batched engine with a
    custom scalar backend (see tests/test_kernels.py)."""

    def __init__(self, x, k, eval_fn):
        self.x = x = x.reshape(-1)
        self._eval_fn = eval_fn
        self.n = jnp.asarray(x.size, jnp.int32)
        self.k = jnp.clip(jnp.asarray(k, jnp.int32), 1, x.size).reshape(1)

    def __call__(self, y: jax.Array) -> FG:
        fg = self._eval_fn(y.reshape(()))
        return FG(*(jnp.reshape(v, (1,)) for v in fg))

    def init_stats(self):
        x = self.x
        one = lambda v: jnp.reshape(v, (1,))
        return (one(jnp.min(x)), one(jnp.max(x)),
                one(jnp.mean(x, dtype=x.dtype)))


def _bracket_loop(x, k, *, method, maxit, cap, eval_fn=None):
    """Scalar (B=1) view of :func:`bracket_loop_batched`.

    Returns ``(state with (1,)-shaped fields, xmin, xmax)``; ``eval_fn``
    overrides the data pass with a custom scalar FG backend.
    """
    x = x.reshape(-1)
    if eval_fn is None:
        ev = RowsEvaluator(x[None, :],
                           jnp.asarray(k, jnp.int32).reshape(1))
    else:
        ev = _ScalarFnEvaluator(x, k, eval_fn)
    s, xmin, xmax = bracket_loop_batched(ev, method=method, maxit=maxit,
                                         cap=cap)
    return s, xmin[0], xmax[0]


def _finalize(x, k, s: BatchState, cap, xmin, xmax) -> SelectResult:
    """Scalar (B=1) view of :func:`_finalize_rows`."""
    x = x.reshape(-1)
    one = lambda v: jnp.reshape(jnp.asarray(v), (1,))
    res = _finalize_rows(
        x[None, :], jnp.asarray(k, jnp.int32).reshape(1), s, cap,
        one(xmin).astype(x.dtype), one(xmax).astype(x.dtype))
    return jax.tree.map(lambda a: a[0], res)
