"""Selection (k-th order statistic) by convex minimization — Beliakov (2011).

Implements the paper's method set on a single shared skeleton:

* ``cp``        — Kelley's cutting-plane method (Algorithm 1 of the paper).
* ``bisection`` — classical bisection on the subgradient sign (paper Sec. III).
* ``golden``    — golden-section-style bracket shrink (paper baseline).
* ``brent``     — parabolic fit with bisection safeguard (paper baseline).
* ``sort``      — full ``jnp.sort`` (the paper's "GPU radix sort" baseline).

All iterative methods run the same ``lax.while_loop``; they differ only in the
*proposal* of the next pivot.  Each iteration costs exactly one fused pass
over the data (``objective.eval_partials``) — the paper's
``maxit + O(1)`` parallel reductions.

Exactness: unlike the paper (which stops on a float tolerance and then scans
for the largest ``x_i <= y~``), we carry the counts ``n_lt / n_le`` through
the loop, which yields

  1. an *exact-hit* certificate ``n_lt < k <= n_le  =>  pivot == x_(k)``;
  2. a count-based stopping rule ``count(y_L < x <= y_R) <= cap`` that turns
     the paper's dynamic-size ``copy_if`` into a *static-shape* fixed-capacity
     compaction (required for ``jit``);
  3. a tie-safe fallback: if more than ``cap`` duplicates of ``x_(k)`` exist,
     the next distinct value above ``y_L`` is verified by one extra counting
     pass.

Invariants maintained by the loop (proved by the subdifferential signs, see
``objective.py``):   count(x <= y_L) < k <= count(x <= y_R).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.objective import FG, eval_fg, fg_from_partials, os_weights
from repro.core import transforms

METHODS = ("cp", "cp_hybrid", "bisection", "golden", "brent", "sort")

# Status codes for SelectResult.status
EXACT_HIT = 0       # pivot certified equal to x_(k) during iterations
HYBRID_SORT = 1     # answer from compact+sort of the pivot interval
TIE_FALLBACK = 2    # answer = next distinct value, certified by counts
NOT_CONVERGED = 3   # approximate answer (bracket right end)


class SelectResult(NamedTuple):
    value: jax.Array        # the order statistic (exact unless status==3)
    iters: jax.Array        # number of f/g evaluations inside the loop
    status: jax.Array       # see codes above
    y_lo: jax.Array         # final bracket
    y_hi: jax.Array
    n_in: jax.Array         # count(y_lo < x <= y_hi) at exit


class _LoopState(NamedTuple):
    yL: jax.Array
    fL: jax.Array
    gL: jax.Array   # right one-sided derivative at yL (< 0)
    yR: jax.Array
    fR: jax.Array
    gR: jax.Array   # left one-sided derivative at yR (> 0)
    cleL: jax.Array  # lower bound on count(x <= yL)  (exact after 1st move)
    cleR: jax.Array  # exact count(x <= yR)
    t_exact: jax.Array
    found_exact: jax.Array
    it: jax.Array
    # golden/brent bookkeeping: previous probe (for parabolic fit)
    tp: jax.Array
    fp: jax.Array


def _propose_cp(s: _LoopState, n, k):
    """Kelley cut intersection: minimizer of max of the two support lines."""
    return (s.fR - s.fL + s.yL * s.gL - s.yR * s.gR) / (s.gL - s.gR)


def _propose_bisection(s: _LoopState, n, k):
    return 0.5 * (s.yL + s.yR)


_INV_GOLDEN = 0.381966011250105  # 2 - golden ratio


def _propose_golden(s: _LoopState, n, k):
    # Shrink from the side whose objective value is larger (descent side).
    left = s.fL > s.fR
    w = jnp.where(left, _INV_GOLDEN, 1.0 - _INV_GOLDEN)
    return s.yL + w * (s.yR - s.yL)


def _propose_brent(s: _LoopState, n, k):
    """Parabola through (yL,fL), (tp,fp), (yR,fR); midpoint safeguard."""
    x1, f1, x2, f2, x3, f3 = s.yL, s.fL, s.tp, s.fp, s.yR, s.fR
    num = (x2 - x1) ** 2 * (f2 - f3) - (x2 - x3) ** 2 * (f2 - f1)
    den = (x2 - x1) * (f2 - f3) - (x2 - x3) * (f2 - f1)
    ok = jnp.abs(den) > 1e-30
    t = x2 - 0.5 * num / jnp.where(ok, den, 1.0)
    mid = 0.5 * (s.yL + s.yR)
    inside = (t > s.yL) & (t < s.yR)
    return jnp.where(ok & inside, t, mid)


_PROPOSALS = {
    "cp": _propose_cp,
    "cp_hybrid": _propose_cp,
    "bisection": _propose_bisection,
    "golden": _propose_golden,
    "brent": _propose_brent,
}


def _bracket_loop(x, k, *, method, maxit, cap, eval_fn=None):
    """Run the shared bracket-shrinking loop; returns final _LoopState."""
    n = x.size
    dtype = x.dtype
    propose = _PROPOSALS[method]
    if eval_fn is None:
        eval_fn = lambda t: eval_fg(x, t, k)

    xmin = jnp.min(x)
    xmax = jnp.max(x)
    xmean = jnp.mean(x, dtype=dtype)
    alpha, beta = os_weights(n, k, dtype)
    nf = jnp.asarray(n, dtype)
    # Analytic init at the extremes (paper: single fused reduction).  The
    # slopes use the conservative tie count 1, which keeps the support lines
    # *lower* bounds (valid cuts) even with duplicated extremes.
    fL0 = beta * (xmean - xmin)
    fR0 = alpha * (xmax - xmean)
    gL0 = alpha * (1.0 / nf) - beta * (nf - 1.0) / nf
    gR0 = alpha * (nf - 1.0) / nf - beta * (1.0 / nf)

    kk = jnp.asarray(k, jnp.int32)
    s0 = _LoopState(
        yL=xmin, fL=fL0, gL=gL0,
        yR=xmax, fR=fR0, gR=gR0,
        cleL=jnp.asarray(1, jnp.int32),  # count(x<=min) >= 1 (conservative)
        cleR=jnp.asarray(n, jnp.int32),
        t_exact=jnp.asarray(jnp.nan, dtype),
        found_exact=jnp.asarray(False),
        it=jnp.asarray(0, jnp.int32),
        tp=0.5 * (xmin + xmax), fp=jnp.maximum(fL0, fR0),
    )

    def cond(s: _LoopState):
        return (
            (~s.found_exact)
            & (s.cleR - s.cleL > cap)
            & (s.it < maxit)
            & (s.yR > s.yL)
        )

    def body(s: _LoopState):
        t = propose(s, n, k)
        # numerical safeguard: keep strictly inside the open bracket
        bad = ~jnp.isfinite(t) | (t <= s.yL) | (t >= s.yR)
        t = jnp.where(bad, 0.5 * (s.yL + s.yR), t).astype(dtype)
        fg: FG = eval_fn(t)
        exact = (fg.n_lt < kk) & (kk <= fg.n_le)
        move_left = fg.g_hi < 0  # t strictly left of the minimizer set
        # if neither exact nor move_left then g_lo > 0 -> t strictly right.
        new = _LoopState(
            yL=jnp.where(move_left, t, s.yL),
            fL=jnp.where(move_left, fg.f, s.fL),
            gL=jnp.where(move_left, fg.g_hi, s.gL),
            yR=jnp.where(move_left | exact, s.yR, t),
            fR=jnp.where(move_left | exact, s.fR, fg.f),
            gR=jnp.where(move_left | exact, s.gR, fg.g_lo),
            cleL=jnp.where(move_left, fg.n_le, s.cleL),
            cleR=jnp.where(move_left | exact, s.cleR, fg.n_le),
            t_exact=jnp.where(exact, t, s.t_exact),
            found_exact=s.found_exact | exact,
            it=s.it + 1,
            tp=t, fp=fg.f,
        )
        return new

    return jax.lax.while_loop(cond, body, s0), xmin, xmax


def _finalize(x, k, s: _LoopState, cap, xmin, xmax):
    """Exact recovery from the final bracket.  Two fused passes.

    Pass 1 (the paper's ``copy_if`` + count): compact elements of the open
    pivot interval into a fixed ``cap`` buffer, count ``c_L = count(x<=y_L)``
    and find the next distinct value above ``y_L``.
    Pass 2 (tie fallback verification): ``count(x <= vnext)``.
    """
    n = x.size
    kk = jnp.asarray(k, jnp.int32)
    x = x.reshape(-1)

    mask_in = (x > s.yL) & (x <= s.yR)
    cL = jnp.sum(x <= s.yL, dtype=jnp.int32)
    n_in = jnp.sum(mask_in, dtype=jnp.int32)
    # fixed-capacity compaction; slot `cap` is the overflow trash slot
    pos = jnp.cumsum(mask_in.astype(jnp.int32)) - 1
    idx = jnp.where(mask_in, jnp.minimum(pos, cap), cap)
    big = jnp.asarray(jnp.inf, x.dtype)
    z = jnp.full((cap + 1,), big, x.dtype).at[idx].set(jnp.where(mask_in, x, big))
    zs = jax.lax.sort(z[:cap])
    ans_sort = zs[jnp.clip(kk - cL - 1, 0, cap - 1)]

    vnext = jnp.min(jnp.where(x > s.yL, x, big))
    n_le_v = jnp.sum(x <= vnext, dtype=jnp.int32)
    fallback_ok = (cL < kk) & (kk <= n_le_v)

    value = jnp.where(
        s.found_exact,
        s.t_exact,
        jnp.where(n_in <= cap, ans_sort, jnp.where(fallback_ok, vnext, s.yR)),
    )
    status = jnp.where(
        s.found_exact,
        EXACT_HIT,
        jnp.where(
            n_in <= cap,
            HYBRID_SORT,
            jnp.where(fallback_ok, TIE_FALLBACK, NOT_CONVERGED),
        ),
    )
    # Extreme-tie shortcuts (the bracket invariant c(y_L) < k only holds for
    # answers strictly inside the data range): if count(x <= y_L) >= k the
    # answer is at or below y_L, which can only be x_(1)=min (y_L starts at
    # the min and only moves to points certified count(x<=t) < k).  Symmetric
    # test at the max.  Also covers k==1, k==n and all-equal arrays.
    n_lt_max = jnp.sum(x < xmax, dtype=jnp.int32)
    at_min = cL >= kk
    at_max = n_lt_max < kk
    value = jnp.where(at_min, xmin, jnp.where(at_max, xmax, value))
    status = jnp.where(at_min | at_max, EXACT_HIT, status)
    return SelectResult(
        value=value, iters=s.it, status=status.astype(jnp.int32),
        y_lo=s.yL, y_hi=s.yR, n_in=n_in,
    )


def _default_cap(n: int) -> int:
    # generous: >= 2 * sqrt-ish growth, bounded; paper observed |z| ~ 1-5% n.
    return int(min(max(4096, n // 64), 1 << 19))


@functools.partial(
    jax.jit, static_argnames=("method", "maxit", "cap", "transform")
)
def order_statistic(
    x: jax.Array,
    k,
    *,
    method: str = "cp",
    maxit: int = 64,
    cap: Optional[int] = None,
    transform: Optional[str] = None,
) -> SelectResult:
    """k-th smallest element of ``x`` (k is 1-indexed, may be traced).

    ``method`` in {"cp", "cp_hybrid", "bisection", "golden", "brent", "sort"}.
    ``cp`` and ``cp_hybrid`` are aliases (the hybrid finalize is always on —
    it is what makes the result exact).  ``transform='log1p'`` applies the
    paper's monotone guard for extreme-valued data (Sec. V-D).
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; one of {METHODS}")
    x = x.reshape(-1)
    n = x.size
    if cap is None:
        cap = _default_cap(n)
    cap = min(cap, n)
    k = jnp.clip(jnp.asarray(k, jnp.int32), 1, n)

    if method == "sort":
        xs = jax.lax.sort(x)
        value = xs[k - 1]
        zero = jnp.asarray(0, jnp.int32)
        return SelectResult(
            value=value, iters=zero, status=jnp.asarray(EXACT_HIT, jnp.int32),
            y_lo=xs[0], y_hi=xs[-1], n_in=jnp.asarray(n, jnp.int32),
        )

    if transform == "log1p":
        xt, inv = transforms.log1p_transform(x)
        s, tmin, tmax = _bracket_loop(xt, k, method=method, maxit=maxit, cap=cap)
        # Map the bracket back *data-consistently*: F is monotone
        # non-decreasing in fp on the data, so
        #   y_orig = max{x_i : F(x_i) <= y_t}
        # preserves counts exactly: count(x <= y_orig) == count(F(x) <= y_t).
        # Both loop invariants (c(y_L) < k <= c(y_R)) therefore transfer to
        # the original domain, and the finalize stays exact.  On an exact hit
        # the t-space image may merge several distinct originals (F is not
        # injective in fp): collapse the bracket to the image's preimage set
        # and let the original-space finalize resolve it.
        neg = jnp.asarray(-jnp.inf, x.dtype)
        yL_t = jnp.where(s.found_exact, s.t_exact, s.yL)
        yR_t = jnp.where(s.found_exact, s.t_exact, s.yR)
        yL = jnp.where(
            s.found_exact,
            jnp.max(jnp.where(xt < yL_t, x, neg)),   # strict: preimage start
            jnp.max(jnp.where(xt <= yL_t, x, neg)),
        )
        yR = jnp.max(jnp.where(xt <= yR_t, x, neg))
        s = s._replace(
            yL=yL, yR=yR,
            t_exact=inv(s.t_exact),
            # exactness certificates do not survive the fp roundtrip:
            found_exact=jnp.asarray(False),
        )
        return _finalize(x, k, s, cap, jnp.min(x), jnp.max(x))
    elif transform is not None:
        raise ValueError(f"unknown transform {transform!r}")

    s, xmin, xmax = _bracket_loop(x, k, method=method, maxit=maxit, cap=cap)
    return _finalize(x, k, s, cap, xmin, xmax)


def median(x: jax.Array, **kw) -> SelectResult:
    """Med(x) = x_([(n+1)/2]) (paper Sec. I convention)."""
    n = x.size
    return order_statistic(x, (n + 1) // 2, **kw)


def quantile(x: jax.Array, q, **kw) -> SelectResult:
    """Lower empirical q-quantile: x_(ceil(q*n)) clipped to [1, n]."""
    n = x.size
    k = jnp.clip(jnp.ceil(jnp.asarray(q) * n).astype(jnp.int32), 1, n)
    return order_statistic(x, k, **kw)


def topk_threshold(x: jax.Array, m, **kw) -> SelectResult:
    """Value of the m-th largest element (for kNN / trimming)."""
    n = x.size
    return order_statistic(x, n - jnp.asarray(m, jnp.int32) + 1, **kw)


def multi_order_statistic(x: jax.Array, ks, **kw) -> SelectResult:
    """Several order statistics of the SAME array at once (vmapped CP).

    All brackets iterate together: each iteration evaluates every live
    pivot against ``x`` in one batched pass (a single fused kernel launch on
    TPU) instead of len(ks) independent selections — the cheap way to get
    (p25, p50, p75, p99, ...) telemetry sets.
    """
    ks = jnp.asarray(ks, jnp.int32)
    return jax.vmap(lambda k: order_statistic(x, k, **kw))(ks)


def quantiles(x: jax.Array, qs, **kw) -> SelectResult:
    """Lower empirical quantiles at each q in ``qs`` (one vmapped solve)."""
    n = x.size
    ks = jnp.clip(jnp.ceil(jnp.asarray(qs) * n).astype(jnp.int32), 1, n)
    return multi_order_statistic(x, ks, **kw)
