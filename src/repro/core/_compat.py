"""Version-compat shims for jax APIs that moved between releases.

The codebase targets current jax (``jax.shard_map``, ``jax.sharding.AxisType``,
``check_vma``); CI and some containers carry older releases where shard_map
still lives in ``jax.experimental`` (flag ``check_rep``) and meshes have no
axis types.  Keep every cross-version touchpoint here so the rest of the code
reads as if written for one jax.
"""
from __future__ import annotations

import functools

import jax


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the concept exists."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def shard_map(f=None, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across jax versions.

    ``check=False`` disables the static replication/varying-axis analysis
    (``check_vma`` on current jax, ``check_rep`` before it) — the distributed
    selection results are semantically replicated (built from psum/all_gather
    outputs) but the analysis cannot prove it.
    """
    if f is None:
        return functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check=check)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


def scan_in_shard_map(body, init, n: int):
    """``lax.scan(body, init, jnp.arange(n))`` usable INSIDE a shard_map
    that gets differentiated.

    The pre-0.5 shard_map cannot transpose a ``lax.scan`` living in its
    body (scalar residuals leak into the transposed out-specs); since the
    trip count is static at every call site, fall back to a Python unroll
    there.  Current jax keeps the real scan (O(1) jaxpr size).
    """
    import jax.numpy as jnp

    if hasattr(jax, "shard_map"):
        carry, _ = jax.lax.scan(body, init, jnp.arange(n))
        return carry
    carry = init
    for i in range(n):
        carry, _ = body(carry, jnp.asarray(i))
    return carry
