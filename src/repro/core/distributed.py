"""Distributed selection over sharded arrays (the paper's Sec. V-D multi-GPU
story, mapped to TPU meshes).

Two primitives, both ``shard_map``-native:

* :func:`local_order_statistic` — the k-th (or weighted, via ``weights=``)
  order statistic of a 1-D array sharded over one or more mesh axes.  ONE
  round loop serves both measures: each binned round is one local histogram
  pass + a psum of the ``(nbins + 2,)`` slot-MEASURE vector (int counts on
  the counting leg, fp masses on the weighted leg — the same vector is both
  on the counting leg, so the wire carries it once), each cutting-plane
  round psums the additive FG partials — the paper's "partial sums from
  several GPUs are added together", except the combine is an ICI all-reduce
  instead of a CPU hop.  ``method='binned_polish'`` additionally psums the
  per-slot SUM vector and drives the next round's edge placement with the
  globally-reconstructed straddling-bin centroid (``selection.polish_edges``)
  — one round saved at large n for ``nbins + 2`` extra wire scalars per
  round.  The hybrid finalize compacts *per shard* (fixed local capacity),
  ``all_gather``s the tiny buffers and sorts — the paper's small-array
  ``z`` step (carrying the aligned weight buffers on the weighted leg).

* :func:`median_across_axis` — vectorized coordinate-wise order statistics
  *across* a mesh axis (n = axis size per coordinate, millions of
  coordinates).  This is the robust-gradient-aggregation workhorse: per-
  replica gradient shards never leave their device; the solver only psums
  per-coordinate count/sum vectors.  For small replica counts an
  ``all_gather`` + local sort is cheaper in ICI bytes (crossover benchmarked
  in ``benchmarks/``); both methods are provided.

Both primitives ride the batched-first selection engine: the psum combine is
just another :class:`~repro.core.objective.Evaluator`.  The 1-D primitive
wraps a ``ShardedEvaluator`` (local fused pass + psum of the additive
partials); the across-axis primitive builds an :func:`axis_evaluator` whose
batch dimension is the coordinate set and hands it to
``selection.bracket_loop_batched`` — the same loop that runs rows-mode and
shared-x selection on a single device.

Every function here must be called INSIDE ``shard_map`` (they take the mesh
axis name(s)).  ``sharded_order_statistic`` is the user-facing wrapper.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import _compat, selection
from repro.core.objective import (
    FG,
    FnEvaluator,
    ShardedEvaluator,
    os_weights,
)

AxisNames = Sequence[str] | str

# round schedules of the 1-D distributed primitive ('auto' resolves
# statically by the global element count, mirroring the local engine)
DIST_METHODS = ("binned", "binned_polish", "cp", "auto")


def _axes_tuple(axes) -> tuple:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def _psum(v, axes):
    return jax.lax.psum(v, axes)


def _pmax(v, axes):
    return jax.lax.pmax(v, axes)


def _pmin(v, axes):
    return jax.lax.pmin(v, axes)


def _pcast_varying(v, axes_t):
    # jax >= 0.7 wants device-varying values marked explicitly for the
    # static varying-axis analysis; older versions have no pcast (and no
    # analysis), where the cast is a no-op.
    pcast = getattr(jax.lax, "pcast", None)
    return v if pcast is None else pcast(v, axes_t, to="varying")


def eval_fg_sharded(x_local, y, k, n_global, axes, *, backend=None) -> FG:
    """Fused local pass + psum combine — one ShardedEvaluator call.

    ``n_global`` overrides the psum-derived element count (callers that pad
    shards to equal size pass the true count so the weights stay honest);
    ``None`` derives it from the shards.
    """
    ev = ShardedEvaluator(x_local, k, axes, backend=backend)
    if n_global is not None:
        ev.n = jnp.asarray(n_global, jnp.int32)
        ev.k = jnp.clip(jnp.asarray(k, jnp.int32), 1, ev.n)
    return ev(y)


class _DistState(NamedTuple):
    yL: jax.Array
    fL: jax.Array
    gL: jax.Array
    yR: jax.Array
    fR: jax.Array
    gR: jax.Array
    loc_cleL: jax.Array   # per-shard count(x_local <= yL)  (not replicated)
    loc_cleR: jax.Array
    max_in: jax.Array     # replicated: pmax over shards of local in-bracket
    t_exact: jax.Array
    found_exact: jax.Array
    it: jax.Array
    tp: jax.Array         # carried in-bin CP cut (drives the polish edges)


def local_order_statistic(
    x_local: jax.Array,
    k,
    axes: AxisNames,
    *,
    maxit: int = 64,
    cap_local: int = 4096,
    backend: Optional[str] = None,
    method: str = "binned",
    nbins: int = selection.DEF_NBINS,
    weights: Optional[jax.Array] = None,
    binned_impl: Optional[str] = None,
    prior=None,
) -> selection.SelectResult:
    """k-th smallest of the *global* (sharded) array; call inside shard_map.

    The result is replicated (identical on every shard).  Exact under the
    same guarantees as ``selection.order_statistic``; the count-based
    stopping rule bounds the *per-shard* in-bracket count so the local
    fixed-capacity compaction never overflows regardless of shard imbalance.

    With ``weights`` (sharded exactly like the data), ``k`` is the target
    cumulative MASS and the result is the weighted order statistic — the
    measure swap happens inside the :class:`ShardedEvaluator`; the round
    loop and finalize below are shared by both legs.

    ``method='binned'`` (default) narrows by histogram sweeps: each round is
    one local binned pass + a psum of the ``(nbins + 2,)`` slot-measure
    vector — the bracket shrinks by a factor of ``nbins`` per collective
    round, so the whole solve is ~3 rounds where the cutting-plane loop
    (``'cp'``) takes ~15-40 psums of the additive partials.  The slot
    COUNTS always stay per-shard (they feed the local cap bookkeeping); on
    the counting leg the psum'd counts double as the measure vector, so the
    wire cost is unchanged from the pre-unification engine on both legs.

    ``method='binned_polish'`` drives the rounds with the in-bin CP cut:
    each round ALSO psums the ``(nbins + 2,)`` per-slot sum vector (the
    only extra wire cost), reconstructs the straddling bin's mass centroid
    ``Σ_bin (w·)x / Σ_bin mass`` globally, and hands the cut to
    ``selection.polish_edges`` for the NEXT round's realized edges — the
    answer's neighborhood is then resolved at ~``2^-(nbins/4)`` of the
    bracket instead of ``1/nbins``, trading ``nbins + 2`` wire scalars per
    round for a round saved (2 -> 1 psum rounds at n = 1M, both measures —
    see BENCH_selection.json ``distributed``).  Same fp contract as the
    local engine: the cut steers edge PLACEMENT only, narrowing and
    certificates run on the psum'd measured prefixes through the one
    ``selection.binned_descent_step``, so a garbage centroid costs at most
    a round, never exactness.

    ``method='auto'`` mirrors the local engine's resolution (static by the
    global element count): 'binned' for ``n >= selection.BINNED_MIN_N``,
    'cp' below — and stays on plain 'binned' until the polish schedule is
    TPU-validated.  ``binned_impl`` routes the LOCAL histogram pass's jnp
    slotting exactly as in ``selection.select_rows``.

    ``prior`` (warm start, replicated scalar fields — a previous
    replicated result or ``selection.Prior``): round 1's psum'd slot
    vector is laid out by ``selection.prior_edges`` — the carried bracket
    verbatim plus the collapse pair around the prior answer — so an
    unchanged answer re-certifies in ONE psum round; the cp schedule
    spends its first psum at the prior pivot instead of the analytic cut.
    Same contract as the polish centroid: a stale/garbage/NaN prior costs
    psum rounds, never exactness.
    """
    x_local = x_local.reshape(-1)
    pr = selection.as_prior(prior)
    n_local = x_local.size
    axes_t = _axes_tuple(axes)
    if method == "auto":
        # psum of a python int constant-folds to the static global count
        n_glob = jax.lax.psum(n_local, axes_t)
        method = "binned" if n_glob >= selection.BINNED_MIN_N else "cp"
    weighted = weights is not None
    if weighted:
        weights = jnp.asarray(weights).reshape(-1)
    # the evaluator owns the data layout AND the measure: local fused pass
    # (Pallas on TPU) + psum of the additive partials is the whole
    # multi-device story
    ev = ShardedEvaluator(x_local, k, axes, backend=backend, weights=weights,
                          binned_impl=binned_impl)
    kk = ev.k
    dtype = x_local.dtype
    wl = weights.astype(kk.dtype) if weighted else None

    xmin, xmax, xmean = ev.init_stats()

    # analytic cut seeds, mirroring selection._seed_state's two measure legs
    if weighted:
        Wsafe = jnp.maximum(ev.W, jnp.asarray(1e-30, ev.W.dtype))
        alpha = ((ev.W - kk) / Wsafe).astype(dtype)
        beta = (kk / Wsafe).astype(dtype)
        gL0, gR0 = -beta, alpha
    else:
        nf = ev.n.astype(dtype)
        alpha, beta = os_weights(nf, kk, dtype)
        gL0 = alpha * (1.0 / nf) - beta * (nf - 1.0) / nf
        gR0 = alpha * (nf - 1.0) / nf - beta * (1.0 / nf)

    fL0 = beta * (xmean - xmin)
    fR0 = alpha * (xmax - xmean)
    # analytic Kelley intersection seeds the polish's first in-bin cut
    # (mirrors selection.binned_loop_batched's polish seeding)
    t0 = (fR0 - fL0 + xmin * gL0 - xmax * gR0) / (gL0 - gR0)
    bad0 = ~jnp.isfinite(t0) | (t0 <= xmin) | (t0 >= xmax)
    t0 = jnp.where(bad0, 0.5 * (xmin + xmax), t0).astype(dtype)
    s0 = _DistState(
        yL=xmin,
        fL=fL0,
        gL=gL0,
        yR=xmax,
        fR=fR0,
        gR=gR0,
        loc_cleL=_pcast_varying(jnp.asarray(0, jnp.int32), axes_t),
        loc_cleR=_pcast_varying(jnp.asarray(n_local, jnp.int32), axes_t),
        max_in=jnp.asarray(n_local, jnp.int32),
        t_exact=jnp.asarray(jnp.nan, dtype),
        found_exact=jnp.asarray(False),
        it=jnp.asarray(0, jnp.int32),
        tp=t0,
    )

    def cond(carry):
        s, stalled = carry
        return ((~s.found_exact) & ~stalled & (s.max_in > cap_local)
                & (s.it < maxit) & (s.yR > s.yL))

    def cp_body(carry):
        s, stalled = carry
        t = (s.fR - s.fL + s.yL * s.gL - s.yR * s.gR) / (s.gL - s.gR)
        bad = ~jnp.isfinite(t) | (t <= s.yL) | (t >= s.yR)
        t = jnp.where(bad, 0.5 * (s.yL + s.yR), t).astype(s.yL.dtype)
        if pr is not None:
            # warm start: the prior answer takes the FIRST psum round only
            # (finite + strictly inside the bracket); the psum'd partials
            # decide every move, so a wrong prior costs rounds, not
            # exactness — an exact one certifies in one round
            pv = jnp.asarray(pr.value, s.yL.dtype).reshape(())
            use = ((s.it == 0) & jnp.isfinite(pv)
                   & (pv > s.yL) & (pv < s.yR))
            t = jnp.where(use, pv, t)
        # local partials kept un-psum'd too: the stopping rule bounds the
        # PER-SHARD in-bracket count so the local compaction never overflows
        loc = ev.local_partials(t)
        le_loc = loc[-1]   # n_le is the trailing partial on both legs
        fg = ev.combine(loc)
        # the measure decisions ARE the engine's (see bracket_loop_batched)
        exact = (fg.m_lt < kk) & (kk <= fg.m_le)
        move_left = fg.m_le < kk
        loc_cleL = jnp.where(move_left, le_loc, s.loc_cleL)
        loc_cleR = jnp.where(move_left | exact, s.loc_cleR, le_loc)
        max_in = _pmax(loc_cleR - loc_cleL, axes)
        return _DistState(
            yL=jnp.where(move_left, t, s.yL),
            fL=jnp.where(move_left, fg.f, s.fL),
            gL=jnp.where(move_left, fg.g_hi, s.gL),
            yR=jnp.where(move_left | exact, s.yR, t),
            fR=jnp.where(move_left | exact, s.fR, fg.f),
            gR=jnp.where(move_left | exact, s.gR, fg.g_lo),
            loc_cleL=loc_cleL, loc_cleR=loc_cleR, max_in=max_in,
            t_exact=jnp.where(exact, t, s.t_exact),
            found_exact=s.found_exact | exact,
            it=s.it + 1,
            tp=s.tp,
        ), stalled

    polish = method == "binned_polish"
    pb = None  # dt-converted prior for the binned rounds (set below)

    def binned_body(carry):
        from repro.kernels.ref import bin_edges  # deferred: core <-> kernels

        s, stalled = carry
        # realized edges computed ONCE, shared by the local data pass and
        # the narrowing decision (the exactness contract); the cross-device
        # combine is a psum of the slot-measure vector (additive, exactly
        # like the FG partials) — the slot counts stay local for the
        # per-shard cap bookkeeping.  Polish rounds place the edges around
        # the carried cut instead of uniformly.
        if polish:
            edges = selection.polish_edges(s.yL, s.yR, s.tp, nbins)
        else:
            edges = bin_edges(s.yL, s.yR, nbins)
        if pb is not None:
            # warm start: round 1's slot vector is laid out by the prior
            # (carried bracket verbatim + the collapse pair); later rounds
            # revert to the uniform/polish layout
            edges = jnp.where(s.it == 0,
                              selection.prior_edges(s.yL, s.yR, pb, nbins),
                              edges)
        cnt_loc, mass_loc, msum_loc = ev.local_histogram(edges,
                                                         need_msum=polish)
        mass = _psum(mass_loc, axes)
        cum = jnp.cumsum(mass[:-1])
        # the narrowing decision + exactness certificates are the one shared
        # implementation in selection.binned_descent_step
        yLn, yRn, _, _, jm1, jstar, hit_lo, exact, stall = \
            selection.binned_descent_step(cum, edges, s.yL, s.yR, kk)
        # late hit_lo can only be an inexact-mass ulp-flip: fail safe (dead
        # code on the counting leg — see selection.binned_loop_batched)
        late_hit_lo = hit_lo & (s.it > 0)
        exact = exact & ~late_hit_lo
        stall = stall | late_hit_lo
        # local prefix counts at the chosen edges: the per-shard analogue of
        # the CP loop's le_loc bookkeeping (bounds the local compaction)
        cum_loc = jnp.cumsum(cnt_loc[:-1])
        locL, locR = cum_loc[jm1], cum_loc[jstar]
        upd = ~exact & ~stall
        loc_cleL = jnp.where(upd, locL, s.loc_cleL)
        loc_cleR = jnp.where(upd, locR, s.loc_cleR)
        if polish:
            # one extra (nbins + 2,) psum reconstructs the straddling bin's
            # GLOBAL mass centroid — the in-bin support-line intersection
            # (see selection.binned_loop_batched); guard degenerate bins
            msum = _psum(msum_loc, axes)
            mbin = mass[jstar].astype(msum.dtype)
            sbin = msum[jstar]
            tcut = sbin / jnp.where(mbin > 0, mbin, 1)
            good = (mbin > 0) & jnp.isfinite(tcut)
            tcut = jnp.where(good, jnp.clip(tcut, yLn, yRn),
                             0.5 * (yLn + yRn)).astype(s.yL.dtype)
            tp_n = jnp.where(upd, tcut, s.tp)
        else:
            tp_n = s.tp
        return _DistState(
            yL=jnp.where(upd, yLn, s.yL), fL=s.fL, gL=s.gL,
            yR=jnp.where(upd, yRn, s.yR), fR=s.fR, gR=s.gR,
            loc_cleL=loc_cleL, loc_cleR=loc_cleR,
            max_in=_pmax(loc_cleR - loc_cleL, axes),
            t_exact=jnp.where(exact, jnp.where(hit_lo, s.yL, yRn),
                              s.t_exact),
            found_exact=s.found_exact | exact,
            it=s.it + 1,
            tp=tp_n,
        ), stalled | stall

    if method in ("binned", "binned_polish"):
        # brackets narrow to realized f32 edge values — keep the bracket
        # state at (at least) the kernels' f32 accumulation precision
        dt = jnp.promote_types(dtype, jnp.float32)
        s0 = s0._replace(yL=s0.yL.astype(dt), yR=s0.yR.astype(dt),
                         t_exact=s0.t_exact.astype(dt),
                         tp=s0.tp.astype(dt))
        if pr is not None:
            pb = selection.Prior(
                *(jnp.asarray(f, dt).reshape(()) for f in pr))
            # the prior's carried cut beats the analytic polish seed
            okc = (jnp.isfinite(pb.cut) & (pb.cut > s0.yL)
                   & (pb.cut < s0.yR))
            s0 = s0._replace(tp=jnp.where(okc, pb.cut, s0.tp))
        body = binned_body
    elif method == "cp":
        body = cp_body
    else:
        raise ValueError(f"unknown method {method!r}; one of "
                         f"{DIST_METHODS}")

    s, _ = jax.lax.while_loop(cond, body, (s0, jnp.asarray(False)))

    # ---- distributed hybrid finalize (compact per shard, gather, sort) ----
    # per-shard compaction by selection.rank_compact (the one rank-gather
    # implementation), then the tiny buffers ride an all_gather
    big = jnp.asarray(jnp.inf, dtype)
    mask_in = (x_local > s.yL) & (x_local <= s.yR)
    cols = [(x_local, big)]
    if weighted:
        cols.append((wl, jnp.zeros((), wl.dtype)))
    bufs, loc_in = selection.rank_compact(mask_in, cap_local, cols)
    n_in = _psum(loc_in, axes)
    z_all = bufs[0]
    for ax in axes_t:
        z_all = jax.lax.all_gather(z_all, ax).reshape(-1)
    ok_gather = _pmax(loc_in, axes) <= cap_local
    vnext = _pmin(jnp.min(jnp.where(x_local > s.yL, x_local, big)), axes)

    if weighted:
        # gather the aligned weight buffers and resolve by sorted prefix
        # masses — the weighted generalization of indexing at k - cL
        zw_all = bufs[1]
        for ax in axes_t:
            zw_all = jax.lax.all_gather(zw_all, ax).reshape(-1)
        order = jnp.argsort(z_all)
        zs = z_all[order]
        cLm = _psum(jnp.sum(jnp.where(x_local <= s.yL, wl, 0),
                            dtype=wl.dtype), axes)
        cumw = cLm + jnp.cumsum(zw_all[order])
        reach = cumw >= kk
        ans_sort = zs[jnp.argmax(reach).astype(jnp.int32)]
        # the buffer certifies only when its total mass actually reaches wk
        ok_sort = ok_gather & reach[-1]
        m_le_v = _psum(jnp.sum(jnp.where(x_local <= vnext, wl, 0),
                               dtype=wl.dtype), axes)
        m_lt_max = _psum(jnp.sum(jnp.where(x_local < xmax, wl, 0),
                                 dtype=wl.dtype), axes)
        # extreme shortcuts gated on the seed bracket (see the engine
        # finalize: re-measured masses can rounding-flip near wk; only a
        # bracket still AT the extreme may certify through them)
        at_min = (cLm >= kk) & (s.yL == xmin)
        at_max = (m_lt_max < kk) & (s.yR == xmax)
        t_hit = s.t_exact.astype(dtype)
        y_hi = s.yR.astype(dtype)
    else:
        zs = jax.lax.sort(z_all)
        cLm = _psum(jnp.sum(x_local <= s.yL, dtype=jnp.int32), axes)
        ans_sort = zs[jnp.clip(kk - cLm - 1, 0, z_all.size - 1)]
        ok_sort = ok_gather
        m_le_v = _psum(jnp.sum(x_local <= vnext, dtype=jnp.int32), axes)
        m_lt_max = _psum(jnp.sum(x_local < xmax, dtype=jnp.int32), axes)
        at_min = cLm >= kk
        at_max = m_lt_max < kk
        t_hit = s.t_exact
        y_hi = s.yR

    fallback_ok = (cLm < kk) & (kk <= m_le_v)
    value = jnp.where(
        s.found_exact, t_hit,
        jnp.where(ok_sort, ans_sort, jnp.where(fallback_ok, vnext, y_hi)),
    )
    status = jnp.where(
        s.found_exact, selection.EXACT_HIT,
        jnp.where(ok_sort, selection.HYBRID_SORT,
                  jnp.where(fallback_ok, selection.TIE_FALLBACK,
                            selection.NOT_CONVERGED)),
    )
    value = jnp.where(at_min, xmin, jnp.where(at_max, xmax, value))
    status = jnp.where(at_min | at_max, selection.EXACT_HIT, status)
    return selection.SelectResult(
        value=value, iters=s.it, status=status.astype(jnp.int32),
        y_lo=s.yL, y_hi=s.yR, n_in=n_in,
    )


def local_weighted_order_statistic(
    x_local: jax.Array,
    w_local: jax.Array,
    wk,
    axes: AxisNames,
    *,
    maxit: int = 64,
    cap_local: int = 4096,
    backend: Optional[str] = None,
    method: str = "binned",
    nbins: int = selection.DEF_NBINS,
    binned_impl: Optional[str] = None,
    prior=None,
) -> selection.SelectResult:
    """Weighted order statistic of the *global* sharded array: the smallest
    element whose global cumulative weight reaches ``wk``.  Call inside
    shard_map; weights are sharded exactly like the data.

    Thin wrapper over :func:`local_order_statistic` — the measure swap is
    the evaluator's ``weights`` leg, not a second round loop: each binned
    round psums the ``(nbins + 2,)`` slot MASS vector (the slot counts stay
    per-shard for the cap bookkeeping), and the finalize all_gathers
    per-shard (value, weight) pair buffers and resolves by sorted prefix
    weights — the weighted analogue of the paper's small-array ``z`` step.
    ``method`` in {'binned', 'binned_polish', 'cp', 'auto'} as in
    :func:`local_order_statistic` (the cp rounds psum the six weighted
    partials; the polish psums the per-slot ``Σ w·x`` vector too and
    saves a round at large n; 'auto' may resolve to 'cp' below
    ``BINNED_MIN_N``).
    """
    if method not in DIST_METHODS:
        raise ValueError(f"unknown method {method!r}; one of "
                         f"{DIST_METHODS}")
    return local_order_statistic(
        x_local, wk, axes, maxit=maxit, cap_local=cap_local,
        backend=backend, method=method, nbins=nbins, weights=w_local,
        binned_impl=binned_impl, prior=prior)


def sharded_order_statistic(
    x: jax.Array,
    k,
    mesh: jax.sharding.Mesh,
    in_spec: P,
    **kwargs,
) -> selection.SelectResult:
    """User-facing wrapper: shard_map the distributed selection.

    ``in_spec`` is the PartitionSpec of ``x`` (1-D).  The result is fully
    replicated.
    """
    axes = tuple(
        a for ax in in_spec for a in
        ((ax,) if isinstance(ax, str) else tuple(ax or ()))
    )

    @functools.partial(
        _compat.shard_map, mesh=mesh, in_specs=(in_spec,),
        out_specs=jax.tree.map(lambda _: P(), selection.SelectResult(
            *(0,) * 6)),
        # outputs are semantically replicated (built from psum/all_gather
        # results), but the static varying-axis analysis cannot prove it
        check=False,
    )
    def run(x_local):
        return local_order_statistic(x_local, k, axes, **kwargs)

    return run(x)


def sharded_median(x, mesh, in_spec, **kw):
    n = x.size
    return sharded_order_statistic(x, (n + 1) // 2, mesh, in_spec, **kw)


def sharded_weighted_order_statistic(
    x: jax.Array,
    w: jax.Array,
    wk,
    mesh: jax.sharding.Mesh,
    in_spec: P,
    **kwargs,
) -> selection.SelectResult:
    """User-facing wrapper: shard_map the weighted distributed selection.

    ``x`` and ``w`` share ``in_spec`` (weights live with their data).  The
    result is fully replicated.
    """
    axes = tuple(
        a for ax in in_spec for a in
        ((ax,) if isinstance(ax, str) else tuple(ax or ()))
    )

    @functools.partial(
        _compat.shard_map, mesh=mesh, in_specs=(in_spec, in_spec),
        out_specs=jax.tree.map(lambda _: P(), selection.SelectResult(
            *(0,) * 6)),
        # outputs are semantically replicated (built from psum/all_gather
        # results), but the static varying-axis analysis cannot prove it
        check=False,
    )
    def run(x_local, w_local):
        return local_weighted_order_statistic(x_local, w_local, wk, axes,
                                              **kwargs)

    return run(x, w)


def sharded_weighted_median(x, w, mesh, in_spec, **kw):
    """Lower weighted median of the sharded array (global mass / 2)."""
    # same dtype rule as selection._total_mass: the target mass must live
    # at the evaluator's accumulation dtype or the two can desynchronize
    W = selection._total_mass(x, jnp.asarray(w))
    return sharded_weighted_order_statistic(x, w, 0.5 * W, mesh, in_spec,
                                            **kw)


def sharded_quantile(x, q, mesh, in_spec, **kw):
    # ranks resolve host-side at f64 (the traced f32 product mis-lands
    # high quantiles at n ~ 2^25 — see selection.ranks_from_quantiles)
    return sharded_order_statistic(
        x, selection.ranks_from_quantiles(q, x.size), mesh, in_spec, **kw)


def multi_order_statistic_across_shards(
    x_local: jax.Array,
    ks,
    axes: AxisNames,
    *,
    maxit: int = 64,
    cap_local: int = 4096,
    backend: Optional[str] = None,
    method: str = "binned",
    nbins: int = selection.DEF_NBINS,
    weights: Optional[jax.Array] = None,
    binned_impl: Optional[str] = None,
    prior=None,
) -> selection.SelectResult:
    """K order statistics of the *global* sharded array in ONE round loop;
    call inside shard_map.  Returns a replicated ``(K,)`` SelectResult.

    The K brackets narrow simultaneously: each binned round is one LOCAL
    shared-x multi-bracket histogram pass (``fused_histogram_multi`` — the
    x tile is read once for all K edge ladders) plus ONE psum of the
    ``(K, nbins + 2)`` slot matrix, so a sharded decile vector costs the
    same collective rounds as a sharded median — not ~K× them.  With
    ``weights`` the targets are cumulative masses and the mass matrix rides
    the wire next to the count matrix (two ``(K, nbins+2)`` psums — the
    counts feed the cap rule); ``method='binned_polish'`` psums the
    per-slot sum matrix too and steers each k's next edge ladder from its
    own straddling-bin centroid.  ``method='cp'`` psums the stacked
    ``(K,)`` additive partials per round; ``'auto'`` resolves by the global
    element count exactly like :func:`local_order_statistic`.

    The loop IS the local engine's (``selection.binned_loop_batched`` /
    ``bracket_loop_batched``) over an :class:`FnEvaluator` whose closures
    psum the local multi-bracket passes — the stopping rule compares the
    GLOBAL in-bracket counts against ``cap_local``, which conservatively
    bounds every shard's compaction buffer.  The finalize compacts per
    shard per k (``selection.rank_compact``), all_gathers the tiny
    ``(cap_local,)`` buffers and resolves through the engine's one answer
    cascade (``selection._assemble_answers``).
    """
    from repro.kernels import ops as kops  # deferred: core <-> kernels

    x_local = x_local.reshape(-1)
    axes_t = _axes_tuple(axes)
    n_glob = jax.lax.psum(x_local.size, axes_t)  # constant-folds (static)
    if method == "auto":
        method = ("binned" if n_glob >= selection.BINNED_MIN_N else "cp")
    weighted = weights is not None
    dtype = x_local.dtype
    bigloc = jnp.asarray(jnp.inf, dtype)

    if weighted:
        wl = jnp.asarray(weights).reshape(-1)
        from repro.kernels.ref import _waccum_dtype
        mdt = _waccum_dtype(x_local, wl)
        W = _psum(jnp.sum(wl, dtype=mdt), axes_t)
        kk = jnp.minimum(jnp.asarray(ks, mdt).reshape(-1), W)
        wl = wl.astype(mdt)

        def partials(y):
            wsp, wsn, wlt, wle, lt, le = kops.fused_weighted_partials_multi(
                x_local, wl, y, backend=backend)
            f = _psum(jnp.stack([wsp, wsn, wlt, wle]), axes_t)
            c = _psum(jnp.stack([lt, le]), axes_t)
            return f[0], f[1], f[2], f[3], c[0], c[1]

        def histogram(edges, need_msum=False):
            cnt, wcnt, wsum = kops.fused_weighted_histogram_multi(
                x_local, wl, edges, backend=backend, impl=binned_impl,
                want_sums=need_msum)
            # count matrix rides a pmax: its prefix differences then bound
            # the WORST shard's in-bracket count (sum of per-slot maxima >=
            # max of per-shard sums), so the engine's cap rule sizes the
            # per-shard compaction buffers — mirroring local_order_statistic
            return (_pmax(cnt, axes_t), _psum(wcnt, axes_t),
                    _psum(wsum, axes_t) if need_msum else None)
    else:
        wl = None
        W = None
        kk = jnp.clip(jnp.asarray(ks, jnp.int32).reshape(-1), 1, n_glob)

        def partials(y):
            sp, sn, lt, le = kops.fused_partials_multi(x_local, y,
                                                       backend=backend)
            f = _psum(jnp.stack([sp, sn]), axes_t)
            c = _psum(jnp.stack([lt, le]), axes_t)
            return f[0], f[1], c[0], c[1]

        def histogram(edges, need_msum=False):
            # ONE psum of the (K, nbins + 2) slot matrix per round drives
            # the narrowing; the count matrix additionally rides a pmax —
            # its prefix differences bound the WORST shard's in-bracket
            # count (sum of per-slot maxima >= max of per-shard sums), so
            # the engine's cap rule sizes the per-shard compaction buffers
            # exactly like local_order_statistic's max_in bookkeeping
            cnt, bsum = kops.fused_histogram_multi(
                x_local, edges, backend=backend, impl=binned_impl,
                want_sums=need_msum)
            return (_pmax(cnt, axes_t), _psum(cnt, axes_t),
                    _psum(bsum, axes_t) if need_msum else None)

    nk = kk.shape[0]
    bc = lambda v: jnp.broadcast_to(v, (nk,))

    def init_stats():
        gmin = _pmin(jnp.min(x_local), axes_t)
        gmax = _pmax(jnp.max(x_local), axes_t)
        if weighted:
            wx = _psum(jnp.sum(wl * x_local, dtype=mdt), axes_t)
            mean = (wx / jnp.maximum(W, 1e-30)).astype(dtype)
        else:
            mean = (_psum(jnp.sum(x_local, dtype=dtype), axes_t)
                    / jnp.asarray(n_glob, dtype))
        return bc(gmin), bc(gmax), bc(mean)

    ev = FnEvaluator(partials, jnp.asarray(n_glob, jnp.int32), kk,
                     init_stats, histogram=histogram,
                     weights_total=W if weighted else None)
    s, xmin, xmax = selection._run_bracket_phase(
        ev, method, maxit, cap_local, nbins,
        prior=selection.as_prior(prior))

    # ---- distributed finalize: compact per shard per k, gather, assemble
    cols = [(x_local, bigloc)]
    if weighted:
        cols.append((wl, jnp.zeros((), wl.dtype)))

    def one(args):
        lo, hi = args
        mask_in = (x_local > lo) & (x_local <= hi)
        bufs, loc_in = selection.rank_compact(mask_in, cap_local, cols)
        gathered = []
        for b in bufs:
            for ax in axes_t:
                b = jax.lax.all_gather(b, ax)
            gathered.append(b.reshape(-1))
        ok = _pmax(loc_in, axes_t) <= cap_local
        n_in = _psum(loc_in, axes_t)
        vnext = _pmin(jnp.min(jnp.where(x_local > lo, x_local, bigloc)),
                      axes_t)
        if weighted:
            cLm = _psum(jnp.sum(jnp.where(x_local <= lo, wl, 0),
                                dtype=mdt), axes_t)
            m_le_v = _psum(jnp.sum(jnp.where(x_local <= vnext, wl, 0),
                                   dtype=mdt), axes_t)
        else:
            cLm = _psum(jnp.sum(x_local <= lo, dtype=jnp.int32), axes_t)
            m_le_v = _psum(jnp.sum(x_local <= vnext, dtype=jnp.int32),
                           axes_t)
        return (*gathered, cLm, n_in, ok, vnext, m_le_v)

    out = jax.lax.map(one, (s.yL, s.yR))
    if weighted:
        z, zw, cLm, n_in, ok, vnext, m_le_v = out
        order = jnp.argsort(z, axis=-1)
        zs = jnp.take_along_axis(z, order, axis=-1)
        zws = jnp.take_along_axis(zw, order, axis=-1)
        m_lt_max = bc(_psum(jnp.sum(
            jnp.where(x_local < jnp.max(xmax), wl, 0), dtype=mdt), axes_t))
    else:
        z, cLm, n_in, ok, vnext, m_le_v = out
        zs = jnp.sort(z, axis=-1)
        zws = None
        m_lt_max = bc(_psum(jnp.sum(x_local < jnp.max(xmax),
                                    dtype=jnp.int32), axes_t))
    gcap = zs.shape[-1]
    # a per-shard buffer overflow must fail the sort path even when the
    # GLOBAL count fits the gathered width (survivors were dropped locally)
    n_in_eff = jnp.where(ok, n_in, gcap + 1)
    res = selection._assemble_answers(kk, s, gcap, zs, zws, cLm, n_in_eff,
                                      vnext, m_le_v, m_lt_max, xmin, xmax)
    return res._replace(n_in=n_in)


def sharded_multi_order_statistic(
    x: jax.Array,
    ks,
    mesh: jax.sharding.Mesh,
    in_spec: P,
    **kwargs,
) -> selection.SelectResult:
    """User-facing wrapper: shard_map the multi-k distributed selection.

    ``in_spec`` is the PartitionSpec of ``x`` (1-D); ``ks`` the (K,) target
    ranks (or masses via ``weights=`` in ``kwargs``, sharded like ``x``).
    The ``(K,)`` result is fully replicated.
    """
    axes = tuple(
        a for ax in in_spec for a in
        ((ax,) if isinstance(ax, str) else tuple(ax or ()))
    )
    weights = kwargs.pop("weights", None)
    in_specs = (in_spec,) if weights is None else (in_spec, in_spec)

    @functools.partial(
        _compat.shard_map, mesh=mesh, in_specs=in_specs,
        out_specs=jax.tree.map(lambda _: P(), selection.SelectResult(
            *(0,) * 6)),
        # outputs are semantically replicated (built from psum/all_gather
        # results), but the static varying-axis analysis cannot prove it
        check=False,
    )
    def run(x_local, *w_local):
        return multi_order_statistic_across_shards(
            x_local, ks, axes,
            weights=w_local[0] if w_local else None, **kwargs)

    return run(x) if weights is None else run(x, weights)


def sharded_quantiles(x, qs, mesh, in_spec, **kw):
    """Lower empirical quantiles of the sharded array (one multi-k solve:
    a decile vector costs the same psum rounds as a sharded median)."""
    return sharded_multi_order_statistic(
        x, selection.ranks_from_quantiles(qs, x.size), mesh, in_spec, **kw)


# ---------------------------------------------------------------------------
# Vectorized selection ACROSS a mesh axis (coordinate-wise order statistics)
# ---------------------------------------------------------------------------


def axis_evaluator(v_local: jax.Array, k, axes: AxisNames) -> FnEvaluator:
    """Evaluator for coordinate-wise selection ACROSS a mesh axis.

    The batch dimension is the coordinate set (this shard's array shape S);
    each coordinate's data is the ``n_rep`` replica values living one per
    device along ``axes``.  The psum combine of the four additive partials
    is the whole communication story — per iteration the wire carries four
    S-shaped vectors, never the replica data.

    The histogram pass (``method='binned'``) works the same way: each
    device one-hots its single replica value against the per-coordinate bin
    edges and the psum of the ``(S..., nbins + 2)`` count vectors is the
    full cross-replica histogram — one collective round buys log2(nbins)
    bisection steps for every coordinate at once.
    """
    axes_t = _axes_tuple(axes)
    v = v_local.astype(jnp.float32)
    n_rep = _psum(jnp.asarray(1, jnp.int32), axes_t)
    kk = jnp.broadcast_to(jnp.clip(jnp.asarray(k, jnp.int32), 1, n_rep),
                          v.shape)

    def partials(y):
        d = v - y
        return (_psum(jnp.maximum(d, 0), axes_t),
                _psum(jnp.maximum(-d, 0), axes_t),
                _psum((d < 0).astype(jnp.int32), axes_t),
                _psum((d <= 0).astype(jnp.int32), axes_t))

    def histogram(edges, need_msum=False):             # (S..., nbins + 1)
        cap = jnp.full_like(edges[..., :1], jnp.inf)
        lower = jnp.concatenate([-cap, edges], axis=-1)
        upper = jnp.concatenate([edges, cap], axis=-1)
        # slot 0 escapes the strict lower test (`v > -inf` would drop
        # v == -inf), matching the kernels' slot layout
        first = jnp.arange(edges.shape[-1] + 1) == 0
        m = ((v[..., None] > lower) | first) & (v[..., None] <= upper)
        # the counting measure: the psum'd counts serve as both the count
        # and the mass vector; the per-bin sums stay None (psumming them
        # would double the wire bytes, and the across-axis regime never
        # runs the polish)
        cnt = _psum(m.astype(jnp.int32), axes_t)
        return cnt, cnt, None

    def init_stats():
        return (_pmin(v, axes_t), _pmax(v, axes_t),
                _psum(v, axes_t) / n_rep.astype(jnp.float32))

    return FnEvaluator(partials, n_rep, kk, init_stats, histogram=histogram)


def order_statistic_across_axis(
    v_local: jax.Array,
    k: int,
    axes: AxisNames,
    *,
    maxit: int = 48,
    method: str = "auto",
    gather_threshold: int = 32,
    nbins: int = 32,
) -> jax.Array:
    """Coordinate-wise k-th order statistic across a mesh axis.

    ``v_local``: this shard's replica values, any shape S; conceptually the
    data is ``n_rep`` stacked S-arrays, one per device along ``axes``.
    Returns S-shaped array (replicated along ``axes``) with the k-th
    smallest across replicas, per coordinate.  This is the building block of
    robust gradient aggregation.

    method='gather' all-gathers the replica dimension and sorts locally;
    method='binned' runs histogram bracket descent over an
    :func:`axis_evaluator` — each collective round psums per-coordinate
    ``(nbins + 2,)`` count vectors and shrinks every bracket by a factor of
    ``nbins``, resolving in ~3 rounds where the cutting-plane loop
    (method='cp') psums four scalars per coordinate for ~n_rep-ish rounds;
    method='cp' is the paper's O(1)-memory cutting-plane iteration.

    method='auto' resolves STATICALLY (mesh axis sizes are trace-time
    constants) by replica count: 'gather' when ``n_rep <= gather_threshold``
    (default 32), else 'binned'.  Rationale: the all-gather materializes an
    ``(n_rep, S)`` buffer and sorts it — unbeatable while that buffer is a
    few shard-sizes, a memory blowup beyond; binned keeps O(S) memory and a
    round count independent of ``n_rep``.  Callers can override either the
    threshold or the method outright.

    Caveat: the count-based methods ('cp' and 'binned') see values through
    the platform's comparison/arithmetic semantics, so on FTZ hardware
    (XLA:CPU, some accelerator modes) coordinates whose replica values are
    DENORMAL-scale collapse to 0 — 'gather' (sort-based) keeps them.
    Gradient coordinates at 1e-44 carry no usable signal, so 'auto' does
    not branch on this; pass ``method='gather'`` explicitly if sub-normal
    resolution matters.
    """
    axes_t = _axes_tuple(axes)

    if method == "auto":
        # lax.psum of a python int constant-folds to the (static) axis size
        method = ("gather" if jax.lax.psum(1, axes_t) <= gather_threshold
                  else "binned")

    if method == "gather":
        g = v_local
        for ax in axes_t:
            g = jax.lax.all_gather(g, ax)  # leading replica dims
        g = g.reshape((-1,) + v_local.shape)
        gs = jnp.sort(g, axis=0)
        idx = jnp.clip(jnp.asarray(k, jnp.int32) - 1, 0, g.shape[0] - 1)
        return jnp.take(gs, idx, axis=0)

    if method not in ("cp", "binned"):
        raise ValueError(f"unknown method {method!r}")

    v = v_local.astype(jnp.float32)
    ev = axis_evaluator(v_local, k, axes_t)
    kk = ev.k

    # pre-seed coordinates whose answer sits at the extremes (incl. k==1,
    # k==n_rep and all-equal coordinates): they can never exact-hit at an
    # interior pivot, so certify them before the loop and keep them frozen
    yL0, yR0, _ = ev.init_stats()
    cle_min = _psum((v <= yL0).astype(jnp.int32), axes_t)
    clt_max = _psum((v < yR0).astype(jnp.int32), axes_t)
    at_min = cle_min >= kk
    at_max = clt_max < kk
    found0 = at_min | at_max
    t0 = jnp.where(at_min, yL0, jnp.where(at_max, yR0, jnp.nan))

    if method == "binned":
        # cap=1: a round ends for a coordinate once a single replica value
        # is bracketed (the vnext fallback below recovers it exactly) or a
        # binned certificate fires; ~3 psum rounds of (nbins+2,) counts
        # replace ~n_rep-ish rounds of scalar-quadruple psums
        s, _, _ = selection.binned_loop_batched(
            ev, nbins=nbins, maxit=maxit, cap=1, found0=found0, t0=t0)
    else:
        # cap=0: iterate to exact hit (or maxit) — there is no compaction
        # stage here (the replica data never leaves its device), so the
        # finalize is certificate + tie-fallback only
        s, _, _ = selection.bracket_loop_batched(
            ev, method="cp", maxit=maxit, cap=0, found0=found0, t0=t0)

    # tie fallback for coordinates that did not exact-hit: next distinct
    # value above yL, certified by counts (one extra pair of psums).
    big = jnp.asarray(jnp.inf, jnp.float32)
    vnext = _pmin(jnp.where(v > s.yL, v, big), axes_t)
    n_le_v = _psum((v <= vnext).astype(jnp.int32), axes_t)
    fb_ok = (s.cleL < kk) & (kk <= n_le_v)
    ans = jnp.where(s.found_exact, s.t_exact,
                    jnp.where(fb_ok, vnext, s.yR))
    return ans.astype(v_local.dtype)


def median_across_axis(v_local, axes, **kw):
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    n_rep = _psum(jnp.asarray(1, jnp.int32), axes_t)
    k = (n_rep + 1) // 2
    return order_statistic_across_axis(v_local, k, axes, **kw)
