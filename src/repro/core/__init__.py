"""Core: selection-by-convex-minimization (Beliakov 2011) + robust stats.

Batched-first: the engine solves (B,) selection problems per bracket loop;
``order_statistic`` is the B=1 view, ``select_rows`` the rows regime,
``multi_order_statistic``/``quantiles`` the shared-x regime.  Data access
goes through the ``Evaluator`` protocol (see ``repro.core.objective``).
"""
from repro.core.objective import (
    FG,
    Evaluator,
    FnEvaluator,
    RowsEvaluator,
    SharedEvaluator,
    ShardedEvaluator,
    eval_fg,
    eval_partials,
    fg_from_partials,
    os_weights,
)
from repro.core.selection import (
    EXACT_HIT,
    HYBRID_SORT,
    METHODS,
    NOT_CONVERGED,
    SelectResult,
    TIE_FALLBACK,
    median,
    multi_order_statistic,
    order_statistic,
    quantile,
    quantiles,
    select_rows,
    topk_threshold,
)

__all__ = [
    "FG", "eval_fg", "eval_partials", "fg_from_partials", "os_weights",
    "Evaluator", "FnEvaluator", "RowsEvaluator", "SharedEvaluator",
    "ShardedEvaluator",
    "SelectResult", "order_statistic", "select_rows",
    "multi_order_statistic", "quantiles", "median", "quantile",
    "topk_threshold",
    "METHODS", "EXACT_HIT", "HYBRID_SORT", "TIE_FALLBACK", "NOT_CONVERGED",
]
