"""Core: selection-by-convex-minimization (Beliakov 2011) + robust stats."""
from repro.core.objective import FG, eval_fg, eval_partials, fg_from_partials, os_weights
from repro.core.selection import (
    EXACT_HIT,
    HYBRID_SORT,
    METHODS,
    NOT_CONVERGED,
    SelectResult,
    TIE_FALLBACK,
    median,
    order_statistic,
    quantile,
    topk_threshold,
)

__all__ = [
    "FG", "eval_fg", "eval_partials", "fg_from_partials", "os_weights",
    "SelectResult", "order_statistic", "median", "quantile", "topk_threshold",
    "METHODS", "EXACT_HIT", "HYBRID_SORT", "TIE_FALLBACK", "NOT_CONVERGED",
]
