"""Core: selection-by-convex-minimization (Beliakov 2011) + robust stats.

Batched-first: the engine solves (B,) selection problems per bracket loop;
``order_statistic`` is the B=1 view, ``select_rows`` the rows regime,
``multi_order_statistic``/``quantiles`` the shared-x regime.  Data access
goes through the ``Evaluator`` protocol (see ``repro.core.objective``).
"""
from repro.core.objective import (
    FG,
    WFG,
    Evaluator,
    FnEvaluator,
    RowsEvaluator,
    SharedEvaluator,
    ShardedEvaluator,
    eval_fg,
    eval_partials,
    fg_from_partials,
    os_weights,
    wfg_from_partials,
)
from repro.core.selection import (
    EXACT_HIT,
    HYBRID_SORT,
    METHODS,
    NOT_CONVERGED,
    Prior,
    SelectResult,
    TIE_FALLBACK,
    as_prior,
    median,
    multi_order_statistic,
    order_statistic,
    quantile,
    quantiles,
    select_rows,
    topk_threshold,
    weighted_median,
    weighted_multi_order_statistic,
    weighted_order_statistic,
    weighted_quantile,
    weighted_quantiles,
    weighted_select_rows,
)
from repro.core.stream import QuantileTracker, reselect

__all__ = [
    "FG", "WFG", "eval_fg", "eval_partials", "fg_from_partials",
    "os_weights", "wfg_from_partials",
    "Evaluator", "FnEvaluator", "RowsEvaluator", "SharedEvaluator",
    "ShardedEvaluator",
    "Prior", "as_prior", "QuantileTracker", "reselect",
    "SelectResult", "order_statistic", "select_rows",
    "multi_order_statistic", "quantiles", "median", "quantile",
    "topk_threshold",
    "weighted_order_statistic", "weighted_select_rows",
    "weighted_multi_order_statistic", "weighted_median",
    "weighted_quantile", "weighted_quantiles",
    "METHODS", "EXACT_HIT", "HYBRID_SORT", "TIE_FALLBACK", "NOT_CONVERGED",
]
