from repro.analysis.roofline import analyze_compiled, roofline_terms
from repro.analysis.params import param_counts
