"""Analytic parameter counts (total + MoE-active) from the param shapes."""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig


def param_counts(params_shapes, cfg: ModelConfig):
    """(total_params, active_params). Active scales MoE expert tensors by
    top_k / num_experts (the dense-equivalent compute size)."""
    total = 0
    active = 0
    flat = jax.tree_util.tree_flatten_with_path(params_shapes)[0]
    for path, leaf in flat:
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if (cfg.moe is not None and "ffn" in names
                and names[-1] in ("w_gate", "w_in", "w_out")
                and leaf.ndim >= 3):
            n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
        active += n
    return total, active
