"""Roofline terms from a compiled (dry-run) artifact.

TPU v5e hardware constants (the TARGET; this container is CPU-only so terms
are *derived*, not measured):

    peak bf16 compute : 197 TFLOP/s per chip
    HBM bandwidth     : 819 GB/s per chip
    ICI link bandwidth: ~50 GB/s per link

Terms (per device; the compiled module is already the per-partition
program under SPMD):

    compute    = HLO_FLOPs / peak
    memory     = HLO_bytes_accessed / HBM_bw
    collective = sum over collective ops of (algorithm bytes) / link_bw

collective bytes are NOT in cost_analysis: we parse the compiled HLO and sum
operand bytes with ring-algorithm factors (all-reduce 2x, all-gather /
reduce-scatter / all-to-all / collective-permute 1x) — the standard
bytes-on-the-wire approximation.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

# result-shape factors for ring algorithms (bytes on the wire per device)
_COLL_FACTORS = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"=\s*\S+\s+while\(.*?condition=\s*%?([\w.\-]+)\s*,\s*body=\s*%?([\w.\-]+)")
_CALLS_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)="
    r"\s*({[^}]*}|%?[\w.\-]+)")
_TRIP_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str):
    """HLO text -> {comp_name: [lines]} (+ entry name).

    Computation headers are non-indented lines ``[ENTRY ]%name (params) ->
    type {``; params may contain nested parens (tuple types), so only the
    leading ``%name (`` is parsed.
    """
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        s = line.rstrip()
        if (not line[:1].isspace()) and s.endswith("{") and "->" in s:
            m = _COMP_RE.match(s.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if s.strip().startswith("ENTRY"):
                    entry = cur
                continue
        if s.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    return comps, entry


def _trip_count(cond_lines) -> int:
    """Best-effort trip count from a while condition: the largest constant
    in a comparison (XLA canonical counted loops compare counter < N)."""
    best = 1
    for line in cond_lines:
        if "compare" in line or "constant" in line:
            for m in _TRIP_RE.finditer(line):
                best = max(best, int(m.group(1)))
    return best


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+"
                     r"([a-z][\w\-]*)\(")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_TRIPCFG_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_WHILE_REF_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLED_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")

_ELEMWISE_SKIP = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "while", "iota", "copy-start", "copy-done",
}
_COLL_KINDS = set(_COLL_FACTORS)


def _dims(shape_str: str):
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt in _DTYPE_BYTES:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            out.append((dt, n))
    return out


def hlo_cost(hlo_text: str) -> Dict:
    """Trip-count-aware cost model of compiled (per-partition) HLO.

    jax's ``cost_analysis`` counts while (scan) bodies ONCE; layer stacks
    here are scans, so we walk the computation graph from ENTRY, weighting
    each op by the product of enclosing-while trip counts (XLA emits
    ``known_trip_count`` in backend_config):

      * flops: exact for dots (2*prod(result)*prod(lhs contracting dims),
        lhs shape resolved via a module-wide def-site shape map) + a
        1-flop/element proxy for other top-level ops;
      * bytes: result + operand bytes of top-level ops (post-fusion text, so
        fusion internals don't double count);
      * collectives: wire bytes, max(result, operands) x ring factor
        (all-reduce 2x, others 1x).
    """
    comps, entry = _split_computations(hlo_text)
    # def-site shape map (per computation, with module-wide fallback)
    shapes: Dict[str, str] = {}
    cshape: Dict[str, Dict[str, str]] = {}
    for cname, lines in comps.items():
        local = {}
        for line in lines:
            m = _DEF_RE.match(line)
            if m:
                local[m.group(1)] = m.group(2)
                shapes.setdefault(m.group(1), m.group(2))
        cshape[cname] = local

    def shape_of(comp, name):
        return cshape.get(comp, {}).get(name) or shapes.get(name, "")

    coll: Dict[str, float] = {k: 0.0 for k in _COLL_FACTORS}
    coll_counts: Dict[str, float] = {k: 0.0 for k in _COLL_FACTORS}
    totals = {"flops_dot": 0.0, "flops_proxy": 0.0, "bytes": 0.0}
    stack = []

    def operand_names(line):
        # first (...) group after the opcode
        m = _DEF_RE.match(line)
        if not m:
            return []
        rest = line[m.end() - 1:]
        om = _OPERANDS_RE.search(rest)
        if not om:
            return []
        return _OPERAND_NAME_RE.findall(om.group(1))

    def walk(comp: str, weight: float, inside_fusion: bool):
        if comp not in comps or comp in stack or weight <= 0:
            return
        stack.append(comp)
        for line in comps[comp]:
            m = _DEF_RE.match(line)
            if not m:
                continue
            res_shape, op = m.group(2), m.group(3)
            base = op.replace("-start", "").replace("-done", "")

            if base == "while":
                tm = _TRIPCFG_RE.search(line)
                trip = int(tm.group(1)) if tm else None
                wm = _WHILE_REF_RE.search(line)
                if wm:
                    cond, body = wm.group(1), wm.group(2)
                    if trip is None:
                        trip = _trip_count(comps.get(cond, []))
                    walk(body, weight * trip, inside_fusion)
                continue

            if base in _COLL_KINDS:
                if op.endswith("-done"):
                    continue
                b = _shape_bytes(res_shape)
                for o in operand_names(line):
                    b = max(b, _shape_bytes(shape_of(comp, o)))
                coll[base] += b * _COLL_FACTORS[base] * weight
                coll_counts[base] += weight
                continue

            if base == "dot":
                ops = operand_names(line)
                cm = _CONTRACT_RE.search(line)
                if ops and cm:
                    lhs = shape_of(comp, ops[0])
                    lhs_dims = []
                    sm = _SHAPE_RE.search(lhs)
                    if sm:
                        lhs_dims = [int(d) for d in sm.group(2).split(",")
                                    if d]
                    contract = 1
                    for i in cm.group(1).split(","):
                        if i != "" and int(i) < len(lhs_dims):
                            contract *= lhs_dims[int(i)]
                    out_elems = sum(n for _, n in _dims(res_shape))
                    totals["flops_dot"] += 2.0 * out_elems * contract * weight
                if not inside_fusion:
                    b = _shape_bytes(res_shape)
                    for o in operand_names(line):
                        b += _shape_bytes(shape_of(comp, o))
                    totals["bytes"] += b * weight
                continue

            called = _CALLED_RE.search(line)
            if base in ("fusion", "call", "custom-call", "map", "reduce",
                        "sort", "scatter", "reduce-window", "select-and-scatter"):
                if called:
                    walk(called.group(1), weight,
                         inside_fusion or base == "fusion")
            bm = _BRANCHES_RE.search(line)
            if bm:
                for br in _OPERAND_NAME_RE.findall(bm.group(1)):
                    walk(br, weight, inside_fusion)

            if inside_fusion:
                # only dots counted inside fusion bodies (handled above)
                continue
            if base in _ELEMWISE_SKIP:
                continue
            # generic top-level op: bytes = result + operands; proxy flops
            b = _shape_bytes(res_shape)
            elems = sum(n for _, n in _dims(res_shape))
            for o in operand_names(line):
                b += _shape_bytes(shape_of(comp, o))
            totals["bytes"] += b * weight
            totals["flops_proxy"] += elems * weight
        stack.pop()

    if entry is not None:
        walk(entry, 1.0, False)
    else:
        for name in comps:
            walk(name, 1.0, False)
    return {
        "flops": totals["flops_dot"] + totals["flops_proxy"],
        "flops_dot": totals["flops_dot"],
        "bytes": totals["bytes"],
        "collectives": coll,
        "collective_counts": coll_counts,
    }


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Back-compat wrapper: trip-aware collective bytes."""
    cost = hlo_cost(hlo_text)
    out = dict(cost["collectives"])
    out["_counts"] = cost["collective_counts"]  # type: ignore
    return out


def analyze_compiled(compiled, *, n_devices: int) -> Dict:
    """Extract the analysis numbers from a compiled executable.

    The primary flops/bytes come from the trip-count-aware HLO walk
    (``hlo_cost``): jax's ``cost_analysis`` counts while (scan) bodies once,
    which undercounts scanned layer stacks by the trip factor.  The raw
    cost_analysis values are kept as ``*_raw`` for reference.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # pre-0.5 jax wraps the dict in a list
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    text = compiled.as_text()
    walk = hlo_cost(text)
    coll_total = sum(walk["collectives"].values())
    return {
        "flops_per_device": float(walk["flops"]),
        "flops_dot_per_device": float(walk["flops_dot"]),
        "bytes_per_device": float(walk["bytes"]),
        "flops_per_device_raw": float(cost.get("flops", 0.0)),
        "bytes_per_device_raw": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_per_device": float(coll_total),
        "collective_breakdown": dict(walk["collectives"]),
        "collective_counts": walk["collective_counts"],
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "peak_hbm_bytes": int(mem.argument_size_in_bytes
                              + mem.output_size_in_bytes
                              + mem.temp_size_in_bytes),
        "n_devices": n_devices,
    }


def roofline_terms(analysis: Dict) -> Dict:
    """The three roofline terms in seconds + dominant bottleneck."""
    t_compute = analysis["flops_per_device"] / PEAK_FLOPS
    t_memory = analysis["bytes_per_device"] / HBM_BW
    t_coll = analysis["collective_bytes_per_device"] / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    total = max(sum(terms.values()), 1e-30)
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        # fraction of the step that the dominant term represents if perfectly
        # overlapped (roofline fraction = bound / sum when nothing overlaps)
        "roofline_fraction": bound / total,
    }
