"""Benchmark harness entry point — one bench per paper table/figure.

  selection_bench      Tables I/II (method x size x dtype)
  batched_selection    batched engine vs vmap-of-scalar vs sort, (B, n) grid
  distribution_bench   Sec. V-C (nine distributions)
  outlier_bench        Sec. V-D / Fig. 5 (extreme values)
  hybrid_breakdown     Sec. IV (CP iterations vs pivot-interval handoff)
  regression_bench     Sec. VI (LMS/LTS/kNN)
  roofline_bench       EXPERIMENTS.md §Roofline source (from dry-run cache)

Prints ``name,us_per_call,derived`` CSV.  ``--full`` uses paper-scale sizes.
``--json`` additionally writes the selection perf trajectory (grid point,
us_per_call, binned sweeps vs cp iterations) to repo-root
``BENCH_selection.json`` — the machine-readable record each perf PR updates.
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale array sizes (slow on CPU)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", action="store_true",
                    help="write repo-root BENCH_selection.json from the "
                         "batched_selection grid")
    args = ap.parse_args()

    # f64 columns of Table II need x64 (benchmarks run in their own process;
    # tests and smoke runs keep the default f32)
    import jax
    jax.config.update("jax_enable_x64", True)

    from benchmarks import (
        batched_selection_bench,
        clip_bench,
        distribution_bench,
        hybrid_breakdown_bench,
        outlier_bench,
        regression_bench,
        roofline_bench,
        selection_bench,
    )

    benches = {
        "selection": selection_bench,
        "batched_selection": batched_selection_bench,
        "distribution": distribution_bench,
        "outlier": outlier_bench,
        "hybrid": hybrid_breakdown_bench,
        "regression": regression_bench,
        "clip": clip_bench,
        "roofline": roofline_bench,
    }
    failed = []
    for name, mod in benches.items():
        if args.only and name != args.only:
            continue
        print(f"\n### bench: {name}")
        kw = {}
        if args.json and name == "batched_selection":
            kw["json_path"] = os.path.join(ROOT, "BENCH_selection.json")
        try:
            mod.run(full=args.full, **kw)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"\nFAILED benches: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
