"""Batched engine (cp vs binned) vs vmap-of-scalar-solver vs jnp.sort.

Three tentpole claims ride this bench:

* PR 1 (batched-first): one engine iterating a (B,) state block beats B
  lock-stepped scalar solvers (``jax.vmap`` of the public scalar API) and
  the full-sort baseline, bit-identical to ``np.partition`` row-wise.
* PR 2 (binned bracket descent): ``method='binned'`` resolves a solve in
  ~2-3 histogram sweeps where ``method='cp'`` needs ~10-20 fused passes —
  the ``sweeps_binned`` / ``iters_cp`` columns are the data-pass counts per
  solve (each binned sweep and each cp iteration is exactly one pass over
  the (B, n) block).
* PR 3 (weighted order statistics): the weighted-binned engine keeps the
  ~3-sweep schedule against a target cumulative MASS (the ``weighted_grid``
  records, bit-identical to the numpy sorted-cumsum oracle), vs the
  weighted sort-cumsum baseline (argsort + weight gather + cumsum +
  searchsorted — the thing every sort-based weighted median pays).
* PR 4 (in-bin CP polish): ``method='binned_polish'`` centers each sweep's
  bins on the cutting-plane cut recovered free from the previous sweep's
  per-bin sums — the ``sweeps_polish`` column records the data-pass
  reduction vs plain ``binned`` (2 -> 1 at n = 1M on normal data), still
  bit-identical to ``np.partition``.
* PR 5 (verified arithmetic binning): the ``hist_pass`` record compares one
  CPU histogram sweep against one fused FG pass at n = 1M — the
  searchsorted/scatter pass was ~25x a fused pass (why auto kept 'cp' on
  CPU); the verified arithmetic pass (multiply/floor/clip slots + factored
  one-hot reduction, counting-leg configuration) is what flipped
  ``method=None`` to 'binned' everywhere.  The ``distributed`` record
  (subprocess, forced host devices) tracks the psum-round counts:
  polish-driven rounds solve the 1M median in 1 round vs binned's 2, both
  measures.
* PR 6 (one-sweep multi-k): the ``multi_k`` record times a K-vector of
  quantiles of ONE array (K in {4, 16, 64} at n = 1M) against the K = 1
  binned median — every data pass is shared across the K ladders, so the
  sweep count stays ~flat in K (<= 2x the single-median sweeps at K = 16)
  where naive per-k dispatch would pay ~K x the HBM traffic.

Emits the usual CSV rows plus one ``BENCH_JSON`` line; ``run(json_path=...)``
(the ``benchmarks/run.py --json`` path) additionally writes the records to a
machine-readable perf-trajectory file (``BENCH_selection.json``).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import selection
from repro.kernels import ops, ref


def _hist_pass_record(rows):
    """One-histogram-sweep vs one-fused-FG-pass timings at n = 1M (jnp/CPU
    path), interleaved medians at matched jit-call granularity."""
    n = 1 << 20
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    nbins_jnp = selection.DEF_NBINS_JNP
    lo, hi = jnp.float32(-4.0), jnp.float32(4.0)
    e_jnp = ref.bin_edges(lo, hi, nbins_jnp)
    e_128 = ref.bin_edges(lo, hi, selection.DEF_NBINS)
    y = jnp.float32(0.01)

    fg = jax.jit(lambda v: ops.fused_partials(v, y, backend="jnp"))
    # the auto path's sweep: arithmetic slots, counting leg, no sums
    arith = jax.jit(lambda v: ops.fused_histogram(
        v, e_jnp, backend="jnp", impl="arithmetic", want_sums=False)[0])
    # yesterday's pass: binary-search slots + scatter at the kernel nbins
    ss128 = jax.jit(lambda v: ops.fused_histogram(
        v, e_128, backend="jnp", impl="searchsorted"))
    # interleave to share the machine's thermal/quota state
    t_fg = min(timeit(fg, x), timeit(fg, x))
    t_ar = min(timeit(arith, x), timeit(arith, x))
    t_ss = timeit(ss128, x, reps=3)
    t_fg = min(t_fg, timeit(fg, x))
    # engine granularity, tightly interleaved (shared-instant machine
    # state — CI/container CPU quotas swing several x over a bench run):
    # one binned sweep vs one cp iteration as the solver pays them
    k = jnp.asarray(n // 2 + 1, jnp.int32)
    x2 = x.reshape(1, -1)
    f_cp = jax.jit(lambda v: selection.select_rows(
        v, k, method="cp", backend="jnp").value)
    f_bin = jax.jit(lambda v: selection.select_rows(
        v, k, method="binned", backend="jnp").value)
    t_ecp = min(timeit(f_cp, x2, reps=3), timeit(f_cp, x2, reps=3))
    t_ebin = min(timeit(f_bin, x2, reps=3), timeit(f_bin, x2, reps=3))
    iters_cp = int(selection.select_rows(x2, k, method="cp",
                                         backend="jnp").iters[0])
    sweeps = int(selection.select_rows(x2, k, method="binned",
                                       backend="jnp").iters[0])
    per_sweep = t_ebin / max(sweeps, 1)
    per_pass = t_ecp / max(iters_cp, 1)
    rec = dict(
        n=n, nbins_jnp=nbins_jnp, nbins_searchsorted=selection.DEF_NBINS,
        us_fg_pass=t_fg * 1e6,
        us_hist_arith=t_ar * 1e6,
        us_hist_searchsorted_128=t_ss * 1e6,
        ratio_arith_over_fg=t_ar / t_fg,
        ratio_searchsorted_over_fg=t_ss / t_fg,
        us_engine_cp_total=t_ecp * 1e6,
        us_engine_binned_total=t_ebin * 1e6,
        engine_iters_cp=iters_cp,
        engine_sweeps_binned=sweeps,
        ratio_engine_sweep_over_cp_pass=per_sweep / per_pass,
        auto_method_jnp_1m=selection._resolve_method(None, n, "jnp"),
    )
    rows.append(("hist_arith_vs_fg/n=1M", t_ar * 1e6,
                 f"{t_ar / t_fg:.2f}x fg (searchsorted: "
                 f"{t_ss / t_fg:.1f}x)"))
    rows.append(("engine_binned_vs_cp/n=1M", t_ebin * 1e6,
                 f"cp={t_ecp * 1e6:.0f}us sweep/pass="
                 f"{per_sweep / per_pass:.2f}x"))
    return rec


def _multi_k_record(rows, full: bool = False):
    """One-sweep multi-k economics (PR 6): a K-vector of quantiles on ONE
    array shares every histogram sweep, so the sweep count stays ~flat in K
    (vs the naive K independent descents paying ~K x the HBM traffic).
    Records K in {4, 16, 64} at n = 1M against the K = 1 binned median
    baseline: total sweeps, us per call, and us per k."""
    n = 1 << 20
    rng = np.random.default_rng(7)
    x = rng.standard_normal(n).astype(np.float32)
    xj = jnp.asarray(x)
    k_med = (n + 1) // 2

    base = jax.jit(lambda v: selection.multi_order_statistic(
        v, jnp.asarray([k_med], jnp.int32), method="binned",
        backend="jnp").value)
    want_med = np.partition(x, k_med - 1)[k_med - 1]
    assert np.float32(np.asarray(base(xj))[0]) == want_med
    t_base = timeit(base, xj, reps=3)
    sweeps_base = int(jnp.max(selection.multi_order_statistic(
        xj, jnp.asarray([k_med], jnp.int32), method="binned",
        backend="jnp").iters))

    recs = []
    for kk in [4, 16, 64]:
        qs = [(i + 1) / (kk + 1) for i in range(kk)]
        ks = np.asarray([int(np.ceil(q * n)) for q in qs], np.int32)
        want = np.partition(x, ks - 1)[ks - 1]
        fn = jax.jit(lambda v, kv=jnp.asarray(ks): selection
                     .multi_order_statistic(v, kv, method="binned",
                                            backend="jnp").value)
        got = np.asarray(fn(xj))
        assert np.array_equal(got, want), ("multi_k", kk)
        t = timeit(fn, xj, reps=3)
        sweeps = int(jnp.max(selection.multi_order_statistic(
            xj, jnp.asarray(ks), method="binned", backend="jnp").iters))
        recs.append(dict(
            K=kk, n=n, sweeps=sweeps, sweeps_k1=sweeps_base,
            us_per_call=t * 1e6, us_per_k=t * 1e6 / kk,
            us_k1_baseline=t_base * 1e6,
            sweep_ratio_vs_k1=sweeps / max(sweeps_base, 1),
            time_ratio_vs_k1=t / t_base,
        ))
        rows.append((f"multi_k_binned/K={kk}/n={n}", t * 1e6,
                     f"sweeps={sweeps} (K=1: {sweeps_base}) "
                     f"{t * 1e6 / kk:.0f}us/k"))
    return recs


def _distributed_rounds_record(rows, n_dev=4, log2_n=20):
    """Psum-round counts from the forced-host-device subprocess worker;
    returns None (and keeps the bench green) if the worker can't run."""
    worker = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "_dist_rounds_worker.py")
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(worker)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    try:
        # bounded: a slow/overloaded runner skips the record (visibly, as
        # "distributed": null) instead of eating the CI budget
        out = subprocess.run(
            [sys.executable, worker, str(n_dev), str(log2_n)],
            capture_output=True, text=True, env=env, timeout=600)
    except Exception as exc:  # pragma: no cover - environment-dependent
        print(f"distributed rounds worker skipped: {exc}")
        return None
    for line in out.stdout.splitlines():
        if line.startswith("DIST_ROUNDS_JSON "):
            rec = json.loads(line[len("DIST_ROUNDS_JSON "):])
            rows.append((
                f"dist_rounds_polish/n_dev={n_dev}/n={1 << log2_n}",
                rec["rounds_binned_polish"],
                f"binned={rec['rounds_binned']} weighted_polish="
                f"{rec['rounds_binned_polish_weighted']}"))
            return rec
    print("distributed rounds worker failed:\n", out.stdout, out.stderr)
    return None


def _warm_start_record(rows, full: bool = False):
    """Warm-vs-cold grids for the prior leg: ``lts_fit``/``irls_fit``
    wall-clock at n = 1M plus drifting-stream re-select sweep counts.

    Warm and cold runs are bit-identical by contract (asserted here); the
    record captures the economy — steady-state sweeps and the wall-clock
    ratio — for the perf trajectory and the CI warm <= cold smoke."""
    from repro.core import robust, stream

    n = 1 << 20
    rng = np.random.default_rng(7)
    xs = rng.standard_normal(n).astype(np.float32)
    X = np.stack([np.ones_like(xs), xs], axis=1)
    y = (2.0 + 3.0 * xs + 0.1 * rng.standard_normal(n)).astype(np.float32)
    y = np.where(rng.random(n) < 0.2,
                 50.0 * rng.standard_normal(n).astype(np.float32), y)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    rec = {"n": n}

    # --- IRLS: warm carry across scale steps ------------------------------
    irls = lambda warm: robust.irls_fit(Xj, yj, loss="huber", iters=6,
                                        method="binned", warm=warm)
    fw, fc = irls(True), irls(False)
    assert np.array_equal(np.asarray(fw.theta), np.asarray(fc.theta))
    us_w = timeit(lambda: irls(True), reps=3, warmup=1) * 1e6
    us_c = timeit(lambda: irls(False), reps=3, warmup=1) * 1e6
    rec["irls"] = dict(
        iters=6, us_warm=us_w, us_cold=us_c, speedup=us_c / us_w,
        sweeps_warm=[int(s) for s in np.asarray(fw.sweeps)],
        sweeps_cold=[int(s) for s in np.asarray(fc.sweeps)])
    rows.append((f"irls_warm_vs_cold/n={n}", us_w,
                 f"cold={us_c:.0f}us speedup={us_c / us_w:.2f}x"))

    # --- LTS: warm carry across concentration steps -----------------------
    key = jax.random.PRNGKey(0)
    lts = lambda warm: robust.lts_fit(key, Xj, yj, n_starts=2, c_steps=5,
                                      method="binned", warm=warm)
    lw, lc = lts(True), lts(False)
    assert np.array_equal(np.asarray(lw.theta), np.asarray(lc.theta))
    us_w = timeit(lambda: lts(True), reps=3, warmup=1) * 1e6
    us_c = timeit(lambda: lts(False), reps=3, warmup=1) * 1e6
    rec["lts"] = dict(
        n_starts=2, c_steps=5, us_warm=us_w, us_cold=us_c,
        speedup=us_c / us_w,
        sweeps_warm=[int(s) for s in np.asarray(lw.sweeps).max(axis=1)],
        sweeps_cold=[int(s) for s in np.asarray(lc.sweeps).max(axis=1)])
    rows.append((f"lts_warm_vs_cold/n={n}", us_w,
                 f"cold={us_c:.0f}us speedup={us_c / us_w:.2f}x"))

    # --- drifting stream: re-select sweeps per tick -----------------------
    ticks = 6
    tr = stream.QuantileTracker(0.5, method="binned")
    cold_sweeps = []
    for t in range(ticks):
        xt = xs + 1e-3 * t * rng.standard_normal(n).astype(np.float32)
        res = tr.update(xt)
        coldr = selection.quantile(jnp.asarray(xt), 0.5, method="binned")
        assert np.asarray(res.value) == np.asarray(coldr.value)
        cold_sweeps.append(int(coldr.iters))
    rec["stream"] = dict(ticks=ticks, sweeps_warm=tr.sweeps,
                         sweeps_cold=cold_sweeps)
    rows.append((f"stream_reselect/n={n}", float(sum(tr.sweeps)),
                 f"cold_sweeps={sum(cold_sweeps)} per-tick={tr.sweeps}"))
    return rec


def run(full: bool = False, json_path: str | None = None):
    # quick mode keeps CI under a minute but still covers an n >= 1e6 point
    # (where the binned pass-count advantage is the whole story)
    grid = [(1, 1 << 12), (8, 1 << 12), (64, 1 << 12),
            (1, 1 << 16), (8, 1 << 16), (64, 1 << 16),
            (1, 1 << 20), (8, 1 << 20)]
    if full:
        grid += [(256, 1 << 16), (64, 1 << 20), (1, 1 << 24)]
    rng = np.random.default_rng(0)
    rows, records = [], []
    for b, n in grid:
        x = rng.standard_normal((b, n)).astype(np.float32)
        xj = jnp.asarray(x)
        k = (n + 1) // 2
        want = np.partition(x, k - 1, axis=1)[:, k - 1]

        vmapped = jax.jit(jax.vmap(
            lambda xi: selection.order_statistic(xi, k, method="cp").value))
        batched_cp = jax.jit(
            lambda v: selection.select_rows(v, k, method="cp").value)
        batched_binned = jax.jit(
            lambda v: selection.select_rows(v, k, method="binned").value)
        batched_polish = jax.jit(
            lambda v: selection.select_rows(v, k,
                                            method="binned_polish").value)
        sort = jax.jit(lambda v: jnp.sort(v, axis=1)[:, k - 1])

        impls = {"vmap_scalar": vmapped, "batched_cp": batched_cp,
                 "batched_binned": batched_binned,
                 "batched_polish": batched_polish, "sort": sort}
        times = {}
        for name, fn in impls.items():
            got = np.asarray(fn(xj))
            assert np.array_equal(got, want), (name, b, n)
            times[name] = timeit(fn, xj, reps=3)

        # data-pass counts per solve: one fused pass per cp iteration, one
        # histogram sweep per binned iteration (max over rows)
        iters_cp = int(jnp.max(
            selection.select_rows(xj, k, method="cp").iters))
        sweeps_binned = int(jnp.max(
            selection.select_rows(xj, k, method="binned").iters))
        sweeps_polish = int(jnp.max(
            selection.select_rows(xj, k, method="binned_polish").iters))
        speedup = times["vmap_scalar"] / times["batched_cp"]
        for name, t in times.items():
            rows.append((
                f"{name}/B={b}/n={n}", t * 1e6,
                f"{b * n / t / 1e6:.1f}Melem/s",
            ))
        rows.append((f"speedup_batched_over_vmap/B={b}/n={n}",
                     speedup, f"iters={iters_cp}"))
        rows.append((f"passes_binned_vs_cp/B={b}/n={n}",
                     sweeps_binned, f"cp={iters_cp}"))
        rows.append((f"sweeps_polish_vs_binned/B={b}/n={n}",
                     sweeps_polish, f"binned={sweeps_binned}"))
        records.append(dict(
            B=b, n=n, k=k,
            iters_cp=iters_cp, sweeps=sweeps_binned,
            sweeps_polish=sweeps_polish,
            us_vmap=times["vmap_scalar"] * 1e6,
            us_batched_cp=times["batched_cp"] * 1e6,
            us_per_call=times["batched_binned"] * 1e6,  # the binned engine
            us_batched_polish=times["batched_polish"] * 1e6,
            us_sort=times["sort"] * 1e6,
            speedup_batched_over_vmap=speedup,
            speedup_binned_over_cp=times["batched_cp"]
            / times["batched_binned"],
        ))
    # ---- weighted rows: weighted-binned vs weighted sort-cumsum ----------
    wgrid = [(1, 1 << 16), (8, 1 << 16), (1, 1 << 20)]
    if full:
        wgrid += [(8, 1 << 20)]
    wrecords = []
    for b, n in wgrid:
        x = rng.standard_normal((b, n)).astype(np.float32)
        # integer weights: masses exactly summable, so every method must be
        # bit-identical to the f64 sorted-cumsum oracle
        w = rng.integers(1, 4, (b, n)).astype(np.float32)
        wks = (0.5 * w.sum(axis=1)).astype(np.float32)
        xj, wj, wkj = jnp.asarray(x), jnp.asarray(w), jnp.asarray(wks)
        want = np.empty(b, np.float32)
        for i in range(b):
            o = np.argsort(x[i], kind="stable")
            c = np.cumsum(w[i][o].astype(np.float64))
            want[i] = x[i][o][np.searchsorted(c, wks[i], "left")]

        impls = {
            "weighted_binned": jax.jit(lambda v, wv, t: selection
                                       .weighted_select_rows(
                                           v, wv, t, method="binned").value),
            "weighted_cp": jax.jit(lambda v, wv, t: selection
                                   .weighted_select_rows(
                                       v, wv, t, method="cp").value),
            "weighted_sort_cumsum": jax.jit(
                lambda v, wv, t: selection.weighted_select_rows(
                    v, wv, t, method="sort").value),
        }
        times = {}
        for name, fn in impls.items():
            got = np.asarray(fn(xj, wj, wkj))
            assert np.array_equal(got, want), (name, b, n)
            times[name] = timeit(fn, xj, wj, wkj, reps=3)

        sweeps_w = int(jnp.max(selection.weighted_select_rows(
            xj, wj, wkj, method="binned").iters))
        iters_wcp = int(jnp.max(selection.weighted_select_rows(
            xj, wj, wkj, method="cp").iters))
        res_wp = selection.weighted_select_rows(xj, wj, wkj,
                                                method="binned_polish")
        assert np.array_equal(np.asarray(res_wp.value), want), (b, n)
        sweeps_w_polish = int(jnp.max(res_wp.iters))
        for name, t in times.items():
            rows.append((f"{name}/B={b}/n={n}", t * 1e6,
                         f"{b * n / t / 1e6:.1f}Melem/s"))
        rows.append((f"weighted_sweeps_binned_vs_cp/B={b}/n={n}",
                     sweeps_w, f"cp={iters_wcp}"))
        rows.append((f"weighted_sweeps_polish_vs_binned/B={b}/n={n}",
                     sweeps_w_polish, f"binned={sweeps_w}"))
        wrecords.append(dict(
            B=b, n=n,
            sweeps=sweeps_w, iters_cp=iters_wcp,
            sweeps_polish=sweeps_w_polish,
            us_per_call=times["weighted_binned"] * 1e6,
            us_weighted_cp=times["weighted_cp"] * 1e6,
            us_weighted_sort=times["weighted_sort_cumsum"] * 1e6,
            speedup_binned_over_sort=times["weighted_sort_cumsum"]
            / times["weighted_binned"],
        ))

    # ---- multi-k sweep sharing + histogram-pass microbench + distributed
    # round counts ---------------------------------------------------------
    multi_k_recs = _multi_k_record(rows, full=full)
    hist_rec = _hist_pass_record(rows)
    dist_rec = _distributed_rounds_record(rows)
    warm_rec = _warm_start_record(rows, full=full)

    emit(rows)
    # schema 2: adds the schema field itself + the warm_start grids (PR 10)
    payload = {"schema": 2, "bench": "batched_selection", "exact": True,
               "backend": jax.default_backend(), "grid": records,
               "weighted_grid": wrecords, "multi_k": multi_k_recs,
               "hist_pass": hist_rec, "distributed": dist_rec,
               "warm_start": warm_rec}
    print("BENCH_JSON " + json.dumps(payload))
    if json_path is not None:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {json_path}")
    return rows


if __name__ == "__main__":
    run(full=False)
