"""Batched engine vs vmap-of-scalar-solver vs jnp.sort over a (B, n) grid.

The tentpole claim of the batched-first refactor: one engine iterating a
(B,) state block beats B lock-stepped scalar solvers (``jax.vmap`` of the
public scalar API — exactly how the pre-refactor hot paths ran) and the
full-sort baseline, while staying bit-identical to ``np.partition`` row-wise.

Emits the usual CSV rows plus one ``BENCH_JSON`` line (machine-readable
perf-trajectory record: every configuration with us/call for all three
implementations and the batched/vmap speedup).
"""
from __future__ import annotations

import json

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import selection


def run(full: bool = False):
    grid_b = [1, 8, 64] + ([256] if full else [])
    grid_n = [1 << 12, 1 << 16] + ([1 << 20] if full else [])
    rng = np.random.default_rng(0)
    rows, records = [], []
    for n in grid_n:
        for b in grid_b:
            x = rng.standard_normal((b, n)).astype(np.float32)
            xj = jnp.asarray(x)
            k = (n + 1) // 2
            want = np.partition(x, k - 1, axis=1)[:, k - 1]

            vmapped = jax.jit(jax.vmap(
                lambda xi: selection.order_statistic(xi, k).value))
            batched = jax.jit(
                lambda v: selection.select_rows(v, k).value)
            sort = jax.jit(lambda v: jnp.sort(v, axis=1)[:, k - 1])

            impls = {"vmap_scalar": vmapped, "batched": batched,
                     "sort": sort}
            times = {}
            for name, fn in impls.items():
                got = np.asarray(fn(xj))
                assert np.array_equal(got, want), (name, b, n)
                times[name] = timeit(fn, xj, reps=3)

            res = selection.select_rows(xj, k)
            iters = int(jnp.max(res.iters))
            speedup = times["vmap_scalar"] / times["batched"]
            for name, t in times.items():
                rows.append((
                    f"{name}/B={b}/n={n}", t * 1e6,
                    f"{b * n / t / 1e6:.1f}Melem/s",
                ))
            rows.append((f"speedup_batched_over_vmap/B={b}/n={n}",
                         speedup, f"iters={iters}"))
            records.append(dict(
                B=b, n=n, k=k, iters=iters,
                us_vmap=times["vmap_scalar"] * 1e6,
                us_batched=times["batched"] * 1e6,
                us_sort=times["sort"] * 1e6,
                speedup_batched_over_vmap=speedup,
            ))
    emit(rows)
    print("BENCH_JSON " + json.dumps(
        {"bench": "batched_selection", "exact": True, "grid": records}))
    return rows


if __name__ == "__main__":
    run(full=False)
