"""Batched engine (cp vs binned) vs vmap-of-scalar-solver vs jnp.sort.

Three tentpole claims ride this bench:

* PR 1 (batched-first): one engine iterating a (B,) state block beats B
  lock-stepped scalar solvers (``jax.vmap`` of the public scalar API) and
  the full-sort baseline, bit-identical to ``np.partition`` row-wise.
* PR 2 (binned bracket descent): ``method='binned'`` resolves a solve in
  ~2-3 histogram sweeps where ``method='cp'`` needs ~10-20 fused passes —
  the ``sweeps_binned`` / ``iters_cp`` columns are the data-pass counts per
  solve (each binned sweep and each cp iteration is exactly one pass over
  the (B, n) block).
* PR 3 (weighted order statistics): the weighted-binned engine keeps the
  ~3-sweep schedule against a target cumulative MASS (the ``weighted_grid``
  records, bit-identical to the numpy sorted-cumsum oracle), vs the
  weighted sort-cumsum baseline (argsort + weight gather + cumsum +
  searchsorted — the thing every sort-based weighted median pays).
* PR 4 (in-bin CP polish): ``method='binned_polish'`` centers each sweep's
  bins on the cutting-plane cut recovered free from the previous sweep's
  per-bin sums — the ``sweeps_polish`` column records the data-pass
  reduction vs plain ``binned`` (2 -> 1 at n = 1M on normal data), still
  bit-identical to ``np.partition``.

Emits the usual CSV rows plus one ``BENCH_JSON`` line; ``run(json_path=...)``
(the ``benchmarks/run.py --json`` path) additionally writes the records to a
machine-readable perf-trajectory file (``BENCH_selection.json``).
"""
from __future__ import annotations

import json

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import selection


def run(full: bool = False, json_path: str | None = None):
    # quick mode keeps CI under a minute but still covers an n >= 1e6 point
    # (where the binned pass-count advantage is the whole story)
    grid = [(1, 1 << 12), (8, 1 << 12), (64, 1 << 12),
            (1, 1 << 16), (8, 1 << 16), (64, 1 << 16),
            (1, 1 << 20), (8, 1 << 20)]
    if full:
        grid += [(256, 1 << 16), (64, 1 << 20), (1, 1 << 24)]
    rng = np.random.default_rng(0)
    rows, records = [], []
    for b, n in grid:
        x = rng.standard_normal((b, n)).astype(np.float32)
        xj = jnp.asarray(x)
        k = (n + 1) // 2
        want = np.partition(x, k - 1, axis=1)[:, k - 1]

        vmapped = jax.jit(jax.vmap(
            lambda xi: selection.order_statistic(xi, k, method="cp").value))
        batched_cp = jax.jit(
            lambda v: selection.select_rows(v, k, method="cp").value)
        batched_binned = jax.jit(
            lambda v: selection.select_rows(v, k, method="binned").value)
        batched_polish = jax.jit(
            lambda v: selection.select_rows(v, k,
                                            method="binned_polish").value)
        sort = jax.jit(lambda v: jnp.sort(v, axis=1)[:, k - 1])

        impls = {"vmap_scalar": vmapped, "batched_cp": batched_cp,
                 "batched_binned": batched_binned,
                 "batched_polish": batched_polish, "sort": sort}
        times = {}
        for name, fn in impls.items():
            got = np.asarray(fn(xj))
            assert np.array_equal(got, want), (name, b, n)
            times[name] = timeit(fn, xj, reps=3)

        # data-pass counts per solve: one fused pass per cp iteration, one
        # histogram sweep per binned iteration (max over rows)
        iters_cp = int(jnp.max(
            selection.select_rows(xj, k, method="cp").iters))
        sweeps_binned = int(jnp.max(
            selection.select_rows(xj, k, method="binned").iters))
        sweeps_polish = int(jnp.max(
            selection.select_rows(xj, k, method="binned_polish").iters))
        speedup = times["vmap_scalar"] / times["batched_cp"]
        for name, t in times.items():
            rows.append((
                f"{name}/B={b}/n={n}", t * 1e6,
                f"{b * n / t / 1e6:.1f}Melem/s",
            ))
        rows.append((f"speedup_batched_over_vmap/B={b}/n={n}",
                     speedup, f"iters={iters_cp}"))
        rows.append((f"passes_binned_vs_cp/B={b}/n={n}",
                     sweeps_binned, f"cp={iters_cp}"))
        rows.append((f"sweeps_polish_vs_binned/B={b}/n={n}",
                     sweeps_polish, f"binned={sweeps_binned}"))
        records.append(dict(
            B=b, n=n, k=k,
            iters_cp=iters_cp, sweeps=sweeps_binned,
            sweeps_polish=sweeps_polish,
            us_vmap=times["vmap_scalar"] * 1e6,
            us_batched_cp=times["batched_cp"] * 1e6,
            us_per_call=times["batched_binned"] * 1e6,  # the binned engine
            us_batched_polish=times["batched_polish"] * 1e6,
            us_sort=times["sort"] * 1e6,
            speedup_batched_over_vmap=speedup,
            speedup_binned_over_cp=times["batched_cp"]
            / times["batched_binned"],
        ))
    # ---- weighted rows: weighted-binned vs weighted sort-cumsum ----------
    wgrid = [(1, 1 << 16), (8, 1 << 16), (1, 1 << 20)]
    if full:
        wgrid += [(8, 1 << 20)]
    wrecords = []
    for b, n in wgrid:
        x = rng.standard_normal((b, n)).astype(np.float32)
        # integer weights: masses exactly summable, so every method must be
        # bit-identical to the f64 sorted-cumsum oracle
        w = rng.integers(1, 4, (b, n)).astype(np.float32)
        wks = (0.5 * w.sum(axis=1)).astype(np.float32)
        xj, wj, wkj = jnp.asarray(x), jnp.asarray(w), jnp.asarray(wks)
        want = np.empty(b, np.float32)
        for i in range(b):
            o = np.argsort(x[i], kind="stable")
            c = np.cumsum(w[i][o].astype(np.float64))
            want[i] = x[i][o][np.searchsorted(c, wks[i], "left")]

        impls = {
            "weighted_binned": jax.jit(lambda v, wv, t: selection
                                       .weighted_select_rows(
                                           v, wv, t, method="binned").value),
            "weighted_cp": jax.jit(lambda v, wv, t: selection
                                   .weighted_select_rows(
                                       v, wv, t, method="cp").value),
            "weighted_sort_cumsum": jax.jit(
                lambda v, wv, t: selection.weighted_select_rows(
                    v, wv, t, method="sort").value),
        }
        times = {}
        for name, fn in impls.items():
            got = np.asarray(fn(xj, wj, wkj))
            assert np.array_equal(got, want), (name, b, n)
            times[name] = timeit(fn, xj, wj, wkj, reps=3)

        sweeps_w = int(jnp.max(selection.weighted_select_rows(
            xj, wj, wkj, method="binned").iters))
        iters_wcp = int(jnp.max(selection.weighted_select_rows(
            xj, wj, wkj, method="cp").iters))
        res_wp = selection.weighted_select_rows(xj, wj, wkj,
                                                method="binned_polish")
        assert np.array_equal(np.asarray(res_wp.value), want), (b, n)
        sweeps_w_polish = int(jnp.max(res_wp.iters))
        for name, t in times.items():
            rows.append((f"{name}/B={b}/n={n}", t * 1e6,
                         f"{b * n / t / 1e6:.1f}Melem/s"))
        rows.append((f"weighted_sweeps_binned_vs_cp/B={b}/n={n}",
                     sweeps_w, f"cp={iters_wcp}"))
        rows.append((f"weighted_sweeps_polish_vs_binned/B={b}/n={n}",
                     sweeps_w_polish, f"binned={sweeps_w}"))
        wrecords.append(dict(
            B=b, n=n,
            sweeps=sweeps_w, iters_cp=iters_wcp,
            sweeps_polish=sweeps_w_polish,
            us_per_call=times["weighted_binned"] * 1e6,
            us_weighted_cp=times["weighted_cp"] * 1e6,
            us_weighted_sort=times["weighted_sort_cumsum"] * 1e6,
            speedup_binned_over_sort=times["weighted_sort_cumsum"]
            / times["weighted_binned"],
        ))

    emit(rows)
    payload = {"bench": "batched_selection", "exact": True,
               "backend": jax.default_backend(), "grid": records,
               "weighted_grid": wrecords}
    print("BENCH_JSON " + json.dumps(payload))
    if json_path is not None:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {json_path}")
    return rows


if __name__ == "__main__":
    run(full=False)
