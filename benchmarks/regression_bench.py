"""Paper Sec. VI: robust regression (LMS/LTS) and kNN built on selection.

Reports (a) fit time, (b) estimation error vs outlier fraction — the
high-breakdown property (LS collapses, LTS/LMS do not), and (c) the
selection-based kNN vs a sort-based kNN.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import robust


def make_data(rng, n, p, frac, scale=500.0):
    X = rng.standard_normal((n, p)).astype(np.float32)
    X[:, -1] = 1.0
    theta = rng.standard_normal(p).astype(np.float32)
    y = X @ theta + 0.01 * rng.standard_normal(n).astype(np.float32)
    m = int(frac * n)
    idx = rng.choice(n, m, replace=False)
    y[idx] += scale
    return X, y, theta


def run(full: bool = False):
    n = 4096 if full else 1024
    p = 4
    rng = np.random.default_rng(4)
    rows = []
    for frac in [0.0, 0.1, 0.2, 0.3, 0.4]:
        X, y, theta = make_data(rng, n, p, frac)
        Xj, yj = jnp.asarray(X), jnp.asarray(y)
        theta_ls = np.linalg.lstsq(X, y, rcond=None)[0]
        key = jax.random.PRNGKey(0)
        t_lts = timeit(lambda: robust.lts_fit(key, Xj, yj, n_starts=64),
                       reps=2, warmup=1)
        fit = robust.lts_fit(key, Xj, yj, n_starts=64)
        err_lts = float(np.linalg.norm(np.asarray(fit.theta) - theta))
        err_ls = float(np.linalg.norm(theta_ls - theta))
        rows.append((f"lts_fit/outliers={frac:.0%}/n={n}", t_lts * 1e6,
                     f"err_lts={err_lts:.4f};err_ls={err_ls:.4f}"))
    # LMS
    X, y, theta = make_data(rng, n, p, 0.3)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    t_lms = timeit(lambda: robust.lms_fit(jax.random.PRNGKey(1), Xj, yj,
                                          n_starts=256), reps=2, warmup=1)
    fit = robust.lms_fit(jax.random.PRNGKey(1), Xj, yj, n_starts=256)
    rows.append((f"lms_fit/outliers=30%/n={n}", t_lms * 1e6,
                 f"err={float(np.linalg.norm(np.asarray(fit.theta) - theta)):.4f}"))

    # kNN: selection-based cutoff vs full sort
    nt = 8192 if full else 2048
    tx = rng.standard_normal((nt, 8)).astype(np.float32)
    ty = rng.standard_normal(nt).astype(np.float32)
    qx = rng.standard_normal((64, 8)).astype(np.float32)
    txj, tyj, qxj = map(jnp.asarray, (tx, ty, qx))
    t_sel = timeit(jax.jit(lambda a, b, c: robust.knn_predict(a, b, c, 16)),
                   txj, tyj, qxj, reps=3)

    @jax.jit
    def knn_sort(a, b, c):
        d2 = (jnp.sum(c**2, -1, keepdims=True) - 2 * c @ a.T
              + jnp.sum(a**2, -1)[None])
        idx = jnp.argsort(d2, axis=1)[:, :16]
        return jnp.mean(b[idx], axis=1)

    t_sort = timeit(knn_sort, txj, tyj, qxj, reps=3)
    got = np.asarray(robust.knn_predict(txj, tyj, qxj, 16))
    want = np.asarray(knn_sort(txj, tyj, qxj))
    rows.append((f"knn_select/n={nt}", t_sel * 1e6,
                 f"match_sort={np.allclose(got, want, atol=1e-4)}"))
    rows.append((f"knn_sort/n={nt}", t_sort * 1e6,
                 f"speedup={t_sort / t_sel:.2f}x"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run(full=True)
