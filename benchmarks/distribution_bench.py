"""Paper Sec. V-C: CP behaviour across the nine data distributions.

The paper reports <5% spread of CP runtime across distributions; the
hardware-independent equivalent is the iteration count and pivot-interval
size, which we tabulate here.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit, paper_datasets, timeit
from repro.core import selection


def run(full: bool = False):
    n = (1 << 21) if full else (1 << 17)
    rng = np.random.default_rng(1)
    rows = []
    iters = []
    for name, x in paper_datasets(rng, n).items():
        x = x.astype(np.float32)
        rng.shuffle(x)
        xj = jnp.asarray(x)
        t = timeit(lambda v: selection.median(v).value, xj, reps=3)
        res = selection.median(xj)
        k = (n + 1) // 2
        assert np.float32(res.value) == np.partition(x, k - 1)[k - 1], name
        iters.append(int(res.iters))
        rows.append((f"cp_median/{name}/n={n}", t * 1e6,
                     f"iters={int(res.iters)};z={int(res.n_in)}"))
    spread = (max(iters) - min(iters))
    rows.append((f"cp_median/iter_spread/n={n}", 0.0,
                 f"min={min(iters)};max={max(iters)};spread={spread}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run(full=True)
