"""Gradient-clipping method comparison (the paper-primitive integration).

Wall-clock per call on a synthetic multi-tensor gradient pytree: the
cutting-plane quantile (exactness certificates, maxit fused sweeps), the
2-pass histogram variant, global-norm clipping, and the per-leaf quantile
path (one segmented multi-k solve resolving EVERY leaf's threshold off
shared histogram sweeps — vs L independent solves).  Complements the
dry-run ablations in EXPERIMENTS.md §Perf (which showed all variants cost
<0.1% of a training step at the production mesh).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import robust


def make_grads(rng, scale=1):
    return {
        "embed": jnp.asarray(
            rng.standard_normal((2048 * scale, 512)).astype(np.float32)),
        "layers": [
            {"w1": jnp.asarray(rng.standard_normal(
                (512, 2048)).astype(np.float32) * 0.1),
             "w2": jnp.asarray(rng.standard_normal(
                 (2048, 512)).astype(np.float32) * 10.0)}
            for _ in range(4 * scale)
        ],
    }


def run(full: bool = False):
    rng = np.random.default_rng(0)
    grads = make_grads(rng, scale=4 if full else 1)
    n = sum(l.size for l in jax.tree.leaves(grads))
    rows = []

    fn_cp = jax.jit(lambda g: robust.clip_by_quantile(g, 0.99)[1])
    fn_hist = jax.jit(lambda g: robust.hist_quantile(g, 0.99))

    @jax.jit
    def fn_gn(g):
        return jnp.sqrt(sum(jnp.sum(jnp.square(l))
                            for l in jax.tree.leaves(g)))

    t_cp = timeit(fn_cp, grads, reps=3)
    t_hist = timeit(fn_hist, grads, reps=3)
    t_gn = timeit(fn_gn, grads, reps=3)

    flat = np.abs(np.concatenate(
        [np.asarray(l).ravel() for l in jax.tree.leaves(grads)]))
    k = int(np.ceil(0.99 * n))
    exact = np.partition(flat, k - 1)[k - 1]
    err_cp = abs(float(fn_cp(grads)) - exact) / exact
    err_hist = abs(float(fn_hist(grads)) - exact) / exact

    rows.append((f"clip_cp/n={n}", t_cp * 1e6, f"rel_err={err_cp:.2e}"))
    rows.append((f"clip_hist/n={n}", t_hist * 1e6,
                 f"rel_err={err_hist:.2e}"))
    rows.append((f"clip_global_norm/n={n}", t_gn * 1e6, "no_quantile"))

    # per-leaf thresholds: one segmented multi-k solve (shared sweeps across
    # all leaves) vs L independent per-leaf solves — both EXACT per leaf.
    # The shared-sweep win is HBM traffic (one read of the concatenated
    # tree per round); on the CPU jnp path the factored one-hot reduction
    # is compute-bound at O(L * n) per sweep, so this row tracks the
    # trajectory rather than demonstrating the accelerator-side economics.
    leaves = jax.tree.leaves(grads)
    fn_leaf = jax.jit(
        lambda g: jax.tree.leaves(robust.pytree_quantile_per_leaf(g, 0.99)))
    fn_leaf_indep = jax.jit(lambda g: [
        robust.selection.quantile(jnp.abs(l).reshape(-1), 0.99).value
        for l in jax.tree.leaves(g)])
    t_leaf = timeit(fn_leaf, grads, reps=3)
    t_indep = timeit(fn_leaf_indep, grads, reps=3)
    exact_leaf = np.array([
        np.partition(np.abs(np.asarray(l)).ravel(), kl - 1)[kl - 1]
        for l in leaves
        for kl in [int(np.ceil(0.99 * l.size))]], np.float32)
    got_leaf = np.asarray(fn_leaf(grads), np.float32)
    err_leaf = float(np.max(np.abs(got_leaf - exact_leaf)
                            / np.maximum(exact_leaf, 1e-30)))
    rows.append((f"clip_per_leaf_segmented/L={len(leaves)}/n={n}",
                 t_leaf * 1e6,
                 f"max_rel_err={err_leaf:.2e} indep={t_indep * 1e6:.0f}us"))
    rows.append((f"clip_per_leaf_indep/L={len(leaves)}/n={n}",
                 t_indep * 1e6,
                 f"segmented_speedup={t_indep / t_leaf:.2f}x"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run(full=True)
