"""Paper Tables I & II analogue: median time per method x array size.

Columns in the paper: radix sort, quickselect CPU, quickselect GPU, cutting
plane (+ stage breakdown), bisection, Brent x2.  Mapping here:

  sort          -> jnp/XLA sort (the platform's fastest sort = radix analog)
  numpy_select  -> np.partition (the "quickselect on CPU" row)
  cp            -> cutting plane + count-bounded hybrid finalize (ours)
  bisection / golden / brent -> the paper's baseline minimizers

Wall times on this container are CPU times (indicative); the
hardware-independent columns are the iteration counts and the pivot-interval
size, which transfer directly to TPU (each iteration = one fused reduction).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import selection


def run(full: bool = False):
    sizes = [1 << 13, 1 << 15, 1 << 17, 1 << 19]
    if full:
        sizes += [1 << 21, 1 << 23, 1 << 25]
    rng = np.random.default_rng(0)
    rows = []
    for dtype, dname in [(np.float32, "f32"), (np.float64, "f64")]:
        for n in sizes:
            x = rng.standard_normal(n).astype(dtype)
            xj = jnp.asarray(x)
            k = (n + 1) // 2
            want = np.partition(x, k - 1)[k - 1]

            # numpy partition = "quickselect on CPU" baseline
            t = timeit(lambda: np.partition(x, k - 1)[k - 1], reps=3)
            rows.append((f"numpy_select/{dname}/n={n}", t * 1e6,
                         f"{n / t / 1e6:.1f}Melem/s"))

            for method in ["sort", "cp", "binned", "bisection", "brent"]:
                fn = jax.jit(
                    lambda v, m=method: selection.order_statistic(
                        v, k, method=m, maxit=256).value)
                t = timeit(fn, xj, reps=3)
                got = np.asarray(fn(xj))
                assert got == dtype(want), (method, n, got, want)
                res = selection.order_statistic(xj, k, method=method,
                                                maxit=256)
                rows.append((
                    f"{method}/{dname}/n={n}", t * 1e6,
                    f"iters={int(res.iters)};z={int(res.n_in)};"
                    f"{n / t / 1e6:.1f}Melem/s"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run(full=True)
