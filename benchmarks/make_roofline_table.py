"""Regenerate experiments/roofline_table.md from the dry-run artifacts."""
import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRY = os.path.join(ROOT, "experiments", "dryrun")

LEVERS = {
    ("rwkv6-1.6b", "train_4k"): "time-scan -> chunked GLA matmuls (§Perf)",
    ("rwkv6-1.6b", "prefill_32k"): "same lever as train_4k (chunked mixer)",
    ("gemma3-27b", "train_4k"):
        "TP activation all-reduces -> pure-FSDP plan (§Perf)",
    ("gemma2-2b", "train_4k"):
        "8 heads < tp16 => replicated attention -> FSDP plan (§Perf)",
    ("kimi-k2-1t-a32b", "train_4k"):
        "expert-FSDP gathers dominate; needs >=1k chips or 2D EP",
    ("kimi-k2-1t-a32b", "decode_32k"):
        "FSDP weight gathers at decode; serve on more chips / TP-pure",
    ("mixtral-8x7b", "decode_32k"):
        "FSDP gathers at decode (same lever as kimi)",
    ("gemma2-2b", "prefill_32k"):
        "replicated-attention flash blocks; FSDP/context-parallel",
    ("gemma2-2b", "decode_32k"):
        "32k global KV x4 kv-head replication; shard KV seq",
    ("qwen3-32b", "decode_32k"):
        "KV cache bytes; kv-head 8 < tp16 replication 2x",
}


def main():
    rows = []
    for p in sorted(glob.glob(os.path.join(DRY, "*.json"))):
        base = os.path.basename(p)[:-5]
        parts = base.split("__")
        if len(parts) > 3:  # tagged ablations live in §Perf, not here
            continue
        c = json.load(open(p))
        if c.get("skipped"):
            rows.append((c["arch"], c["shape"], parts[2],
                         "skip (full attention)", "-", "-", "-", "-", "-",
                         "long_500k requires sub-quadratic mixer"))
            continue
        r = c["roofline"]
        lever = LEVERS.get((c["arch"], c["shape"]), "")
        rows.append((c["arch"], c["shape"], c["mesh"], r["dominant"],
                     f"{r['compute_s']:.3f}", f"{r['memory_s']:.3f}",
                     f"{r['collective_s']:.3f}",
                     f"{c['useful_flops_ratio']:.3f}",
                     f"{c['peak_hbm_bytes']/2**30:.1f}", lever))
    rows.sort(key=lambda t: (t[0], t[1], t[2]))
    out = os.path.join(ROOT, "experiments", "roofline_table.md")
    with open(out, "w") as f:
        f.write("| arch | shape | mesh | dominant | compute s | memory s "
                "| collective s | useful | HBM GiB | one-line lever |\n")
        f.write("|---|---|---|---|---|---|---|---|---|---|\n")
        for t in rows:
            f.write("| " + " | ".join(t) + " |\n")
    print(f"wrote {out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
