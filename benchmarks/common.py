"""Shared benchmark utilities: timing, data generators (paper Sec. V-A)."""
from __future__ import annotations

import time

import numpy as np

import jax


def timeit(fn, *args, reps=5, warmup=2):
    """Median wall time (s) of jit'd fn; blocks on results."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def paper_datasets(rng, n):
    """The nine distributions of the paper's Sec. V-A."""
    half = lambda m: np.abs(rng.standard_normal(m))
    mix = lambda a, b, fr: np.concatenate([a[: int(n * fr)],
                                           b[: n - int(n * fr)]])
    return {
        "uniform": rng.random(n),
        "normal": rng.standard_normal(n),
        "halfnormal": half(n),
        "beta25": rng.beta(2, 5, n),
        "mix1": mix(rng.standard_normal(n), rng.normal(100, 1, n), 2 / 3),
        "mix2": mix(rng.standard_normal(n) + 1, rng.normal(100, 1, n), .5),
        "mix3": mix(half(n), np.full(n, 10.0), 0.9),
        "mix4": mix(half(n), rng.normal(100, 1, n), 2 / 3),
        "mix5": mix(half(n) + 1, rng.normal(100, 1, n), 0.5),
    }


def emit(rows):
    """Print ``name,us_per_call,derived`` CSV rows."""
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
