"""Roofline table from the cached dry-run artifacts (experiments/dryrun).

This is the source for EXPERIMENTS.md §Roofline.  Run the dry-runs first:
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")


def load_cells(tag=""):
    cells = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        base = os.path.basename(p)[:-5]
        parts = base.split("__")
        cell_tag = parts[3] if len(parts) > 3 else ""
        if cell_tag != tag:
            continue
        with open(p) as f:
            cells.append(json.load(f))
    return cells


def run(full: bool = False):
    rows = []
    for c in load_cells():
        if c.get("skipped"):
            continue
        r = c["roofline"]
        name = f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}"
        rows.append((
            name, r[r["dominant"] + "_s"] * 1e6,
            f"dom={r['dominant']};c={r['compute_s']*1e3:.2f}ms;"
            f"m={r['memory_s']*1e3:.2f}ms;coll={r['collective_s']*1e3:.2f}ms;"
            f"useful={c['useful_flops_ratio']:.3f};"
            f"hbm={c['peak_hbm_bytes']/2**30:.1f}GiB"))
    if not rows:
        rows.append(("roofline/no_dryrun_artifacts", 0.0,
                     "run repro.launch.dryrun first"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
