"""Paper Sec. V-D / Fig. 5: sensitivity to extreme values.

One element is set to ``mag``; bisection/golden/brent need O(log range)
iterations while the cutting-plane count stays flat.  At mag=1e20 (f32
summation breakdown) the log1p monotone-transform guard keeps CP exact.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import selection


def run(full: bool = False):
    n = (1 << 20) if full else (1 << 16)
    rng = np.random.default_rng(2)
    base = rng.standard_normal(n).astype(np.float32)
    k = (n + 1) // 2
    rows = []
    for mag in [0, 1e3, 1e6, 1e9, 1e12]:
        x = base.copy()
        if mag:
            x[0] = mag
        want = np.partition(x, k - 1)[k - 1]
        xj = jnp.asarray(x)
        for method in ["cp", "bisection", "brent"]:
            res = selection.order_statistic(xj, k, method=method, maxit=256)
            ok = np.float32(res.value) == want
            rows.append((f"{method}/outlier={mag:g}", 0.0,
                         f"iters={int(res.iters)};exact={ok}"))
    # f32 precision breakdown + transform guard
    x = base.copy()
    x[:8] = 1e20
    want = np.partition(x, k - 1)[k - 1]
    res_plain = selection.order_statistic(jnp.asarray(x), k, maxit=256)
    res_guard = selection.order_statistic(jnp.asarray(x), k, maxit=256,
                                          transform="log1p")
    rows.append(("cp/outlier=1e20/plain", 0.0,
                 f"exact={np.float32(res_plain.value) == want}"))
    rows.append(("cp/outlier=1e20/log1p_guard", 0.0,
                 f"exact={np.float32(res_guard.value) == want}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run(full=True)
