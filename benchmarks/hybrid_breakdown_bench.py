"""Paper Sec. IV end / Table I breakdown: CP iterations vs pivot-interval
size trade-off.  The paper stops CP after ~7 iterations when sorting the
remaining z (<2^19 of n=2^25) is already fast; we sweep the iteration budget
and report the pivot-interval size |z| and total time, locating the optimal
handoff point for this platform.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import selection


def run(full: bool = False):
    n = (1 << 22) if full else (1 << 18)
    rng = np.random.default_rng(3)
    x = rng.standard_normal(n).astype(np.float32)
    xj = jnp.asarray(x)
    k = (n + 1) // 2
    want = np.partition(x, k - 1)[k - 1]
    rows = []
    # cap IS the handoff knob: the CP loop stops as soon as the counted
    # pivot interval fits the capacity, then compacts + sorts it.
    for cap_exp in [8, 10, 12, 14, 16, 18]:
        cap = 1 << cap_exp
        fn = jax.jit(lambda v, c=cap: selection.order_statistic(
            v, k, maxit=64, cap=c).value)
        t = timeit(fn, xj, reps=3)
        res = selection.order_statistic(xj, k, maxit=64, cap=cap)
        exact = np.float32(res.value) == want
        rows.append((f"hybrid/cap=2^{cap_exp}/n={n}", t * 1e6,
                     f"iters={int(res.iters)};z={int(res.n_in)};"
                     f"frac={int(res.n_in)/n:.4f};exact={exact}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run(full=True)
