"""Subprocess worker: distributed psum-round counts, binned vs polish.

Run as:  python benchmarks/_dist_rounds_worker.py <n_devices> <log2_n>
Sets XLA_FLAGS *before* importing jax, solves the global median on a
host-device mesh for both measures and both round schedules, checks
exactness, and prints one ``DIST_ROUNDS_JSON {...}`` line for the parent
bench to merge into BENCH_selection.json.
"""
import json
import os
import sys

n_dev = int(sys.argv[1]) if len(sys.argv) > 1 else 4
log2_n = int(sys.argv[2]) if len(sys.argv) > 2 else 20
_kept = [f for f in os.environ.get("XLA_FLAGS", "").split()
         if not f.startswith("--xla_force_host_platform_device_count")]
os.environ["XLA_FLAGS"] = " ".join(
    [f"--xla_force_host_platform_device_count={n_dev}"] + _kept)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import _compat, distributed  # noqa: E402

assert jax.device_count() == n_dev, jax.devices()


def main():
    mesh = _compat.make_mesh((n_dev,), ("data",))
    rng = np.random.default_rng(0)
    n = 1 << log2_n
    x = rng.standard_normal(n).astype(np.float32)
    xj = jnp.asarray(x)
    k = (n + 1) // 2
    want = np.partition(x, k - 1)[k - 1]
    w = rng.integers(1, 4, n).astype(np.float32)
    o = np.argsort(x, kind="stable")
    cumw = np.cumsum(w[o].astype(np.float64))
    wk = float(np.float32(0.5 * w.sum()))
    wwant = x[o][min(np.searchsorted(cumw, wk, "left"), n - 1)]

    rec = {"n": n, "n_dev": n_dev, "exact": True}
    for method in ["binned", "binned_polish"]:
        res = distributed.sharded_order_statistic(xj, k, mesh, P("data"),
                                                  method=method)
        assert np.float32(res.value) == want, (method, float(res.value))
        rec[f"rounds_{method}"] = int(res.iters)
        wres = distributed.sharded_weighted_order_statistic(
            xj, jnp.asarray(w), wk, mesh, P("data"), method=method)
        assert np.float32(wres.value) == wwant, (method, float(wres.value))
        rec[f"rounds_{method}_weighted"] = int(wres.iters)
    print("DIST_ROUNDS_JSON " + json.dumps(rec))


if __name__ == "__main__":
    main()
